"""Quickstart: the whole framework in ~60 lines.

1. Reproduce the paper's headline result (Ara-Opt speedups).
2. Run the Fig. 1 chain as a fused TPU kernel.
3. Train a tiny LM and serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

# --- 1. the paper: simulate baseline Ara vs Ara-Opt -------------------------
from repro.core import AraSimulator, OptConfig, geomean, normalized
from repro.core.calibration import load as load_params
from repro.core.traces import DEFAULT_TRACES

sim = AraSimulator(params=load_params())
print("== Ara vs Ara-Opt (calibrated simulator) ==")
speedups = []
for name, make in DEFAULT_TRACES.items():
    tr = make()
    base = sim.run(tr, OptConfig.baseline())
    opt = sim.run(tr, OptConfig.full())
    speedups.append(base.cycles / opt.cycles)
    print(f"  {name:5s} {base.gflops:5.2f} -> {opt.gflops:5.2f} GFLOPS "
          f"({speedups[-1]:.2f}x, roofline frac "
          f"{normalized(base.gflops, tr.operational_intensity):.2f} -> "
          f"{normalized(opt.gflops, tr.operational_intensity):.2f})")
print(f"  geomean speedup: {geomean(speedups):.2f}x  (paper: 1.33x)\n")

# --- 2. the Fig. 1 chain as a fused Pallas kernel ---------------------------
from repro.kernels import ops, ref

k = jax.random.split(jax.random.PRNGKey(0), 3)
x, y, w = (jax.random.normal(kk, (1 << 14,)) for kk in k)
out = ops.fused_chain(x, y, w)          # vle -> vfmul -> vfadd -> vse, fused
assert jnp.allclose(out, ref.chain_ref(x, y, w), atol=1e-5)
print("== fused streaming chain kernel matches oracle ==\n")

# --- 3. train a tiny LM, then serve it ---------------------------------------
from repro.configs import ARCHS, reduced
from repro.models import init_model
from repro.train import optimizer as optm
from repro.train.step import StepConfig, init_state, make_train_step
from repro.data.pipeline import SyntheticLM
from repro.serve.engine import Engine

cfg = reduced(ARCHS["qwen2.5-3b"])
params = init_model(jax.random.PRNGKey(0), cfg)
step = jax.jit(make_train_step(cfg, StepConfig(
    adamw=optm.AdamWConfig(lr=1e-3))), donate_argnums=(0,))
state = init_state(params)
data = SyntheticLM(cfg, batch=4, seq_len=64, seed=0)
print("== training tiny qwen2.5 on a synthetic bigram stream ==")
for i in range(30):
    state, metrics = step(state, next(data))
    if i % 10 == 0:
        print(f"  step {i:3d} loss {float(metrics['loss']):.4f}")
print(f"  step  29 loss {float(metrics['loss']):.4f}\n")

eng = Engine(state.params, cfg, s_max=128, cache_dtype=jnp.float32)
prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                            cfg.vocab_size)
tokens = eng.generate(prompt, max_new=12)
print("== served generations ==")
print("  prompt:", prompt[0].tolist())
print("  output:", tokens[0].tolist())
