"""Batched serving example: prefill + decode across the model zoo,
demonstrating every cache type (GQA linear, sliding-window ring, MLA
latent, SSD state, RG-LRU state).

    PYTHONPATH=src python examples/serve_batch.py
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models import init_model
from repro.serve.engine import Engine

ARCH_LIST = ["glm4-9b", "gemma3-27b", "deepseek-v2-236b",
             "recurrentgemma-2b", "mamba2-780m"]


def type_of_cache(cfg):
    kinds = set(cfg.pattern)
    if kinds == {"ssd"}:
        return "ssm-state"
    if "rglru" in kinds:
        return "rnn+ring"
    if "mla" in kinds:
        return "mla-latent"
    if "local" in kinds and "attn" in kinds:
        return "ring+linear"
    return "linear-kv"


def main() -> None:
    key = jax.random.PRNGKey(0)
    for name in ARCH_LIST:
        cfg = reduced(ARCHS[name])
        params = init_model(key, cfg)
        eng = Engine(params, cfg, s_max=96, cache_dtype=jnp.float32)
        prompt = jax.random.randint(key, (4, 24), 0, cfg.vocab_size)

        t0 = time.perf_counter()
        out = eng.generate(prompt, max_new=16, temperature=0.8, key=key)
        dt = time.perf_counter() - t0
        print(f"{name:22s} cache={type_of_cache(cfg):12s} "
              f"generated {tuple(out.shape)} in {dt:.1f}s "
              f"(first row: {out[0, :8].tolist()})")


if __name__ == "__main__":
    main()
