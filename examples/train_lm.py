"""End-to-end training driver example: train a small LM for a few hundred
steps with the fault-tolerant loop (checkpoints + resumability), then
validate resume-from-checkpoint.

    PYTHONPATH=src python examples/train_lm.py --steps 200

Scale knobs: this same driver trains the ~100M-param preset on real
hardware (--layers 8 --d-model 512 --batch 32 --seq 1024); the default is
CPU-sized so the example completes in minutes.
"""
import argparse
import dataclasses
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS, reduced
from repro.data.pipeline import SyntheticLM
from repro.models import init_model
from repro.train import optimizer as opt
from repro.train.loop import LoopConfig, run
from repro.train.step import StepConfig, init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    cfg = dataclasses.replace(cfg, n_layers=args.layers,
                              d_model=args.d_model,
                              d_ff=4 * args.d_model)
    params = init_model(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n:,}")

    sched = opt.cosine_schedule(args.lr, warmup=args.steps // 10,
                                total=args.steps)
    tstep = jax.jit(make_train_step(cfg, StepConfig(
        microbatches=2, adamw=opt.AdamWConfig(lr=args.lr),
        schedule=sched)), donate_argnums=(0,))

    workdir = pathlib.Path(tempfile.mkdtemp(prefix="repro_train_"))
    ckpt = CheckpointManager(workdir / "ckpt", keep=2)
    data = SyntheticLM(cfg, batch=args.batch, seq_len=args.seq, seed=1)
    res = run(tstep, init_state(params), data, ckpt,
              LoopConfig(total_steps=args.steps,
                         ckpt_every=max(args.steps // 4, 1),
                         log_every=20),
              log_path=str(workdir / "train.jsonl"))
    losses = [h["loss"] for h in res.history]
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps")

    # Demonstrate restart: a second run() resumes from the final checkpoint.
    data2 = SyntheticLM(cfg, batch=args.batch, seq_len=args.seq, seed=1)
    res2 = run(tstep, init_state(init_model(jax.random.PRNGKey(0), cfg)),
               data2, ckpt,
               LoopConfig(total_steps=args.steps + 20,
                          ckpt_every=10, log_every=20))
    print(f"resumed from step {res2.resumed_from}, continued to "
          f"{res2.history[-1]['step']}: loss {res2.history[-1]['loss']:.4f}")
    print(f"artifacts: {workdir}")


if __name__ == "__main__":
    main()
