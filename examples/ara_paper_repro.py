"""Reproduce the paper's evaluation section in one script:
Fig. 3 (speedups), Fig. 4 (gap-closed), Table I (ablation),
Fig. 5 (size sensitivity), plus the deviation-attribution summary
(top stall sources per kernel against the ideal chaining model).

Exits non-zero if the reproduced geomean speedup drifts more than the
tolerance recorded at calibration time in ``ara_calibrated.json``
(``drift_tol``, falling back to `calibration.GEOMEAN_DRIFT_TOL`) —
a silent-model-drift tripwire for CI and local hacking alike.  When
fig7 sensitivity artifacts exist (`benchmarks/fig7_sensitivity.py`),
also prints the top-3 most influential knobs per kernel.

    PYTHONPATH=src python examples/ara_paper_repro.py

All simulation goes through the unified `repro.core.api.simulate`
entrypoint (via `benchmarks.gridlib`); ``--backend``/``--method`` pick
the execution strategy (e.g. ``--method assoc`` reproduces the paper
through the log-depth max-plus engine instead of the sequential scan).
"""
import argparse
import csv
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))

from benchmarks import (fig3_speedup, fig4_roofline, fig5_sensitivity,
                        fig6_attribution, gridlib, table1_ablation)
from repro.analysis.attribution import summarize
from repro.core.calibration import GEOMEAN_DRIFT_TOL as DRIFT_TOL
from repro.core.calibration import load_payload


def print_sensitivity_top3() -> None:
    """Top-3 knobs per kernel from the newest fig7 artifact, if any
    profile's CSV exists (see docs/sensitivity.md for how to read it)."""
    out_dir = REPO / "experiments" / "benchmarks"
    candidates = sorted(out_dir.glob("fig7_sensitivity*.csv"),
                        key=lambda p: p.stat().st_mtime, reverse=True)
    if not candidates:
        return
    path = candidates[0]
    with path.open() as fh:
        rows = list(csv.DictReader(fh))
    if not rows or "tornado_rank" not in rows[0]:
        return
    by_kernel: dict[str, list[dict]] = {}
    for r in rows:
        by_kernel.setdefault(r["kernel"], []).append(r)
    print(f"\n# sensitivity: top-3 knobs per kernel ({path.name})")
    for kernel, krows in by_kernel.items():
        top = sorted(krows, key=lambda r: int(r["tornado_rank"]))[:3]
        knobs = ", ".join(f"{r['knob']} (swing {float(r['swing_speedup']):.3f})"
                          for r in top)
        print(f"{kernel:<6} {knobs}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", choices=("numpy", "jax", "auto"),
                    default="numpy",
                    help="array engine for the batched grid passes")
    ap.add_argument("--method", choices=("scan", "assoc", "auto"),
                    default="scan",
                    help="jax instruction-axis algorithm (assoc = the "
                         "max-plus associative-scan engine)")
    args = ap.parse_args(argv)
    gridlib.set_execution(backend=args.backend, method=args.method)

    # Attribution cells first: they carry everything the plain readers
    # below need, so fig3/fig4/table1 then hit the cache instead of the
    # attribution pass re-simulating their plain cells.
    traces = gridlib.paper_traces()
    cells = gridlib.grid().cells(traces, [gridlib.BASE], attribution=True)
    base = {name: cells[(name, gridlib.BASE.label)] for name in traces}

    fig3_rows = fig3_speedup.main()
    print()
    fig4_roofline.main()
    print()
    table1_ablation.main()
    print()
    fig5_sensitivity.main()
    print()
    print("# top-2 stall sources per kernel (baseline vs ideal chaining)")
    for name, info in summarize(base).items():
        srcs = ", ".join(f"{cat} ({val:.0f} cyc)"
                         for cat, val in info["top2"])
        print(f"{name:<6} cycles={info['cycles']:>9.0f} "
              f"ideal={info['ideal']:>9.0f}  {srcs}")
    fig6_attribution.export_example_trace()
    print_sensitivity_top3()

    # Drift gate: reproduced geomean vs the calibration-time record,
    # at the tolerance the record itself carries (metadata written by
    # `calibration.save`; code-constant fallback for old records).
    gm = next(r["speedup_sim"] for r in fig3_rows
              if r["kernel"] == "GEOMEAN")
    payload = load_payload()
    recorded = payload.get("geomean_speedup")
    tol = float(payload.get("drift_tol", DRIFT_TOL))
    if recorded is None:
        print("\n[drift] no recorded geomean in ara_calibrated.json "
              "(re-run calibration to arm the tripwire)")
        return 0
    drift = abs(gm / recorded - 1.0)
    print(f"\n[drift] geomean speedup {gm:.4f} vs recorded {recorded:.4f} "
          f"({100 * drift:.2f}% drift, tolerance {100 * tol:.0f}%)")
    if drift > tol:
        print("[drift] FAIL: simulator output drifted from the calibrated "
              "record — recalibrate or fix the regression", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
