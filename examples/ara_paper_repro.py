"""Reproduce the paper's evaluation section in one script:
Fig. 3 (speedups), Fig. 4 (gap-closed), Table I (ablation),
Fig. 5 (size sensitivity), plus the deviation-attribution summary
(top stall sources per kernel against the ideal chaining model).

Exits non-zero if the reproduced geomean speedup drifts more than 5%
from the value recorded at calibration time in ``ara_calibrated.json``
— a silent-model-drift tripwire for CI and local hacking alike.

    PYTHONPATH=src python examples/ara_paper_repro.py
"""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))

from benchmarks import (fig3_speedup, fig4_roofline, fig5_sensitivity,
                        fig6_attribution, gridlib, table1_ablation)
from repro.analysis.attribution import summarize
from repro.core.calibration import GEOMEAN_DRIFT_TOL as DRIFT_TOL
from repro.core.calibration import load_payload


def main() -> int:
    # Attribution cells first: they carry everything the plain readers
    # below need, so fig3/fig4/table1 then hit the cache instead of the
    # attribution pass re-simulating their plain cells.
    traces = gridlib.paper_traces()
    cells = gridlib.grid().cells(traces, [gridlib.BASE], attribution=True)
    base = {name: cells[(name, gridlib.BASE.label)] for name in traces}

    fig3_rows = fig3_speedup.main()
    print()
    fig4_roofline.main()
    print()
    table1_ablation.main()
    print()
    fig5_sensitivity.main()
    print()
    print("# top-2 stall sources per kernel (baseline vs ideal chaining)")
    for name, info in summarize(base).items():
        srcs = ", ".join(f"{cat} ({val:.0f} cyc)"
                         for cat, val in info["top2"])
        print(f"{name:<6} cycles={info['cycles']:>9.0f} "
              f"ideal={info['ideal']:>9.0f}  {srcs}")
    fig6_attribution.export_example_trace()

    # Drift gate: reproduced geomean vs the calibration-time record.
    gm = next(r["speedup_sim"] for r in fig3_rows
              if r["kernel"] == "GEOMEAN")
    recorded = load_payload().get("geomean_speedup")
    if recorded is None:
        print("\n[drift] no recorded geomean in ara_calibrated.json "
              "(re-run calibration to arm the tripwire)")
        return 0
    drift = abs(gm / recorded - 1.0)
    print(f"\n[drift] geomean speedup {gm:.4f} vs recorded {recorded:.4f} "
          f"({100 * drift:.2f}% drift, tolerance {100 * DRIFT_TOL:.0f}%)")
    if drift > DRIFT_TOL:
        print("[drift] FAIL: simulator output drifted from the calibrated "
              "record — recalibrate or fix the regression", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
