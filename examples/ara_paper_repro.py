"""Reproduce the paper's evaluation section in one script:
Fig. 3 (speedups), Fig. 4 (gap-closed), Table I (ablation),
Fig. 5 (size sensitivity) — from the calibrated simulator.

    PYTHONPATH=src python examples/ara_paper_repro.py
"""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))

from benchmarks import (fig3_speedup, fig4_roofline, fig5_sensitivity,
                        table1_ablation)

fig3_speedup.main()
print()
fig4_roofline.main()
print()
table1_ablation.main()
print()
fig5_sensitivity.main()
