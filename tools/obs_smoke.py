"""Observability smoke driver (CI `smoke` job).

Runs a small calibrated grid with the runlog enabled — a numpy
attribution pass, a cold jax pass (compile) and a warm one (execute),
plus one SweepCache miss/put/hit cycle — then:

1. emits the merged Perfetto trace (host spans + one simulated cell),
2. prints `summarize_runlog()` (top spans, compile/execute split,
   cache hit rate),
3. exits 1 if any recorded metric name is missing from
   `repro.obs.metrics.KNOWN_METRICS` (docs/observability.md mirrors
   that dict, so an undocumented metric fails CI here).

    python tools/obs_smoke.py --out experiments/obs_smoke
"""
from __future__ import annotations

import argparse
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=str(_REPO / "experiments" /
                                         "obs_smoke"),
                    help="output directory (runlog + merged trace)")
    args = ap.parse_args(argv)

    from repro.core import api
    from repro.core.calibration import load as load_params
    from repro.core.isa import ABLATION_GRID, OptConfig
    from repro.core.simulator import AraSimulator
    from repro.core.traces import axpy, dotp, scal
    from repro.launch.sweep_cache import SweepCache, cell_key
    from repro.obs import export as obs_export

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    runlog = out / "runlog.jsonl"
    if runlog.exists():
        runlog.unlink()                    # one smoke run per file

    params = load_params()
    traces = [scal(256), axpy(256), dotp(256)]
    opts = [OptConfig.baseline(), *ABLATION_GRID]

    # numpy attribution pass, then a cold + warm jax pass so the runlog
    # carries both exec.jax.compile and exec.jax.execute leaves.
    api.simulate(traces, opts, params, backend="numpy",
                 attribution=True, runlog=runlog)
    api.simulate(traces, opts, params, backend="jax", runlog=runlog)
    api.simulate(traces, opts, params, backend="jax", runlog=runlog)

    # One miss/put/hit cycle so the cache counters are non-trivial.
    cache = SweepCache(out / "cache")
    sim = AraSimulator(params=params)
    res = sim.run(traces[0], opts[0])
    key = cell_key(traces[0], opts[0], params)
    cache.get(key)                         # miss
    cache.put_result(key, res)
    cache.get(key)                         # hit
    obs_export.flush(runlog)               # metrics snapshot update

    records = obs_export.read_runlog(runlog)
    trace_path = obs_export.export_merged_trace(
        out / "merged_trace.json", records, [(traces[0], res)])

    print(obs_export.summarize_runlog(runlog))
    print(f"\nmerged trace: {trace_path}")

    unknown = obs_export.check_metric_names(runlog)
    if unknown:
        print(f"\nUNDOCUMENTED METRICS: {', '.join(unknown)}",
              file=sys.stderr)
        return 1
    print("all recorded metric names documented")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
