"""Docs consistency checker (CI `docs` job; also run by tier-1 via
`tests/test_docs.py`).

Four checks:

1. **Intra-repo links resolve.**  Every relative markdown link in
   `README.md` and `docs/**/*.md` must point at a file that exists in
   the repo.  Links under `experiments/` are generated artifacts
   (gitignored) and only checked for staying under that prefix;
   absolute URLs and pure anchors are skipped.
2. **Stall vocabulary stays in sync.**  Every stall-category-shaped
   token (``mem_*``/``dep_*``/``opr_*``) in `docs/attribution.md` must
   name a real category or critical path in `repro.core.stalls`, and
   all nine categories plus all three paths must be documented.
3. **The knob table stays in sync.**  The table between the
   ``knob-table-start``/``knob-table-end`` markers in
   `docs/sensitivity.md` must document exactly the fields of
   `repro.core.simulator.SimParams` — a renamed/added/dropped field
   fails the check in both directions.
4. **Every figure script is documented.**  Each `benchmarks/fig*.py`
   must be named by at least one doc under `docs/` that carries a
   "how to read" section.
5. **The metric table stays in sync.**  The table between the
   ``metric-table-start``/``metric-table-end`` markers in
   `docs/observability.md` must name exactly the keys of
   `repro.obs.metrics.KNOWN_METRICS` — an emitted-but-undocumented
   (or documented-but-gone) metric fails in both directions.
6. **The generator knob table stays in sync.**  The table between the
   ``gen-knob-table-start``/``gen-knob-table-end`` markers in
   `docs/workloads.md` must document exactly the fields of
   `repro.core.tracegen.GenSpec`, and the workload-class taxonomy
   there must name every generator class.
7. **The search-space table stays in sync.**  The table between the
   ``search-table-start``/``search-table-end`` markers in
   `docs/search.md` must document exactly the dimensions of
   `repro.launch.costmodel.SEARCH_SPACE`, each under its correct
   optimization class.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

_LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
_IMG = re.compile(r"!\[[^\]]*\]\(([^)\s]+)\)")
_STALLISH = re.compile(r"\b(?:mem|dep|opr)_[a-z_]+\b")


def _doc_files() -> list[pathlib.Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def check_links() -> list[str]:
    errors: list[str] = []
    for doc in _doc_files():
        rel_doc = doc.relative_to(REPO)
        text = doc.read_text()
        targets = _LINK.findall(text) + _IMG.findall(text)
        for target in targets:
            if "://" in target or target.startswith(("mailto:", "#")):
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:
                continue
            resolved = (doc.parent / path_part).resolve()
            try:
                rel = resolved.relative_to(REPO)
            except ValueError:
                errors.append(f"{rel_doc}: link escapes the repo: "
                              f"{target}")
                continue
            if rel.parts and rel.parts[0] == "experiments":
                continue                   # generated artifact, not in git
            if not resolved.exists():
                errors.append(f"{rel_doc}: broken link: {target}")
    return errors


def check_stall_vocabulary() -> list[str]:
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.stalls import CRITICAL_PATHS, STALL_CATEGORIES
    doc = REPO / "docs" / "attribution.md"
    if not doc.exists():
        return ["docs/attribution.md is missing"]
    text = doc.read_text()
    known = set(STALL_CATEGORIES) | set(CRITICAL_PATHS)
    errors = [f"docs/attribution.md names unknown stall category/path "
              f"{tok!r} (not in repro.core.stalls)"
              for tok in sorted(set(_STALLISH.findall(text)) - known)]
    errors += [f"docs/attribution.md does not document {name!r}"
               for name in (*STALL_CATEGORIES, *CRITICAL_PATHS)
               if name not in text]
    return errors


def check_simparams_table() -> list[str]:
    """docs/sensitivity.md's knob table == dataclasses.fields(SimParams).

    The table rows between the explicit markers are parsed for their
    first backticked column; the resulting set must equal the SimParams
    field set, so a renamed simulator knob fails CI until the doc row
    is renamed with it (the same contract as the stall vocabulary)."""
    import dataclasses
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.simulator import SimParams
    doc = REPO / "docs" / "sensitivity.md"
    if not doc.exists():
        return ["docs/sensitivity.md is missing"]
    text = doc.read_text()
    m = re.search(r"<!-- knob-table-start -->(.*?)<!-- knob-table-end -->",
                  text, re.S)
    if m is None:
        return ["docs/sensitivity.md lacks the knob-table-start/"
                "knob-table-end markers"]
    documented = set(re.findall(r"^\|\s*`([A-Za-z0-9_]+)`", m.group(1),
                                re.M))
    fields = {f.name for f in dataclasses.fields(SimParams)}
    errors = [f"docs/sensitivity.md knob table names unknown SimParams "
              f"field {name!r}" for name in sorted(documented - fields)]
    errors += [f"docs/sensitivity.md knob table does not document "
               f"SimParams field {name!r}"
               for name in sorted(fields - documented)]
    return errors


def check_metric_table() -> list[str]:
    """docs/observability.md's metric table == metrics.KNOWN_METRICS.

    Same contract as the knob table: rows between the explicit markers
    are parsed for their first backticked column, and the set must
    equal KNOWN_METRICS' keys, so a new metric fails CI until its doc
    row lands (and a dropped one until the row is removed)."""
    sys.path.insert(0, str(REPO / "src"))
    from repro.obs.metrics import KNOWN_METRICS
    doc = REPO / "docs" / "observability.md"
    if not doc.exists():
        return ["docs/observability.md is missing"]
    text = doc.read_text()
    m = re.search(
        r"<!-- metric-table-start -->(.*?)<!-- metric-table-end -->",
        text, re.S)
    if m is None:
        return ["docs/observability.md lacks the metric-table-start/"
                "metric-table-end markers"]
    documented = set(re.findall(r"^\|\s*`([A-Za-z0-9_.]+)`", m.group(1),
                                re.M))
    known = set(KNOWN_METRICS)
    errors = [f"docs/observability.md metric table names unknown metric "
              f"{name!r} (not in repro.obs.metrics.KNOWN_METRICS)"
              for name in sorted(documented - known)]
    errors += [f"docs/observability.md metric table does not document "
               f"metric {name!r}" for name in sorted(known - documented)]
    return errors


def check_tracegen_table() -> list[str]:
    """docs/workloads.md's knob table == dataclasses.fields(GenSpec),
    and its taxonomy covers every generator workload class.

    Same contract as the SimParams knob table: rows between the explicit
    markers are parsed for their first backticked column, and the set
    must equal GenSpec's field set, so a renamed/added/dropped generator
    knob fails CI until the doc row moves with it."""
    import dataclasses
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.tracegen import CLASSES, GenSpec
    doc = REPO / "docs" / "workloads.md"
    if not doc.exists():
        return ["docs/workloads.md is missing"]
    text = doc.read_text()
    m = re.search(r"<!-- gen-knob-table-start -->(.*?)"
                  r"<!-- gen-knob-table-end -->", text, re.S)
    if m is None:
        return ["docs/workloads.md lacks the gen-knob-table-start/"
                "gen-knob-table-end markers"]
    documented = set(re.findall(r"^\|\s*`([A-Za-z0-9_]+)`", m.group(1),
                                re.M))
    fields = {f.name for f in dataclasses.fields(GenSpec)}
    errors = [f"docs/workloads.md knob table names unknown GenSpec "
              f"field {name!r}" for name in sorted(documented - fields)]
    errors += [f"docs/workloads.md knob table does not document "
               f"GenSpec field {name!r}"
               for name in sorted(fields - documented)]
    errors += [f"docs/workloads.md does not document workload class "
               f"{cls!r}" for cls in CLASSES if f"`{cls}`" not in text]
    return errors


def check_search_table() -> list[str]:
    """docs/search.md's strength table == costmodel.SEARCH_SPACE.

    Rows between the explicit markers are parsed for their first
    backticked column (the knob name) and their class column; the name
    set must equal the search dimensions and each row's class must
    match the dimension's, so a renamed/added/dropped/re-classed search
    knob fails CI until the doc row moves with it."""
    sys.path.insert(0, str(REPO / "src"))
    from repro.launch.costmodel import SPACE_BY_NAME
    doc = REPO / "docs" / "search.md"
    if not doc.exists():
        return ["docs/search.md is missing"]
    text = doc.read_text()
    m = re.search(
        r"<!-- search-table-start -->(.*?)<!-- search-table-end -->",
        text, re.S)
    if m is None:
        return ["docs/search.md lacks the search-table-start/"
                "search-table-end markers"]
    rows = re.findall(r"^\|\s*`([A-Za-z0-9_]+)`\s*\|\s*([MCO])\s*\|",
                      m.group(1), re.M)
    documented = {name for name, _ in rows}
    known = set(SPACE_BY_NAME)
    errors = [f"docs/search.md search table names unknown search "
              f"dimension {name!r} (not in costmodel.SEARCH_SPACE)"
              for name in sorted(documented - known)]
    errors += [f"docs/search.md search table does not document search "
               f"dimension {name!r}"
               for name in sorted(known - documented)]
    errors += [f"docs/search.md lists {name!r} under class {cls!r}, "
               f"but SEARCH_SPACE says {SPACE_BY_NAME[name].cls!r}"
               for name, cls in rows
               if name in known and cls != SPACE_BY_NAME[name].cls]
    return errors


def check_figure_docs() -> list[str]:
    """Every benchmarks/fig*.py has a "how to read it" doc under docs/."""
    docs = [(p, p.read_text()) for p in sorted((REPO / "docs")
                                               .glob("**/*.md"))]
    errors = []
    for script in sorted((REPO / "benchmarks").glob("fig*.py")):
        hits = [p for p, text in docs
                if script.name in text and re.search(r"how to read",
                                                     text, re.I)]
        if not hits:
            errors.append(f"no doc under docs/ with a 'how to read' "
                          f"section mentions benchmarks/{script.name}")
    return errors


def main() -> int:
    errors = (check_links() + check_stall_vocabulary()
              + check_simparams_table() + check_figure_docs()
              + check_metric_table() + check_tracegen_table()
              + check_search_table())
    for e in errors:
        print(f"ERROR: {e}")
    if not errors:
        print(f"docs check OK ({len(_doc_files())} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
