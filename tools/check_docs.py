"""Docs consistency checker (CI `docs` job; also run by tier-1 via
`tests/test_docs.py`).

Two checks:

1. **Intra-repo links resolve.**  Every relative markdown link in
   `README.md` and `docs/**/*.md` must point at a file that exists in
   the repo.  Links under `experiments/` are generated artifacts
   (gitignored) and only checked for staying under that prefix;
   absolute URLs and pure anchors are skipped.
2. **Stall vocabulary stays in sync.**  Every stall-category-shaped
   token (``mem_*``/``dep_*``/``opr_*``) in `docs/attribution.md` must
   name a real category or critical path in `repro.core.stalls`, and
   all nine categories plus all three paths must be documented.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

_LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
_IMG = re.compile(r"!\[[^\]]*\]\(([^)\s]+)\)")
_STALLISH = re.compile(r"\b(?:mem|dep|opr)_[a-z_]+\b")


def _doc_files() -> list[pathlib.Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def check_links() -> list[str]:
    errors: list[str] = []
    for doc in _doc_files():
        rel_doc = doc.relative_to(REPO)
        text = doc.read_text()
        targets = _LINK.findall(text) + _IMG.findall(text)
        for target in targets:
            if "://" in target or target.startswith(("mailto:", "#")):
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:
                continue
            resolved = (doc.parent / path_part).resolve()
            try:
                rel = resolved.relative_to(REPO)
            except ValueError:
                errors.append(f"{rel_doc}: link escapes the repo: "
                              f"{target}")
                continue
            if rel.parts and rel.parts[0] == "experiments":
                continue                   # generated artifact, not in git
            if not resolved.exists():
                errors.append(f"{rel_doc}: broken link: {target}")
    return errors


def check_stall_vocabulary() -> list[str]:
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.stalls import CRITICAL_PATHS, STALL_CATEGORIES
    doc = REPO / "docs" / "attribution.md"
    if not doc.exists():
        return ["docs/attribution.md is missing"]
    text = doc.read_text()
    known = set(STALL_CATEGORIES) | set(CRITICAL_PATHS)
    errors = [f"docs/attribution.md names unknown stall category/path "
              f"{tok!r} (not in repro.core.stalls)"
              for tok in sorted(set(_STALLISH.findall(text)) - known)]
    errors += [f"docs/attribution.md does not document {name!r}"
               for name in (*STALL_CATEGORIES, *CRITICAL_PATHS)
               if name not in text]
    return errors


def main() -> int:
    errors = check_links() + check_stall_vocabulary()
    for e in errors:
        print(f"ERROR: {e}")
    if not errors:
        print(f"docs check OK ({len(_doc_files())} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
