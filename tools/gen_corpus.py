"""Generate (or verify) the committed scenario corpus.

    python tools/gen_corpus.py --seed 0            # rewrite the corpus
    python tools/gen_corpus.py --check             # CI: regenerate into a
                                                   # temp dir, byte-diff

The corpus is a pure function of ``(seed, per_class)``: scenario specs
are drawn by `repro.core.tracegen.sample_spec`, expanded by `generate`,
classified by arithmetic intensity, and stamped with golden simulation
totals (numpy backend, default `SimParams`, baseline + M+C+O corners)
from one batched `api.simulate` call.  ``--check`` failing means either
the generator, the simulator, or the corpus files drifted — regenerate
and commit, or fix the drift.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parents[1]
if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))

import numpy as np  # noqa: E402

from repro.core import api, tracegen  # noqa: E402
from repro.core.isa import OptConfig  # noqa: E402
from repro.core.simulator import SimParams  # noqa: E402
from repro.data import corpus  # noqa: E402

#: Default corpus shape: every tracegen class x this many scenarios.
PER_CLASS = 16

_CORNERS = (OptConfig.baseline(), OptConfig.full())


def build_scenarios(seed: int = 0, per_class: int = PER_CLASS
                    ) -> list[corpus.Scenario]:
    """Sample, expand, classify, and stamp golden totals (one batched
    numpy attribution call over the whole corpus)."""
    drafts: list[tuple[str, tracegen.GenSpec]] = []
    for cls in tracegen.CORPUS_CLASSES:
        for idx in range(per_class):
            spec = tracegen.sample_spec(cls, seed=seed, index=idx)
            drafts.append((cls, spec))
    traces = [tracegen.generate(spec) for _, spec in drafts]
    batch = api.simulate(traces, list(_CORNERS), SimParams(),
                         backend="numpy", method="scan",
                         bucket="none", attribution=True)
    scenarios: list[corpus.Scenario] = []
    for bi, ((cls, spec), tr) in enumerate(zip(drafts, traces)):
        expected = {}
        for oi_, opt in enumerate(_CORNERS):
            expected[opt.label] = {
                "cycles": float(batch.cycles[bi, oi_, 0]),
                "ideal": float(batch.ideal[bi, oi_, 0]),
                "stalls": [float(x) for x in batch.stalls[bi, oi_, 0]],
            }
        assert np.isfinite(batch.cycles[bi]).all(), tr.name
        scenarios.append(corpus.Scenario(
            name=tr.name, cls=cls, spec=spec, trace=tr,
            intensity=tracegen.classify(tr),
            oi=tr.operational_intensity, expected=expected))
    return scenarios


def _diff_trees(committed: pathlib.Path, fresh: pathlib.Path
                ) -> list[str]:
    errors = []
    fresh_files = {p.name for p in fresh.iterdir()}
    committed_files = ({p.name for p in committed.iterdir()}
                       if committed.exists() else set())
    for name in sorted(fresh_files - committed_files):
        errors.append(f"missing from committed corpus: {name}")
    for name in sorted(committed_files - fresh_files):
        errors.append(f"stale committed file (not regenerated): {name}")
    for name in sorted(fresh_files & committed_files):
        if (committed / name).read_bytes() != (fresh / name).read_bytes():
            errors.append(f"corpus file differs from regeneration: {name}")
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="corpus master seed (default 0, the committed "
                         "corpus)")
    ap.add_argument("--per-class", type=int, default=PER_CLASS)
    ap.add_argument("--out", type=pathlib.Path,
                    default=corpus.CORPUS_DIR)
    ap.add_argument("--check", action="store_true",
                    help="regenerate into a temp dir and byte-diff "
                         "against the committed corpus (exit 1 on drift)")
    args = ap.parse_args(argv)

    if args.check:
        committed = corpus.load_manifest(args.out)
        scenarios = build_scenarios(committed.get("seed", args.seed),
                                    args.per_class)
        with tempfile.TemporaryDirectory() as tmp:
            corpus.dump_corpus(scenarios, pathlib.Path(tmp),
                               committed.get("seed", args.seed))
            errors = _diff_trees(pathlib.Path(args.out),
                                 pathlib.Path(tmp))
        for e in errors:
            print(f"ERROR: {e}")
        if errors:
            print("corpus drift: rerun tools/gen_corpus.py and commit, "
                  "or fix the generator/simulator change")
            return 1
        print(f"corpus check OK ({len(scenarios)} scenarios, "
              f"byte-identical regeneration)")
        return 0

    scenarios = build_scenarios(args.seed, args.per_class)
    manifest = corpus.dump_corpus(scenarios, args.out, args.seed)
    n_cls = len(manifest["classes"])
    print(f"wrote {manifest['n_scenarios']} scenarios across {n_cls} "
          f"classes -> {args.out}")
    for cls, count in manifest["classes"].items():
        print(f"  {cls:14s} {count}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
