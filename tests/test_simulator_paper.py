"""Simulator validation against the paper's published numbers + invariants.

Headline numbers (Fig. 3 / Fig. 4) must be reproduced within tolerance by
the calibrated simulator; structural invariants must hold for ANY parameter
setting (hypothesis-sampled), since they encode the paper's qualitative
claims rather than the RTL's exact timings.
"""
import math

import pytest
from hypothesis_compat import given, settings, st

from repro.core import paper
from repro.core.calibration import load as load_params
from repro.core.isa import ABLATION_GRID, OptConfig, geomean
from repro.core.roofline import gap_closed, normalized
from repro.core.simulator import AraSimulator, SimParams
from repro.core.traces import DEFAULT_TRACES


@pytest.fixture(scope="module")
def sim():
    return AraSimulator(params=load_params())


@pytest.fixture(scope="module")
def results(sim):
    out = {}
    for name, fn in DEFAULT_TRACES.items():
        tr = fn()
        base = sim.run(tr, OptConfig.baseline())
        opt = sim.run(tr, OptConfig.full())
        out[name] = (tr, base, opt)
    return out


def test_geomean_speedup_near_paper(results):
    sp = [b.cycles / o.cycles for _, b, o in results.values()]
    gm = geomean(sp)
    # Paper: 1.33x.  The simulator is cycle-approximate, not RTL: 15% band.
    assert 1.33 * 0.85 <= gm <= 1.33 * 1.15, gm


def test_geomean_matches_calibration_record(results):
    """Drift tripwire (CI arm of examples/ara_paper_repro.py's gate): the
    reproduced geomean must stay within 5% of the geomean recorded in
    ara_calibrated.json at calibration time.  A timing-model edit that
    shifts it must recalibrate (re-recording the value) rather than
    silently drift."""
    from repro.core.calibration import GEOMEAN_DRIFT_TOL, load_payload
    recorded = load_payload().get("geomean_speedup")
    assert recorded is not None, \
        "ara_calibrated.json lacks geomean_speedup; re-run calibration"
    sp = [b.cycles / o.cycles for _, b, o in results.values()]
    gm = geomean(sp)
    assert abs(gm / recorded - 1.0) <= GEOMEAN_DRIFT_TOL, (gm, recorded)


# Tolerances are log-space bands reflecting achieved calibration fidelity
# (EXPERIMENTS.md §Paper-repro discusses the scal/gemm residuals: a strip-
# level model cannot reproduce every RTL pipeline artifact).
@pytest.mark.parametrize("kernel,tol", [
    ("scal", 0.55), ("axpy", 0.25), ("ger", 0.25), ("gemm", 0.30),
    ("dotp", 0.20), ("gemv", 0.20),
])
def test_headline_speedups(results, kernel, tol):
    tr, base, opt = results[kernel]
    sim_speedup = base.cycles / opt.cycles
    target = paper.FIG3_SPEEDUP[kernel]
    assert abs(math.log(sim_speedup / target)) <= tol, \
        (kernel, sim_speedup, target)


def test_ordering_matches_paper(results):
    """Fig. 3 structure: streaming kernels gain most; reduction-bound
    dotp/gemv gain least."""
    sp = {k: b.cycles / o.cycles for k, (_, b, o) in results.items()}
    from repro.core.isa import geomean as gm
    g = gm(list(sp.values()))
    assert sp["scal"] > g * 0.95          # scal at/above the geomean
    assert sp["gemv"] < g and sp["dotp"] < g
    low = sorted(sp, key=sp.get)[:4]
    assert "dotp" in low or "gemv" in low


def test_fig4_baseline_fractions(results):
    for k, (nb, _) in paper.FIG4_NORMALIZED.items():
        tr, base, _ = results[k]
        nsim = normalized(base.gflops, tr.operational_intensity)
        assert abs(nsim - nb) < 0.30, (k, nsim, nb)


def test_fig4_opt_moves_toward_roofline(results):
    """Every kernel's normalized perf must improve; streaming kernels must
    close most of their gap (Fig. 4)."""
    for k, (tr, base, opt) in results.items():
        oi = tr.operational_intensity
        assert normalized(opt.gflops, oi) > normalized(base.gflops, oi), k
    for k in ("scal", "axpy"):
        tr, base, opt = results[k]
        gc = gap_closed(base.gflops, opt.gflops, tr.operational_intensity)
        assert gc > 0.5, (k, gc)


def test_ablation_structure(sim, results):
    """Table I qualitative structure: M is the strongest single class on
    the geomean; M+C approaches All; dotp is insensitive to M."""
    singles = {}
    for label, cfg in (("M", OptConfig(True, False, False)),
                       ("C", OptConfig(False, True, False)),
                       ("O", OptConfig(False, False, True))):
        sp = []
        for name in ("scal", "axpy", "ger", "gemm", "gemv", "dotp"):
            tr, base, _ = results[name]
            sp.append(base.cycles / sim.run(tr, cfg).cycles)
        singles[label] = geomean(sp)
    assert singles["M"] >= singles["C"] - 0.02
    assert singles["M"] >= singles["O"] - 0.02

    tr, base, opt_all = results["dotp"]
    m_only = sim.run(tr, OptConfig(True, False, False))
    assert base.cycles / m_only.cycles < 1.15          # paper: 1.00

    mc, all_ = [], []
    for name in ("scal", "axpy", "ger", "gemm"):
        tr, base, opt = results[name]
        mc.append(base.cycles / sim.run(tr, OptConfig(True, True, False)).cycles)
        all_.append(base.cycles / opt.cycles)
    assert geomean(mc) > 0.8 * geomean(all_)


def test_gemm_lane_utilization_direction(results):
    """§VI.C: gemm lane utilization rises substantially (0.58 -> 0.83)."""
    _, base, opt = results["gemm"]
    assert opt.lane_utilization > base.lane_utilization + 0.03
    assert 0.3 < base.lane_utilization < 0.92


# --- invariants for arbitrary physical parameters -------------------------

# Physical region: baseline-side costs must dominate opt-side constants
# (d_chain_base >= d_fwd, shallow baseline queues, nonzero release ovh).
_param_strategy = st.fixed_dictionaries({
    "mem_latency": st.floats(10, 120),
    "tx_ovh_base": st.floats(0.05, 1.0),
    "rw_turnaround_base": st.floats(1.0, 30.0),
    "store_commit_base": st.floats(0.0, 80.0),
    "issue_gap_base": st.floats(1.0, 8.0),
    "war_release_ovh": st.floats(2.0, 40.0),
    "d_chain_base": st.floats(3.0, 30.0),
    "queue_adv_base": st.floats(8.0, 64.0),
})


@given(vals=_param_strategy)
@settings(max_examples=20, deadline=None)
def test_opt_never_slower(vals):
    """Ara-Opt must never lose to baseline under any physical params."""
    sim = AraSimulator(params=SimParams(**vals))
    for name in ("scal", "axpy", "dotp", "gemv"):
        tr = DEFAULT_TRACES[name]()
        assert sim.speedup(tr, OptConfig.full()) >= 0.97, (name, vals)


@given(vals=_param_strategy)
@settings(max_examples=10, deadline=None)
def test_all_beats_or_ties_singles(vals):
    sim = AraSimulator(params=SimParams(**vals))
    tr = DEFAULT_TRACES["scal"]()
    full = sim.speedup(tr, OptConfig.full())
    for cfg in ABLATION_GRID[:3]:
        assert full >= sim.speedup(tr, cfg) - 0.02


def test_cycles_positive_and_flops_conserved(results):
    for name, (tr, base, opt) in results.items():
        assert base.cycles > 0 and opt.cycles > 0
        assert base.flops == opt.flops == tr.total_flops


def test_perf_below_rooflines(results):
    """No configuration may exceed the hardware roofline."""
    for name, (tr, base, opt) in results.items():
        oi = tr.operational_intensity
        for r in (base, opt):
            assert normalized(r.gflops, oi) <= 1.02, (name, r.gflops)
