"""Design-space-search contracts (repro.launch.design_search +
repro.launch.costmodel).

Load-bearing invariants:

  * the cost model reproduces the paper's Table II anchors exactly
    (base 2.64 mm^2 / 141.89 mW; full Ara-Opt at default strengths
    2.78 mm^2 / 214.05 mW) and is monotone in knob aggressiveness;
  * designs canonicalize: bound-clipped, disabled-class knobs dropped,
    so two construction routes to one design share a fingerprint and
    the archive never re-simulates a re-proposed candidate;
  * same seed => byte-identical search log and frontier;
  * (property) `pareto_front` returns a mutually non-dominated set
    that weakly dominates every excluded point;
  * the search never loses: with the paper corners injected, the best
    design on the calibrated grid scores >= the recorded Ara-Opt
    geomean (`ara_calibrated.json`);
  * populations are scored in batched calls only — `simulate.calls`
    grows with the number of opt corners, never with the number of
    candidates;
  * the committed `experiments/search/pareto.json` stays mutually
    non-dominated and drift-free against the calibration anchor.
"""
import json

import pytest
from hypothesis_compat import given, settings, st

from repro.core.calibration import load as load_calibrated
from repro.core.calibration import load_payload
from repro.core.isa import OptConfig
from repro.core.simulator import SimParams
from repro.core.traces import axpy, gemm, scal
from repro.launch import costmodel as C
from repro.launch import design_search as D
from repro.launch import hillclimb
from repro.obs import metrics as obs_metrics


def _traces():
    return {"scal": scal(128), "axpy": axpy(128),
            "gemm": gemm(8, 8, 8)}


def _classes():
    return {"scal": "blas1", "axpy": "blas1", "gemm": "blas3"}


@pytest.fixture(scope="module")
def scorer():
    return D.PopulationScorer(_traces(), _classes(),
                              center=load_calibrated())


# -- cost model ------------------------------------------------------------

def test_cost_model_reproduces_table2_anchors():
    base = C.design_cost(OptConfig.baseline(), SimParams())
    assert base["area_mm2"] == pytest.approx(2.64, abs=1e-12)
    assert base["power_mw"] == pytest.approx(141.89, abs=1e-12)
    full = C.design_cost(OptConfig.full(), SimParams())
    assert full["area_mm2"] == pytest.approx(2.78, abs=1e-9)
    assert full["power_mw"] == pytest.approx(214.05, abs=1e-9)
    assert base["cost"] < full["cost"]


def test_cost_monotone_in_aggressiveness():
    """Pushing any knob toward its 'stronger' end never cheapens the
    design, and strictly prices the fully-maxed design above the
    defaults."""
    for dim in C.SEARCH_SPACE:
        weak, strong = ((dim.hi, dim.lo) if dim.stronger == "down"
                        else (dim.lo, dim.hi))
        p_weak = D.make_design(True, True, True, {dim.name: weak})
        p_strong = D.make_design(True, True, True, {dim.name: strong})
        cw = C.design_cost(p_weak.opt, p_weak.params(SimParams()))
        cs = C.design_cost(p_strong.opt, p_strong.params(SimParams()))
        assert cs["cost"] >= cw["cost"], dim.name
    maxed = D.make_design(True, True, True, {
        d.name: (d.lo if d.stronger == "down" else d.hi)
        for d in C.SEARCH_SPACE})
    assert C.design_cost(maxed.opt, maxed.params(SimParams()))["cost"] \
        > C.design_cost(OptConfig.full(), SimParams())["cost"]


def test_disabled_class_contributes_no_cost():
    only_m = D.make_design(True, False, False,
                           {"prefetch_hit": 1.0, "tx_ovh_opt": 0.02})
    with_o_knobs = D.make_design(True, False, False,
                                 {"prefetch_hit": 1.0,
                                  "tx_ovh_opt": 0.02,
                                  "queue_adv_opt": 512.0})
    # The O knob is dropped at canonicalization: same design, same cost.
    assert only_m == with_o_knobs
    assert C.design_cost(only_m.opt, only_m.params(SimParams()))["cost"] \
        < C.design_cost(OptConfig.full(), SimParams())["cost"]


# -- design canonicalization ----------------------------------------------

def test_make_design_clips_fills_and_drops():
    d = D.make_design(True, False, True,
                      {"prefetch_hit": 99.0,       # clipped to hi
                       "issue_gap_opt": 1.0})      # C off: dropped
    strengths = dict(d.strengths)
    assert strengths["prefetch_hit"] == 16.0
    assert "issue_gap_opt" not in strengths
    # Missing enabled-class knobs fill from the center (paper defaults).
    assert strengths["d_fwd"] == SimParams().d_fwd
    assert d.label == "M+O"


def test_design_fingerprint_identity():
    a = D.make_design(True, True, False, {"prefetch_hit": 3.0})
    b = D.make_design(True, True, False,
                      {"prefetch_hit": 3.0, "d_fwd": 9.0})  # O off: dropped
    assert a == b and a.key == b.key
    c = D.make_design(True, True, False, {"prefetch_hit": 3.5})
    assert a.key != c.key


def test_paper_corners_cover_table1():
    corners = D.paper_corners()
    assert [c.label for c in corners] == ["base", "M", "C", "O", "M+C+O"]
    assert corners[0].strengths == ()
    # Ara-Opt carries every search knob at its calibrated strength.
    cal = load_calibrated()
    ara = dict(corners[-1].strengths)
    assert set(ara) == {d.name for d in C.SEARCH_SPACE}
    assert ara["idx_ovh_opt"] == cal.idx_ovh_opt


# -- population scoring ---------------------------------------------------

def test_baseline_design_scores_one(scorer):
    scored = scorer.score([D.baseline_design()])[0]
    assert scored.score == pytest.approx(1.0, abs=1e-12)
    assert scored.cost == pytest.approx(2.64, abs=1e-12)
    assert scored.dominant_path in ("mem_supply", "dep_issue", "operand")


def test_scoring_is_batched_not_per_candidate(scorer):
    """A population spanning k opt corners costs exactly k batched
    simulate calls — never one per candidate."""
    designs = [D.ara_opt_design(),
               D.make_design(True, True, True, {"prefetch_hit": 2.0}),
               D.make_design(True, True, True, {"prefetch_hit": 8.0}),
               D.make_design(True, False, False),
               D.make_design(True, False, False, {"tx_ovh_opt": 0.5}),
               D.make_design(False, True, False)]
    corners = len({d.label for d in designs})
    calls0 = obs_metrics.counter("simulate.calls").value
    groups0 = obs_metrics.counter("simulate.groups").value
    cand0 = obs_metrics.counter("search.candidates").value
    scored = scorer.score(designs)
    calls = obs_metrics.counter("simulate.calls").value - calls0
    groups = obs_metrics.counter("simulate.groups").value - groups0
    cand = obs_metrics.counter("search.candidates").value - cand0
    assert len(scored) == len(designs)
    assert calls == corners < len(designs)
    assert groups == corners
    assert cand == len(designs)
    # Input order is preserved through the corner-grouped dispatch.
    assert [s.design for s in scored] == designs


def test_scored_design_carries_per_class_gaps(scorer):
    s = scorer.score([D.ara_opt_design()])[0]
    assert dict(s.gap_by_class).keys() == {"blas1", "blas3"}
    assert s.geomean_speedup > 1.0
    assert abs(sum(v for _, v in s.path_shares) - 1.0) < 1e-9


def test_gap_closed_objective(scorer_gap=None):
    sc = D.PopulationScorer(_traces(), _classes(),
                            center=load_calibrated(),
                            objective="gap_closed")
    base, ara = sc.score([D.baseline_design(), D.ara_opt_design()])
    # Baseline closes none of its own gap; Ara-Opt closes a real share.
    assert base.score == pytest.approx(D.GAP_FLOOR)
    assert 0.0 < ara.score <= 1.5


# -- Pareto frontier ------------------------------------------------------

def _stub(i: int, score: float, cost: float) -> D.ScoredDesign:
    design = D.make_design(True, False, False,
                           {"prefetch_hit": 1.0 + i * 1e-6})
    return D.ScoredDesign(design=design, score=score, cost=cost,
                          area_mm2=cost, power_mw=0.0,
                          geomean_speedup=score, gap_closed=0.0,
                          gap_by_class=(), dominant_path="mem_supply",
                          path_shares=())


@given(points=st.lists(
    st.tuples(st.floats(min_value=0.5, max_value=2.0),
              st.floats(min_value=2.0, max_value=3.0)),
    min_size=1, max_size=24))
@settings(max_examples=60, deadline=None)
def test_pareto_front_property(points):
    """The frontier is mutually non-dominated AND weakly dominates
    every evaluated point it excludes."""
    scored = [_stub(i, s, c) for i, (s, c) in enumerate(points)]
    front = D.pareto_front(scored)
    assert front, "frontier of a non-empty set is non-empty"
    keys = {f.key for f in front}
    for a in front:
        for b in front:
            if a is not b:
                assert not D.dominates(a, b)
    for p in scored:
        if p.key in keys:
            continue
        assert any(f.score >= p.score and f.cost <= p.cost
                   for f in front), (p.score, p.cost)
    # Cheapest-first, strictly increasing in both axes along the front.
    for lo, hi in zip(front, front[1:]):
        assert lo.cost < hi.cost and lo.score < hi.score


def test_pareto_front_dedupes_exact_ties():
    scored = [_stub(0, 1.2, 2.7), _stub(1, 1.2, 2.7), _stub(2, 1.0, 2.6)]
    front = D.pareto_front(scored)
    assert [(f.score, f.cost) for f in front] == [(1.0, 2.6), (1.2, 2.7)]


# -- the search loop ------------------------------------------------------

def _tiny_search(seed=0, **kw):
    kw.setdefault("algorithm", "evolve")
    kw.setdefault("generations", 2)
    kw.setdefault("population", 6)
    kw.setdefault("sobol_n", 4)
    scorer = kw.pop("scorer")
    return D.run_search(seed=seed, scorer=scorer,
                        center=load_calibrated(), **kw)


def _search_log(result):
    return json.dumps(result.history) + "|" + json.dumps(
        [(s.key, s.score, s.cost) for s in result.frontier])


def test_seed_determinism(scorer):
    a = _tiny_search(seed=7, scorer=scorer)
    b = _tiny_search(seed=7, scorer=scorer)
    assert _search_log(a) == _search_log(b)
    assert a.best.key == b.best.key
    assert a.config == b.config


def test_archive_never_rescores_duplicates(scorer):
    """Injecting the same corner twice evaluates it once: the archive
    is fingerprint-keyed and `evaluated` holds unique designs."""
    inject = D.paper_corners() + [D.ara_opt_design(),
                                  D.baseline_design()]
    cand0 = obs_metrics.counter("search.candidates").value
    r = _tiny_search(seed=1, scorer=scorer, generations=1,
                     population=4, sobol_n=0, inject=inject)
    keys = [s.key for s in r.evaluated]
    assert len(keys) == len(set(keys))
    scored = obs_metrics.counter("search.candidates").value - cand0
    assert scored == len(keys)


def test_search_respects_cost_bound(scorer):
    """With a bound below every optimized corner, only the baseline is
    feasible and must win `best` (selection is feasible-first)."""
    r = _tiny_search(seed=2, scorer=scorer, generations=1,
                     population=4, sobol_n=0, cost_bound=2.644)
    assert r.best.design == D.baseline_design()
    assert any(s.cost > 2.644 for s in r.evaluated)  # infeasible archived


@pytest.mark.parametrize("algorithm", ["beam", "random", "chain"])
def test_all_algorithms_produce_frontiers(scorer, algorithm):
    r = _tiny_search(seed=3, scorer=scorer, algorithm=algorithm,
                     generations=1, population=4, beam_width=2,
                     branch=2, restarts=2, sobol_n=0)
    assert r.frontier and r.best.score >= 1.0
    assert r.config["algorithm"] == algorithm
    assert r.history[0]["gen"] == 0


def test_search_never_loses_on_calibrated_grid():
    """The acceptance gate: with the paper corners injected, a smoke-
    budget search over the calibrated 11-kernel grid returns a best
    design scoring >= the recorded Ara-Opt geomean (elitism keeps the
    injected Ara-Opt corner; the search may only improve on it)."""
    recorded = load_payload()["geomean_speedup"]
    r = D.run_search(algorithm="evolve", objective="speedup",
                     eval_set="grid", seed=0, generations=1,
                     population=8, sobol_n=0)
    assert r.best.score >= recorded - 1e-9
    ara_key = D.ara_opt_design().key
    assert ara_key in {s.key for s in r.evaluated}
    # Ara-Opt's own grid score IS the calibration artifact's geomean.
    ara = next(s for s in r.evaluated if s.key == ara_key)
    assert ara.score == pytest.approx(recorded, abs=1e-9)


def test_hillclimb_delegates_to_chain(scorer, monkeypatch):
    seen = {}
    real = D.run_search

    def spy(**kw):
        seen.update(kw)
        return real(scorer=scorer, **{k: v for k, v in kw.items()
                                      if k not in ("eval_set",)})
    monkeypatch.setattr(D, "run_search", spy)
    r = hillclimb.climb(seed=0, generations=1, branch=2)
    assert seen["algorithm"] == "chain"
    assert r.best.score >= 1.0


# -- committed artifact ---------------------------------------------------

def test_committed_pareto_is_nondominated_and_drift_free():
    """The committed frontier file passes its own CI gate's static
    checks (mutual non-domination + calibrated-geomean drift) without
    re-running the search: regen is stubbed with the committed payload
    itself, so only the intrinsic properties are exercised here — the
    full regeneration equivalence runs in the CI smoke job."""
    committed = json.loads(D.PARETO_PATH.read_text())
    assert D.check_committed(regen=committed) == []
    recorded = load_payload()["geomean_speedup"]
    assert committed["best_calibrated"]["calibrated_geomean"] \
        >= recorded - 1e-6
    assert committed["config"] == dict(
        D.CANONICAL_BUDGET,
        cost_bound=committed["config"]["cost_bound"],
        backend="numpy", method="scan", per_class=2,
        co_move_pairs=committed["config"]["co_move_pairs"])


def test_check_committed_flags_dominated_frontier(tmp_path):
    committed = json.loads(D.PARETO_PATH.read_text())
    broken = json.loads(json.dumps(committed))
    # Duplicate the best frontier point at a higher cost: dominated.
    worst = dict(broken["frontier"][-1])
    worst["cost"] = worst["cost"] + 1.0
    broken["frontier"].append(worst)
    p = tmp_path / "pareto.json"
    p.write_text(json.dumps(broken))
    errors = D.check_committed(path=p, regen=broken)
    assert any("dominated" in e for e in errors)


def test_eval_traces_corpus_budget():
    traces, classes = D.eval_traces("corpus", per_class=1)
    assert set(traces) == set(classes)
    per = {}
    for cls in classes.values():
        per[cls] = per.get(cls, 0) + 1
    assert all(n == 1 for n in per.values())
    assert len(per) >= 5       # the corpus spans the workload classes
    with pytest.raises(ValueError):
        D.eval_traces("nope")
