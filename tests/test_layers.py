"""Layer-level correctness and property tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import ARCHS, reduced
from repro.models import layers as L
from repro.models import moe as M
from repro.models.attention import attend_chunked, attend_naive

CFG = reduced(ARCHS["qwen2.5-3b"])
KEY = jax.random.PRNGKey(3)


# --- norms -------------------------------------------------------------------

def test_rmsnorm_scale_invariant_direction():
    p = L.init_norm(KEY, CFG)
    x = jax.random.normal(KEY, (2, 8, CFG.d_model))
    y1 = L.apply_norm(p, x, CFG)
    y2 = L.apply_norm(p, 100.0 * x, CFG)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)


def test_layernorm_zero_mean_unit_var():
    cfg = dataclasses.replace(CFG, norm="layernorm")
    p = L.init_norm(KEY, cfg)
    x = jax.random.normal(KEY, (4, 16, cfg.d_model)) * 7 + 3
    y = L.apply_norm(p, x, cfg)
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.var(y, -1), 1.0, atol=1e-2)


# --- rope ---------------------------------------------------------------------

def test_rope_preserves_norm():
    x = jax.random.normal(KEY, (2, 8, 4, 32))
    pos = jnp.arange(8)[None, :]
    y = L.apply_rope(x, pos, theta=1e4)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    q = jax.random.normal(KEY, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 64))
    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.array([[i]]), 1e4)
        kj = L.apply_rope(k, jnp.array([[j]]), 1e4)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-3
    assert abs(dot_at(0, 0) - dot_at(25, 25)) < 1e-3


def test_rope_position_zero_identity():
    x = jax.random.normal(KEY, (1, 1, 2, 32))
    y = L.apply_rope(x, jnp.zeros((1, 1), jnp.int32), 1e4)
    np.testing.assert_allclose(y, x, atol=1e-6)


# --- attention implementations agree ------------------------------------------

@given(sq=st.sampled_from([16, 64, 96]), window=st.sampled_from([None, 32]),
       causal=st.booleans())
@settings(max_examples=12, deadline=None)
def test_chunked_equals_naive(sq, window, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, 4, 16))
    k = jax.random.normal(ks[1], (2, sq, 2, 16))
    v = jax.random.normal(ks[2], (2, sq, 2, 16))
    kw = dict(causal=causal, window=window, scale=0.25, softcap=0.0)
    out_n = attend_naive(q, k, v, **kw)
    out_c = attend_chunked(q, k, v, chunk=32, **kw)
    np.testing.assert_allclose(out_n, out_c, rtol=2e-4, atol=2e-4)


def test_chunked_handles_ragged_tail():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 50, 2, 16))
    k = jax.random.normal(ks[1], (1, 50, 2, 16))
    v = jax.random.normal(ks[2], (1, 50, 2, 16))
    out_n = attend_naive(q, k, v, causal=True, window=None, scale=0.25,
                         softcap=0.0)
    out_c = attend_chunked(q, k, v, causal=True, window=None, scale=0.25,
                           softcap=0.0, chunk=32)
    np.testing.assert_allclose(out_n, out_c, rtol=2e-4, atol=2e-4)


# --- MoE ----------------------------------------------------------------------

MOE_CFG = dataclasses.replace(
    reduced(ARCHS["granite-moe-3b-a800m"]), capacity_factor=8.0)


def test_moe_output_shape_and_grads():
    p = M.init_moe(KEY, MOE_CFG)
    x = jax.random.normal(KEY, (2, 16, MOE_CFG.d_model))
    y = M.apply_moe(p, x, MOE_CFG)
    assert y.shape == x.shape
    g = jax.grad(lambda pp: jnp.sum(M.apply_moe(pp, x, MOE_CFG) ** 2))(p)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    # Router must receive gradient (differentiable top-k combine).
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0


def test_moe_identical_experts_equal_dense():
    """If all experts share identical weights, MoE == that single expert's
    GLU (combine weights sum to 1): routing becomes irrelevant."""
    p = M.init_moe(KEY, MOE_CFG)
    we = p["experts"]
    for k in we:
        we[k] = jnp.broadcast_to(we[k][:1], we[k].shape)
    x = jax.random.normal(KEY, (1, 8, MOE_CFG.d_model))
    y = M.apply_moe(p, x, MOE_CFG)
    from repro.models.layers import apply_ffn
    dense = apply_ffn({"w_gate": we["w_gate"][0], "w_in": we["w_in"][0],
                       "w_out": we["w_out"][0]}, x, MOE_CFG)
    if "shared" in p:
        dense = dense + apply_ffn(p["shared"], x, MOE_CFG)
    np.testing.assert_allclose(y, dense, rtol=2e-3, atol=2e-3)


def test_moe_load_stats():
    p = M.init_moe(KEY, MOE_CFG)
    x = jax.random.normal(KEY, (4, 32, MOE_CFG.d_model))
    stats = M.router_stats(p, x, MOE_CFG)
    counts = np.asarray(stats["expert_counts"])
    assert counts.sum() == 4 * 32 * MOE_CFG.moe_top_k
    assert (counts >= 0).all()


@given(cap=st.floats(0.3, 1.0))
@settings(max_examples=8, deadline=None)
def test_moe_capacity_drops_bounded(cap):
    """With tight capacity the output must stay finite and bounded (dropped
    tokens contribute zero, never NaN)."""
    cfg = dataclasses.replace(MOE_CFG, capacity_factor=cap)
    p = M.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y = M.apply_moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


# --- ffn ----------------------------------------------------------------------

def test_glu_ffn_matches_manual():
    p = L.init_ffn(KEY, CFG)
    x = jax.random.normal(KEY, (2, 4, CFG.d_model))
    y = L.apply_ffn(p, x, CFG)
    manual = (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])) @ p["w_out"]
    np.testing.assert_allclose(y, manual, rtol=1e-5, atol=1e-5)
