"""Calibration plumbing: jax-backend scoring parity + recorded geomean."""
import dataclasses
import json

import pytest

from repro.core import calibration as C
from repro.core.simulator import SimParams

#: All 11 kernels at reduced sizes: the loss reads every kernel, but
#: backend parity doesn't need paper-sized instruction streams.
_small_traces = C.parity_traces


def test_evaluate_many_jax_backend_parity():
    jax = pytest.importorskip("jax")
    del jax
    traces = _small_traces()
    plist = [SimParams(),
             SimParams(mem_latency=70.0, issue_gap_base=4.0)]
    ref = C.evaluate_many(plist, traces)
    got = C.evaluate_many(plist, traces, backend="jax")
    for m_ref, m_got in zip(ref, got):
        for kernel, s in m_ref["speedup"].items():
            assert m_got["speedup"][kernel] == pytest.approx(s, rel=1e-6)
        assert m_got["geomean_speedup"] == \
            pytest.approx(m_ref["geomean_speedup"], rel=1e-6)
        assert C.loss(m_got) == pytest.approx(C.loss(m_ref), rel=1e-5)


def test_check_backend_parity():
    pytest.importorskip("jax")
    diff = C.check_backend_parity("jax")       # default: reduced sizes
    assert diff <= 1e-6


def test_check_backend_parity_rejects_divergence(monkeypatch):
    calls = {}

    def fake_losses(cands, traces, backend="numpy",
                    attribution_weight=0.0, method="scan"):
        calls[backend] = True
        return [1.0 if backend == "numpy" else 2.0]

    monkeypatch.setattr(C, "_losses_of", fake_losses)
    with pytest.raises(RuntimeError, match="disagrees"):
        C.check_backend_parity("jax", _small_traces())
    assert calls == {"numpy": True, "jax": True}


def test_evaluate_attribution_metrics():
    """attribution=True attaches per-kernel path/category shares of
    baseline and full-opt cycles, and attribution_loss consumes them."""
    from repro.core.stalls import PATH_NAMES, STALL_CATEGORIES
    m = C.evaluate(SimParams(), _small_traces(), attribution=True)
    for tag in ("base", "full"):
        assert set(m[f"paths_{tag}"]["scal"]) == set(PATH_NAMES)
        assert set(m[f"stalls_{tag}"]["gemm"]) == set(STALL_CATEGORIES)
        for kernel, shares in m[f"paths_{tag}"].items():
            for path, share in shares.items():
                assert -1e-9 <= share <= 1.0 + 1e-9, (kernel, path)
    al = C.attribution_loss(m)
    assert al >= 0.0
    # The calibrated model keeps the paper's narrative: scal/axpy lose
    # to mem-supply at baseline, so those hinge terms are inactive.
    pb = m["paths_base"]
    for k in ("scal", "axpy"):
        assert pb[k]["mem_supply"] >= max(pb[k]["dep_issue"],
                                          pb[k]["operand"])


def test_attribution_weighted_loss_jax_parity():
    """--backend jax scores attribution-aware objectives: weighted loss
    matches numpy through the compiled attribution scan."""
    pytest.importorskip("jax")
    diff = C.check_backend_parity("jax", attribution_weight=0.5)
    assert diff <= 1e-6


def test_save_records_geomean(tmp_path):
    path = tmp_path / "cal.json"
    params = SimParams()
    metrics = {"geomean_speedup": 1.25}
    C.save(params, 0.5, path=path, metrics=metrics)
    payload = json.loads(path.read_text())
    assert payload["loss"] == 0.5
    assert payload["geomean_speedup"] == 1.25
    assert payload["params"] == dataclasses.asdict(params)
    assert C.load(path) == params
    assert C.load_payload(path)["geomean_speedup"] == 1.25
    assert C.load_payload(tmp_path / "missing.json") == {}
