"""Docs tree contracts: links resolve, stall vocabulary stays in sync.

The CI `docs` job runs `tools/check_docs.py` standalone; running the
same checks in tier-1 keeps a broken doc from ever reaching that job.
"""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_required_docs_exist():
    for rel in ("README.md", "docs/architecture.md",
                "docs/attribution.md", "docs/backends.md"):
        assert (REPO / rel).is_file(), f"{rel} missing"


def test_intra_repo_links_resolve():
    assert check_docs.check_links() == []


def test_stall_vocabulary_in_sync():
    assert check_docs.check_stall_vocabulary() == []


def test_roadmap_points_at_docs():
    """The stall-report prose moved out of ROADMAP.md; the pointer must
    survive future edits."""
    text = (REPO / "ROADMAP.md").read_text()
    assert "docs/attribution.md" in text
    assert "docs/backends.md" in text
