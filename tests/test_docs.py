"""Docs tree contracts: links resolve, stall vocabulary stays in sync.

The CI `docs` job runs `tools/check_docs.py` standalone; running the
same checks in tier-1 keeps a broken doc from ever reaching that job.
"""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_required_docs_exist():
    for rel in ("README.md", "docs/architecture.md",
                "docs/attribution.md", "docs/backends.md",
                "docs/sensitivity.md", "docs/figures.md",
                "docs/observability.md", "docs/workloads.md"):
        assert (REPO / rel).is_file(), f"{rel} missing"


def test_intra_repo_links_resolve():
    assert check_docs.check_links() == []


def test_stall_vocabulary_in_sync():
    assert check_docs.check_stall_vocabulary() == []


def test_simparams_knob_table_in_sync():
    """docs/sensitivity.md's knob table must match
    `dataclasses.fields(SimParams)` exactly — a renamed field fails."""
    assert check_docs.check_simparams_table() == []


def test_simparams_check_catches_renames(monkeypatch, tmp_path):
    """The checker really is bidirectional: a doc row for a
    nonexistent field and a missing row both surface as errors."""
    doc = tmp_path / "docs" / "sensitivity.md"
    doc.parent.mkdir()
    real = (REPO / "docs" / "sensitivity.md").read_text()
    doc.write_text(real.replace("`mem_latency`", "`mem_latencyy`", 1))
    monkeypatch.setattr(check_docs, "REPO", tmp_path)
    errors = check_docs.check_simparams_table()
    assert any("mem_latencyy" in e for e in errors)          # unknown row
    assert any("'mem_latency'" in e for e in errors)         # missing row


def test_metric_table_in_sync():
    """docs/observability.md's metric table must match
    `repro.obs.metrics.KNOWN_METRICS` exactly, both directions."""
    assert check_docs.check_metric_table() == []


def test_metric_check_catches_divergence(monkeypatch, tmp_path):
    doc = tmp_path / "docs" / "observability.md"
    doc.parent.mkdir()
    real = (REPO / "docs" / "observability.md").read_text()
    doc.write_text(real.replace("`simulate.calls`",
                                "`simulate.callz`", 1))
    monkeypatch.setattr(check_docs, "REPO", tmp_path)
    errors = check_docs.check_metric_table()
    assert any("simulate.callz" in e for e in errors)     # unknown row
    assert any("'simulate.calls'" in e for e in errors)   # missing row


def test_tracegen_knob_table_in_sync():
    """docs/workloads.md's generator knob table must match
    `dataclasses.fields(GenSpec)` exactly, and the taxonomy must name
    every workload class."""
    assert check_docs.check_tracegen_table() == []


def test_tracegen_check_catches_renames(monkeypatch, tmp_path):
    doc = tmp_path / "docs" / "workloads.md"
    doc.parent.mkdir()
    real = (REPO / "docs" / "workloads.md").read_text()
    doc.write_text(real.replace("| `chain_depth`", "| `chain_depthh`", 1))
    monkeypatch.setattr(check_docs, "REPO", tmp_path)
    errors = check_docs.check_tracegen_table()
    assert any("chain_depthh" in e for e in errors)       # unknown row
    assert any("'chain_depth'" in e for e in errors)      # missing row


def test_every_figure_script_documented():
    """Every benchmarks/fig*.py needs a 'how to read it' doc under
    docs/ (docs/figures.md or a more specific page)."""
    assert check_docs.check_figure_docs() == []


def test_roadmap_points_at_docs():
    """The stall-report prose moved out of ROADMAP.md; the pointer must
    survive future edits."""
    text = (REPO / "ROADMAP.md").read_text()
    assert "docs/attribution.md" in text
    assert "docs/backends.md" in text
