"""The unified `repro.core.api.simulate` entrypoint: plan resolution,
input normalization, and equivalence with the legacy paths (whose
deprecation shims are now gone for good)."""
import numpy as np
import pytest

from repro.core import api
from repro.core.batch_sim import BatchAraSimulator
from repro.core.isa import OptConfig
from repro.core.simulator import AraSimulator, SimParams
from repro.core.traces import axpy, scal, stack_traces

OPTS = (OptConfig.baseline(), OptConfig.full())


def test_simulate_matches_scalar():
    tr = scal(256)
    res = api.simulate(tr, OPTS, backend="numpy")
    sim = AraSimulator(params=SimParams())
    for oi, opt in enumerate(OPTS):
        assert res.cycles[0, oi, 0] == sim.run(tr, opt).cycles


def test_simulate_input_forms_agree():
    traces = [scal(128), axpy(128)]
    ref = api.simulate(traces, OPTS, backend="numpy")
    as_map = api.simulate({t.name: t for t in traces}, OPTS,
                          backend="numpy")
    as_stacked = api.simulate(stack_traces(traces), OPTS,
                              backend="numpy")
    np.testing.assert_array_equal(as_map.cycles, ref.cycles)
    np.testing.assert_array_equal(as_stacked.cycles, ref.cycles)


def test_simulate_p_chunk_passthrough():
    traces = [scal(128)]
    params = [SimParams(), SimParams(mem_latency=90.0),
              SimParams(issue_gap_base=5.0)]
    ref = api.simulate(traces, OPTS, params, backend="numpy")
    chunked = api.simulate(traces, OPTS, params, backend="numpy",
                           p_chunk=2)
    np.testing.assert_array_equal(chunked.cycles, ref.cycles)


def test_simulate_does_not_warn(recwarn):
    api.simulate(scal(64), OPTS, backend="numpy")
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


def test_run_and_sweep_shims_are_gone():
    """The one-PR deprecation grace period is over: the old entrypoints
    must not quietly resurface (api.simulate is the only public path)."""
    sim = BatchAraSimulator()
    assert not hasattr(sim, "run")
    assert not hasattr(sim, "sweep")


def test_resolve_plan_pins_explicit_choices():
    plan = api.resolve_plan(backend="jax", method="assoc",
                            width=1, n_instrs=1)
    assert (plan.backend, plan.method) == ("jax", "assoc")


def test_resolve_plan_auto_on_cpu(monkeypatch):
    """Without an accelerator, auto must stay on numpy/scan at any size
    (the measured BENCH_simulate.json numbers: numpy beats the compiled
    scan and the scan beats assoc on every CPU profile)."""
    monkeypatch.setattr(api, "jax_accelerator", lambda: False)
    plan = api.resolve_plan(width=10_000, n_instrs=100_000)
    assert (plan.backend, plan.method) == ("numpy", "scan")


def test_resolve_plan_auto_on_accelerator(monkeypatch):
    monkeypatch.setattr(api, "jax_accelerator", lambda: True)
    wide = api.resolve_plan(width=api.JAX_WIDTH_CROSSOVER,
                            n_instrs=api.ASSOC_INSTR_CROSSOVER)
    assert (wide.backend, wide.method) == ("jax", "assoc")
    narrow = api.resolve_plan(width=api.JAX_WIDTH_CROSSOVER - 1,
                              n_instrs=1)
    assert (narrow.backend, narrow.method) == ("numpy", "scan")
    short = api.resolve_plan(width=api.JAX_WIDTH_CROSSOVER,
                             n_instrs=api.ASSOC_INSTR_CROSSOVER - 1)
    assert (short.backend, short.method) == ("jax", "scan")


def test_execution_plan_validation():
    with pytest.raises(ValueError, match="backend"):
        api.ExecutionPlan(backend="cuda", method="scan")
    with pytest.raises(ValueError, match="method"):
        api.ExecutionPlan(backend="jax", method="magic")
    with pytest.raises(ValueError, match="assoc"):
        api.ExecutionPlan(backend="numpy", method="assoc")
    with pytest.raises(ValueError, match="bucket"):
        api.ExecutionPlan(backend="jax", method="scan", bucket="magic")
    with pytest.raises(ValueError, match="shard"):
        api.ExecutionPlan(backend="jax", method="scan", shard="magic")
    # P-axis sharding only exists on the compiled jax scan path.
    with pytest.raises(ValueError):
        api.ExecutionPlan(backend="numpy", method="scan",
                          shard="devices")
    with pytest.raises(ValueError):
        api.ExecutionPlan(backend="jax", method="assoc",
                          shard="devices")


def test_resolve_plan_auto_bucket_on_pad_waste():
    """bucket="auto" → pow2 only on jax, and only past the waste
    crossover; numpy never buckets (its loop already skips padding)."""
    wasteful = api.resolve_plan(backend="jax", method="scan",
                                pad_waste=0.9)
    assert wasteful.bucket == "pow2"
    tight = api.resolve_plan(backend="jax", method="scan",
                             pad_waste=0.01)
    assert tight.bucket == "none"
    on_numpy = api.resolve_plan(backend="numpy", method="scan",
                                pad_waste=0.9)
    assert on_numpy.bucket == "none"


def test_resolve_plan_auto_shard_needs_devices():
    """On a 1-device host auto sharding always resolves to none; with
    devices it needs jax+scan and at least one params column each."""
    plan = api.resolve_plan(backend="jax", method="scan", n_params=64)
    if api.local_device_count() > 1:
        assert plan.shard == "devices"
    else:
        assert plan.shard == "none"
    starved = api.resolve_plan(backend="jax", method="scan", n_params=0)
    assert starved.shard == "none"


def test_measured_crossovers_override(tmp_path, monkeypatch):
    """Non-null values in the recorded BENCH crossovers fold override
    the code constants; nulls fall back (the committed CPU entry)."""
    import json
    bench = tmp_path / "BENCH_simulate.json"
    bench.write_text(json.dumps({api._machine_key(): {"crossovers": {
        "jax_width": 7, "assoc_instrs": None}}}))
    monkeypatch.setattr(api, "_BENCH_PATH", bench)
    api._recorded_crossovers.cache_clear()
    try:
        cw = api.measured_crossovers()
        assert cw["jax_width"] == 7
        assert cw["assoc_instrs"] == api.ASSOC_INSTR_CROSSOVER
        assert cw["bucket_waste"] == api.BUCKET_WASTE_CROSSOVER
        monkeypatch.setattr(api, "jax_accelerator", lambda: True)
        plan = api.resolve_plan(width=7, n_instrs=1)
        assert plan.backend == "jax"
    finally:
        api._recorded_crossovers.cache_clear()


def test_measured_crossovers_default_to_constants(monkeypatch):
    monkeypatch.setattr(api, "_BENCH_PATH",
                        api._BENCH_PATH.with_name("absent.json"))
    api._recorded_crossovers.cache_clear()
    try:
        assert api.measured_crossovers() == {
            "jax_width": api.JAX_WIDTH_CROSSOVER,
            "assoc_instrs": api.ASSOC_INSTR_CROSSOVER,
            "bucket_waste": api.BUCKET_WASTE_CROSSOVER,
        }
    finally:
        api._recorded_crossovers.cache_clear()


def test_shared_sim_is_cached():
    from repro.core.isa import MachineConfig
    assert api._shared_sim(MachineConfig()) is \
        api._shared_sim(MachineConfig())


def test_resolve_backend_shim_delegates():
    from repro.launch.sensitivity import resolve_backend
    assert resolve_backend("numpy", width=1) == "numpy"
    assert resolve_backend("auto", width=1) == \
        api.resolve_plan(backend="auto", width=1).backend
