"""The unified `repro.core.api.simulate` entrypoint: plan resolution,
input normalization, and equivalence with the legacy paths (whose
deprecation shims are now gone for good)."""
import numpy as np
import pytest

from repro.core import api
from repro.core.batch_sim import BatchAraSimulator
from repro.core.isa import OptConfig
from repro.core.simulator import AraSimulator, SimParams
from repro.core.traces import axpy, scal, stack_traces

OPTS = (OptConfig.baseline(), OptConfig.full())


def test_simulate_matches_scalar():
    tr = scal(256)
    res = api.simulate(tr, OPTS, backend="numpy")
    sim = AraSimulator(params=SimParams())
    for oi, opt in enumerate(OPTS):
        assert res.cycles[0, oi, 0] == sim.run(tr, opt).cycles


def test_simulate_input_forms_agree():
    traces = [scal(128), axpy(128)]
    ref = api.simulate(traces, OPTS, backend="numpy")
    as_map = api.simulate({t.name: t for t in traces}, OPTS,
                          backend="numpy")
    as_stacked = api.simulate(stack_traces(traces), OPTS,
                              backend="numpy")
    np.testing.assert_array_equal(as_map.cycles, ref.cycles)
    np.testing.assert_array_equal(as_stacked.cycles, ref.cycles)


def test_simulate_p_chunk_passthrough():
    traces = [scal(128)]
    params = [SimParams(), SimParams(mem_latency=90.0),
              SimParams(issue_gap_base=5.0)]
    ref = api.simulate(traces, OPTS, params, backend="numpy")
    chunked = api.simulate(traces, OPTS, params, backend="numpy",
                           p_chunk=2)
    np.testing.assert_array_equal(chunked.cycles, ref.cycles)


def test_simulate_does_not_warn(recwarn):
    api.simulate(scal(64), OPTS, backend="numpy")
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


def test_run_and_sweep_shims_are_gone():
    """The one-PR deprecation grace period is over: the old entrypoints
    must not quietly resurface (api.simulate is the only public path)."""
    sim = BatchAraSimulator()
    assert not hasattr(sim, "run")
    assert not hasattr(sim, "sweep")


def test_resolve_plan_pins_explicit_choices():
    plan = api.resolve_plan(backend="jax", method="assoc",
                            width=1, n_instrs=1)
    assert (plan.backend, plan.method) == ("jax", "assoc")


def test_resolve_plan_auto_on_cpu(monkeypatch):
    """Without an accelerator, auto must stay on numpy/scan at any size
    (the measured BENCH_simulate.json numbers: numpy beats the compiled
    scan and the scan beats assoc on every CPU profile)."""
    monkeypatch.setattr(api, "jax_accelerator", lambda: False)
    plan = api.resolve_plan(width=10_000, n_instrs=100_000)
    assert (plan.backend, plan.method) == ("numpy", "scan")


def test_resolve_plan_auto_on_accelerator(monkeypatch):
    monkeypatch.setattr(api, "jax_accelerator", lambda: True)
    wide = api.resolve_plan(width=api.JAX_WIDTH_CROSSOVER,
                            n_instrs=api.ASSOC_INSTR_CROSSOVER)
    assert (wide.backend, wide.method) == ("jax", "assoc")
    narrow = api.resolve_plan(width=api.JAX_WIDTH_CROSSOVER - 1,
                              n_instrs=1)
    assert (narrow.backend, narrow.method) == ("numpy", "scan")
    short = api.resolve_plan(width=api.JAX_WIDTH_CROSSOVER,
                             n_instrs=api.ASSOC_INSTR_CROSSOVER - 1)
    assert (short.backend, short.method) == ("jax", "scan")


def test_execution_plan_validation():
    with pytest.raises(ValueError, match="backend"):
        api.ExecutionPlan(backend="cuda", method="scan")
    with pytest.raises(ValueError, match="method"):
        api.ExecutionPlan(backend="jax", method="magic")
    with pytest.raises(ValueError, match="assoc"):
        api.ExecutionPlan(backend="numpy", method="assoc")


def test_shared_sim_is_cached():
    from repro.core.isa import MachineConfig
    assert api._shared_sim(MachineConfig()) is \
        api._shared_sim(MachineConfig())


def test_resolve_backend_shim_delegates():
    from repro.launch.sensitivity import resolve_backend
    assert resolve_backend("numpy", width=1) == "numpy"
    assert resolve_backend("auto", width=1) == \
        api.resolve_plan(backend="auto", width=1).backend
