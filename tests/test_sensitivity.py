"""Sensitivity-subsystem contracts (repro.launch.sensitivity).

Load-bearing invariants:

  * chunked P-axis execution is bit-exact vs. the unchunked numpy run
    (chunks are independent grid columns);
  * the jax backend agrees with numpy (float64 allclose) on wide
    params axes, including through the chunk-padding path;
  * a knob with zero influence reports an elasticity of exactly 0.0;
  * tornado rankings are invariant under design/param reordering;
  * fig7 cells round-trip through the content-addressed sweep cache;
  * (property) perturbing one knob moves stall categories on its own
    critical path — whenever a traversal moves measured cycles, the
    knob's mapped path (or the ideal component) moves with it, and the
    exact decomposition invariant survives every perturbation.
"""
import dataclasses
import random

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import api
from repro.core.batch_sim import BatchAraSimulator, stack_params
from repro.core.isa import OptConfig
from repro.core.simulator import SimParams
from repro.core.stalls import check_invariant
from repro.core.traces import axpy, gemm, scal, spmv, stack_traces
from repro.launch import sensitivity as S
from repro.launch.sweep_cache import SweepCache

BASE, FULL = OptConfig.baseline(), OptConfig.full()


def _traces():
    return {"scal": scal(256), "axpy": axpy(256),
            "gemm": gemm(16, 16, 16), "spmv": spmv(8)}


def _stacked():
    return stack_traces(list(_traces().values()))


# -- stack_params / designs ----------------------------------------------

def test_stack_params_columns():
    plist = [SimParams(), SimParams(mem_latency=99.0, d_fwd=3.0)]
    cols = stack_params(plist)
    assert set(cols) == {f.name for f in dataclasses.fields(SimParams)}
    assert list(cols["mem_latency"]) == [38.0, 99.0]
    assert list(cols["d_fwd"]) == [2.0, 3.0]


def test_knob_paths_cover_every_simparams_field():
    assert set(S.KNOB_PATHS) == set(S.all_knobs())


def test_oat_design_shape_and_center():
    d = S.oat_design(SimParams(), knobs=("mem_latency", "d_fwd"),
                     points=3)
    assert d.width == 1 + 2 * 3
    assert d.variants[0] == SimParams()
    assert d.assignments[0] == {}
    assert len(d.indices_for("mem_latency")) == 3
    lo, hi = S.knob_bounds(SimParams(), "mem_latency")
    vals = [d.assignments[i]["mem_latency"]
            for i in d.indices_for("mem_latency")]
    assert min(vals) == lo and max(vals) == hi


def test_lhs_candidates_stratified_within_bounds():
    space = [("a", 0.0, 10.0), ("b", 5.0, 6.0)]
    cands = S.lhs_candidates(space, 8, random.Random(0))
    assert len(cands) == 8
    for name, lo, hi in space:
        vals = sorted(c[name] for c in cands)
        assert all(lo <= v <= hi for v in vals)
        # one sample per stratum per dimension
        strata = sorted(int(8 * (v - lo) / (hi - lo)) for v in vals)
        assert strata == list(range(8))


def test_lhs_design_jitters_locally():
    center = SimParams()
    d = S.lhs_design(center, n=6, span=1.25, seed=1)
    assert d.width == 7
    for over in d.assignments[1:]:
        for name, v in over.items():
            c = getattr(center, name)
            if c > 0:
                assert c / 1.25 - 1e-9 <= v <= c * 1.25 + 1e-9, name


# -- execution parity ----------------------------------------------------

def test_p_chunk_bitexact_vs_unchunked_numpy():
    d = S.oat_design(SimParams(),
                     knobs=("mem_latency", "issue_gap_base",
                            "d_chain_base"), points=3)
    st_ = _stacked()
    sim = BatchAraSimulator()
    full = api.simulate(st_, [BASE, FULL], list(d.variants),
                        backend="numpy", attribution=True, sim=sim)
    chunked = api.simulate(st_, [BASE, FULL], list(d.variants),
                           backend="numpy", attribution=True, p_chunk=4,
                           sim=sim)
    for field in ("cycles", "busy_fpu", "busy_bus", "ideal", "stalls",
                  "lane_first_out", "first_first_out", "finish_start"):
        assert np.array_equal(getattr(full, field),
                              getattr(chunked, field),
                              equal_nan=True), field


def test_p_chunk_validation():
    with pytest.raises(ValueError, match="p_chunk"):
        api.simulate(_stacked(), [BASE], [SimParams()],
                     backend="numpy", p_chunk=0)


def test_jax_matches_numpy_on_wide_params_axis():
    pytest.importorskip("jax")
    d = S.oat_design(SimParams(), knobs=("mem_latency", "d_fwd"),
                     points=4)                       # P = 9
    st_ = _stacked()
    sim = BatchAraSimulator()
    ref = api.simulate(st_, [BASE, FULL], list(d.variants),
                       backend="numpy", attribution=True, sim=sim)
    # p_chunk=4 exercises the jax padding path (9 = 4 + 4 + pad(1->4)),
    # with every chunk reusing one compiled shape.
    got = api.simulate(st_, [BASE, FULL], list(d.variants),
                       backend="jax", attribution=True, p_chunk=4,
                       sim=sim)
    np.testing.assert_allclose(got.cycles, ref.cycles, rtol=1e-9)
    np.testing.assert_allclose(got.ideal, ref.ideal, rtol=1e-9,
                               atol=1e-6)
    np.testing.assert_allclose(got.stalls, ref.stalls, rtol=1e-9,
                               atol=1e-6)


def test_resolve_backend():
    assert S.resolve_backend("numpy", 10_000) == "numpy"
    assert S.resolve_backend("jax", 1) == "jax"
    narrow = S.resolve_backend("auto", 2)
    assert narrow == "numpy"
    wide = S.resolve_backend("auto", S.JAX_WIDTH_THRESHOLD)
    # CPU-only hosts keep numpy regardless of width (docs/backends.md);
    # accelerator hosts switch to jax.
    assert wide == ("jax" if S.jax_accelerator() else "numpy")


# -- reductions ----------------------------------------------------------

def _sweep(design, traces=None, **kw):
    traces = traces if traces is not None else _traces()
    kw.setdefault("backend", "numpy")
    kw.setdefault("use_cache", False)
    return S.sweep_design(traces, design, **kw)


def test_elasticity_of_zero_influence_knob_is_exactly_zero():
    # No paper kernel in this set issues vfdiv, so div_factor cannot
    # move any cell: the elasticity must be *exactly* 0.0, not small.
    traces = {"scal": scal(256), "axpy": axpy(256)}
    d = S.oat_design(SimParams(), knobs=("div_factor",), points=3)
    rows = S.knob_rows(d, _sweep(d, traces))
    assert rows
    for r in rows:
        assert r["elast_base"] == 0.0
        assert r["elast_full"] == 0.0
        assert r["elast_speedup"] == 0.0
        assert r["swing_base"] == 0.0
        assert r["top_moved"] == "none"


def test_tornado_ordering_invariant_under_param_reordering():
    knobs = ("mem_latency", "rw_turnaround_base", "d_chain_base",
             "issue_gap_base")
    d_fwdo = S.oat_design(SimParams(), knobs=knobs, points=2)
    d_rev = S.oat_design(SimParams(), knobs=knobs[::-1], points=2)
    rows_f = S.knob_rows(d_fwdo, _sweep(d_fwdo))
    rows_r = S.knob_rows(d_rev, _sweep(d_rev))

    def ranking(rows):
        out = {}
        for r in rows:
            out.setdefault(r["kernel"], {})[r["tornado_rank"]] = r["knob"]
        return {k: [v[i] for i in sorted(v)] for k, v in out.items()}

    assert ranking(rows_f) == ranking(rows_r)


def test_pair_rows_surface_shape():
    d = S.pair_design(SimParams(), ("mem_latency", "issue_gap_base"),
                      points=3)
    rows = S.pair_rows(d, _sweep(d))
    assert len(rows) == len(_traces()) * 9
    assert {"kernel", "mem_latency", "issue_gap_base", "cycles_base",
            "cycles_full", "speedup", "gap_closed"} <= set(rows[0])


def test_lhs_rows_band_brackets_center():
    d = S.lhs_design(SimParams(), n=6, span=1.05, seed=2)
    rows = S.lhs_rows(d, _sweep(d))
    for r in rows:
        assert r["n"] == 6
        # A +-5% joint jitter keeps the band around the center point.
        assert r["speedup_min"] <= r["speedup_center"] * 1.10
        assert r["speedup_max"] >= r["speedup_center"] * 0.90


# -- cache round-trip ----------------------------------------------------

def test_fig7_cell_cache_roundtrip(tmp_path):
    cache = SweepCache(tmp_path)
    traces = {"scal": scal(256), "gemm": gemm(16, 16, 16)}
    d = S.oat_design(SimParams(), knobs=("mem_latency",), points=2)
    cells = S.run_grid(traces, d.variants, [BASE, FULL], cache=cache,
                       backend="numpy")
    n_cells = len(traces) * 2 * d.width
    assert len(cells) == n_cells
    assert cache.misses == n_cells and cache.hits == 0

    again = S.run_grid(traces, d.variants, [BASE, FULL], cache=cache,
                       backend="numpy")
    assert cache.hits == n_cells
    for key, res in cells.items():
        got = again[key]
        assert got.cycles == res.cycles
        assert got.ideal == res.ideal
        np.testing.assert_array_equal(got.stalls, res.stalls)
        assert got.phases == pytest.approx(res.phases)
    t1 = S.tensors_from_cells(cells, list(traces), [BASE.label,
                                                    FULL.label], d.width)
    t2 = S.tensors_from_cells(again, list(traces), [BASE.label,
                                                    FULL.label], d.width)
    assert np.array_equal(t1.cycles, t2.cycles)
    assert np.array_equal(t1.stalls, t2.stalls)


# -- locality property ---------------------------------------------------

#: Knobs whose perturbation the property test samples, with the
#: critical path `KNOB_PATHS` maps them to.  Only baseline-side knobs:
#: under the BASE config the `*_opt` values are structurally unused.
_PROP_KNOBS = ("mem_latency", "tx_ovh_base", "rw_turnaround_base",
               "store_commit_base", "issue_gap_base", "war_release_ovh",
               "d_chain_base", "conflict_base", "queue_adv_base")


@pytest.fixture(scope="module")
def prop_traces():
    return {"scal": scal(128), "axpy": axpy(128), "spmv": spmv(8)}


@given(knob=st.sampled_from(_PROP_KNOBS),
       scale=st.floats(min_value=1.05, max_value=1.6))
@settings(max_examples=12, deadline=None)
def test_perturbing_one_knob_moves_its_own_critical_path(
        prop_traces, knob, scale):
    """Perturbing one field only moves stall categories on its
    critical path: whenever the traversal moves measured cycles at
    all, the knob's mapped path (or the ideal component — forwarding
    floors and latency floors are ideal time) moves with it.  The
    binding-argument adoption can additionally shift *other* paths'
    attribution (a cell flipping from lane-bound to memory-bound), so
    the sound direction is cycles-change => own-path-change, plus the
    exact decomposition invariant on every perturbed cell.
    """
    center = SimParams()
    varied = dataclasses.replace(
        center, **{knob: getattr(center, knob) * scale})
    res = api.simulate(
        stack_traces(list(prop_traces.values())), [BASE],
        [center, varied], backend="numpy", attribution=True)
    t = S.SweepTensors(tuple(prop_traces), (BASE.label,), res.cycles,
                       res.ideal, res.stalls, None)
    deltas = S.path_stall_delta(t, 0, 1, opt_col=0)
    own_path = S.KNOB_PATHS[knob]
    for bi in range(res.cycles.shape[0]):
        for pi in range(2):
            assert check_invariant(res.ideal[bi, 0, pi],
                                   res.stalls[bi, 0, pi],
                                   res.cycles[bi, 0, pi])
        dcyc = res.cycles[bi, 0, 1] - res.cycles[bi, 0, 0]
        if abs(dcyc) > 1e-6:
            dideal = res.ideal[bi, 0, 1] - res.ideal[bi, 0, 0]
            assert (abs(deltas[own_path][bi]) > 1e-9
                    or abs(dideal) > 1e-9), (knob, bi, dcyc)


def test_opt_side_knobs_are_inert_under_baseline():
    """The strict form of locality: under the BASE config, perturbing
    any `*_opt` knob changes nothing at all — cycles and every stall
    component stay bit-identical."""
    center = SimParams()
    variants = [center] + [
        dataclasses.replace(center, **{k: getattr(center, k) * 1.5})
        for k in ("tx_ovh_opt", "rw_turnaround_opt", "issue_gap_opt",
                  "conflict_opt", "queue_adv_opt")]
    res = api.simulate(_stacked(), [BASE], variants,
                       backend="numpy", attribution=True)
    for pi in range(1, len(variants)):
        assert np.array_equal(res.cycles[:, :, pi], res.cycles[:, :, 0])
        assert np.array_equal(res.stalls[:, :, pi], res.stalls[:, :, 0])
        assert np.array_equal(res.ideal[:, :, pi], res.ideal[:, :, 0])


# -- Sobol / variance decomposition ---------------------------------------

def test_sobol_design_layout():
    space = [("mem_latency", 20.0, 60.0), ("issue_gap_base", 1.0, 6.0)]
    d = S.sobol_design(center=SimParams(), n=8, seed=0, space=space)
    # center + A + B + one AB block per knob.
    assert d.kind == "sobol"
    assert d.width == 1 + 8 * (len(space) + 2)
    assert d.assignments[0] == {}
    # AB_i == A with column i replaced from B, elementwise.
    a = d.assignments[1:9]
    b = d.assignments[9:17]
    ab0 = d.assignments[17:25]
    for ra, rb, rab in zip(a, b, ab0):
        assert rab["mem_latency"] == rb["mem_latency"]
        assert rab["issue_gap_base"] == ra["issue_gap_base"]


def test_sobol_zero_influence_knob_is_exactly_zero():
    """Opt-side knobs under the BASE corner are structurally unused, so
    their Sobol indices must be *exactly* 0.0 (the numpy backend is
    bit-exact: fAB_i == fA elementwise, so both estimators' numerators
    are exact zeros, not epsilon)."""
    space = [("mem_latency", 20.0, 60.0), ("tx_ovh_opt", 0.02, 1.0),
             ("queue_adv_opt", 24.0, 512.0)]
    d = S.sobol_design(center=SimParams(), n=8, seed=0, space=space)
    res = api.simulate(_stacked(), [BASE], list(d.variants),
                       backend="numpy", method="scan")
    for bi in range(res.cycles.shape[0]):
        idx = S.sobol_indices(d, res.cycles[bi, 0, :])
        for knob in ("tx_ovh_opt", "queue_adv_opt"):
            assert idx[knob] == {"Si": 0.0, "STi": 0.0,
                                 "interaction": 0.0}
        # ... while the baseline-side latency knob does carry variance.
        assert idx["mem_latency"]["STi"] > 0.0


def test_sobol_indices_bounded():
    """First-order indices decompose a share of variance: their sum
    stays in [0, 1] up to estimator noise, and no knob's first-order
    index exceeds its total-order index (tolerance for the small-n
    Saltelli/Jansen estimators)."""
    knobs = ("mem_latency", "issue_gap_base", "conflict_base",
             "store_commit_base")
    center = SimParams()
    space = [(k, *S.knob_bounds(center, k, 2.0)) for k in knobs]
    d = S.sobol_design(center=center, n=96, seed=1, space=space)
    res = api.simulate(_stacked(), [BASE], list(d.variants),
                       backend="numpy", method="scan")
    tol = 0.15
    for bi in range(res.cycles.shape[0]):
        idx = S.sobol_indices(d, res.cycles[bi, 0, :])
        total = sum(v["Si"] for v in idx.values())
        assert -tol <= total <= 1.0 + tol
        for v in idx.values():
            assert v["Si"] <= v["STi"] + tol
            assert v["interaction"] >= 0.0


def test_sobol_flat_output_yields_zero_indices():
    space = [("mem_latency", 20.0, 60.0)]
    d = S.sobol_design(center=SimParams(), n=4, seed=0, space=space)
    idx = S.sobol_indices(d, np.full(d.width, 7.0))
    assert idx["mem_latency"] == {"Si": 0.0, "STi": 0.0,
                                  "interaction": 0.0}


def test_sobol_top_knob_agrees_with_oat_elasticity():
    """The Sobol first-order ranking and PR 5's OAT elasticities agree
    on which knob dominates baseline cycles at the calibrated point
    (mem_latency, for the memory-bound scal)."""
    from repro.core.calibration import load
    center = load()
    knobs = ("mem_latency", "issue_gap_base", "conflict_base",
             "store_commit_base")
    traces = {"scal": scal(256), "axpy": axpy(256)}
    space = [(k, *S.knob_bounds(center, k, 2.0)) for k in knobs]
    d = S.sobol_design(center=center, n=16, seed=1, space=space)
    res = api.simulate(stack_traces(list(traces.values())), [BASE],
                       list(d.variants), backend="numpy", method="scan")
    idx = S.sobol_indices(d, res.cycles[0, 0, :])   # scal
    top_sobol = max(idx, key=lambda k: idx[k]["STi"])

    do = S.oat_design(center, knobs=knobs, points=3)
    rows = S.knob_rows(do, S.sweep_design(traces, do, backend="numpy",
                                          use_cache=False))
    scal_rows = [r for r in rows if r["kernel"] == "scal"]
    top_oat = max(scal_rows, key=lambda r: abs(r["elast_base"]))["knob"]
    assert top_sobol == top_oat == "mem_latency"


def test_sobol_rows_include_geomean_decomposition():
    knobs = ("mem_latency", "issue_gap_base")
    center = SimParams()
    space = [(k, *S.knob_bounds(center, k, 2.0)) for k in knobs]
    d = S.sobol_design(center=center, n=8, seed=0, space=space)
    t = _sweep(d)
    rows = S.sobol_rows(d, t)
    kernels = set(r["kernel"] for r in rows)
    assert "geomean" in kernels
    assert len(rows) == len(kernels) * len(knobs)
    for r in rows:
        assert {"si_base", "sti_base", "si_speedup", "sti_speedup",
                "interaction", "path"} <= set(r)


def test_co_move_pairs_deterministic_and_skips_zero_mass():
    # Only one knob carries interaction mass: no pair has positive
    # joint mass, so none is proposed.
    idx = {"a": {"Si": 0.1, "STi": 0.5, "interaction": 0.4},
           "b": {"Si": 0.2, "STi": 0.2, "interaction": 0.0},
           "c": {"Si": 0.0, "STi": 0.0, "interaction": 0.0}}
    assert S.co_move_pairs(idx) == []
    # Two massive knobs pair up, deterministically name-ordered.
    idx["b"]["interaction"] = 0.3
    pairs = S.co_move_pairs(idx, top=2)
    assert pairs == S.co_move_pairs(idx, top=2)
    assert ("a", "b") in pairs
    assert all(p[0] < p[1] for p in pairs)
