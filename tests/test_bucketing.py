"""Shape-bucketed execution (`repro.core.bucketing`) and the other
execution-planner axes: parity (the planner must be invisible in the
results), bucket-plan structure, the pipelined P axis, 1-device
sharding, cache-key independence, and the Pallas block-padding fix.

The contract under test everywhere: plan axes change *how* a grid
executes, never *what* it computes — numpy bucketed is bit-exact
(its per-row loop makes row subsets structurally identical), jax is
float64-allclose (XLA reassociation), and the sweep cache cannot tell
plans apart.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings

from repro.core import api, bucketing, calibration
from repro.core.isa import ABLATION_GRID, OptConfig
from repro.core.simulator import SimParams
from repro.core.traces import dotp, gemm, scal, stack_traces, symv
from trace_gen import build_trace, instr_tuples

from hypothesis_compat import st

ALL_CORNERS = (OptConfig.baseline(), *ABLATION_GRID)
BASE_FULL = (OptConfig.baseline(), OptConfig.full())

#: A deliberately mixed-length stack: 3..~1200 instructions, so the
#: pow2 plan forms several buckets and the unbucketed pad waste is huge.
MIXED = (scal(256), gemm(32, 32, 32), dotp(512), symv(16))


def _assert_results_equal(got, ref, exact: bool):
    """Every BatchResult field agrees (bit-exact or allclose)."""
    import dataclasses
    assert got.names == ref.names
    for f in dataclasses.fields(type(ref)):
        if f.name == "names":
            continue
        a, b = getattr(got, f.name), getattr(ref, f.name)
        if b is None:
            assert a is None, f.name
        elif exact:
            np.testing.assert_array_equal(a, b, err_msg=f.name)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-9,
                                       err_msg=f.name)


# -- bucket planning ------------------------------------------------------

def test_plan_buckets_structure():
    stacked = stack_traces(list(MIXED))
    buckets = bucketing.plan_buckets(stacked)
    n = stacked.n_instrs
    # Partition: every row exactly once, shortest cap first.
    rows = sorted(r for bk in buckets for r in bk.rows)
    assert rows == list(range(stacked.batch))
    caps = [bk.cap for bk in buckets]
    assert caps == sorted(caps)
    for bk in buckets:
        member_max = max(int(n[r]) for r in bk.rows)
        # Cap is the longest member; the pow2 edge bounds the spread.
        assert bk.cap == member_max
        assert all(bk.cap <= 2 * max(int(n[r]), 1) for r in bk.rows)
    # The longest bucket's cap is the stack's own padded length.
    assert caps[-1] == stacked.max_instrs


def test_plan_buckets_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        bucketing.plan_buckets(stack_traces([scal(64)]), policy="magic")


def test_pad_waste_share_drops():
    stacked = stack_traces(list(MIXED))
    before = bucketing.pad_waste_share(stacked)
    after = bucketing.pad_waste_share(stacked,
                                      bucketing.plan_buckets(stacked))
    assert before > 0.5          # the mixed stack is mostly padding
    assert after < 0.1           # bucketing kills it
    assert 0.0 <= after < before


def test_subset_rejects_cap_below_member():
    stacked = stack_traces(list(MIXED))
    with pytest.raises(ValueError):
        stacked.subset((1,), max_instrs=4)    # gemm needs ~1200


# -- numpy parity (bit-exact) --------------------------------------------

def test_numpy_bucketed_bit_exact_full_calibrated_grid():
    """Acceptance: the full calibrated parity grid, all 8 corners,
    bucketed vs unbucketed on numpy — every field bit-for-bit."""
    traces = list(calibration.parity_traces().values())
    params = calibration.load()
    ref = api.simulate(traces, ALL_CORNERS, params, backend="numpy",
                       bucket="none", shard="none", attribution=True)
    got = api.simulate(traces, ALL_CORNERS, params, backend="numpy",
                       bucket="pow2", shard="none", attribution=True)
    _assert_results_equal(got, ref, exact=True)


@given(raws=st.lists(instr_tuples(min_size=1, max_size=24),
                     min_size=1, max_size=4))
@settings(max_examples=25, deadline=None)
def test_property_bucketed_numpy_bit_exact(raws):
    """Random mixed-length traces: bucketing is invisible on numpy, and
    the attribution invariant `ideal + sum(stalls) == cycles` holds on
    the bucketed results themselves."""
    traces = [build_trace(raw) for raw in raws]
    ref = api.simulate(traces, BASE_FULL, backend="numpy",
                       bucket="none", shard="none", attribution=True)
    got = api.simulate(traces, BASE_FULL, backend="numpy",
                       bucket="pow2", shard="none", attribution=True)
    _assert_results_equal(got, ref, exact=True)
    np.testing.assert_allclose(got.ideal + got.stalls.sum(axis=-1),
                               got.cycles, rtol=1e-12)


@given(raws=st.lists(instr_tuples(min_size=1, max_size=12),
                     min_size=2, max_size=3))
@settings(max_examples=5, deadline=None)
def test_property_bucketed_jax_allclose(raws):
    """Random mixed-length traces through the compiled jax scan:
    bucketed must be float64-allclose to the unbucketed program,
    attribution tensors included (few examples: each fresh shape
    signature pays a jit compile)."""
    pytest.importorskip("jax")
    traces = [build_trace(raw) for raw in raws]
    ref = api.simulate(traces, BASE_FULL, backend="jax", method="scan",
                       bucket="none", shard="none", attribution=True)
    got = api.simulate(traces, BASE_FULL, backend="jax", method="scan",
                       bucket="pow2", shard="none", attribution=True)
    _assert_results_equal(got, ref, exact=False)


def test_single_trace_and_equal_lengths_degenerate():
    """Edge cases: one trace, and all-equal lengths, both collapse to a
    single bucket at the unbucketed shape — still bit-exact."""
    for traces in ([scal(256)], [scal(256), scal(256), scal(256)]):
        stacked = stack_traces(traces)
        buckets = bucketing.plan_buckets(stacked)
        assert len(buckets) == 1
        assert buckets[0].cap == stacked.max_instrs
        ref = api.simulate(stacked, BASE_FULL, backend="numpy",
                           bucket="none", shard="none")
        got = api.simulate(stacked, BASE_FULL, backend="numpy",
                           bucket="pow2", shard="none")
        _assert_results_equal(got, ref, exact=True)


# -- jax parity (allclose) -----------------------------------------------

jax = pytest.importorskip("jax")


@pytest.fixture(scope="module")
def cal_params():
    return calibration.load()


@pytest.fixture(scope="module")
def grid_traces():
    return list(calibration.parity_traces().values())


@pytest.fixture(scope="module")
def numpy_ref(grid_traces, cal_params):
    return api.simulate(grid_traces, ALL_CORNERS, cal_params,
                        backend="numpy", bucket="none", shard="none",
                        attribution=True)


def test_jax_scan_bucketed_full_calibrated_grid(grid_traces, cal_params,
                                                numpy_ref):
    got = api.simulate(grid_traces, ALL_CORNERS, cal_params,
                       backend="jax", method="scan", bucket="pow2",
                       shard="none", attribution=True)
    _assert_results_equal(got, numpy_ref, exact=False)


def test_jax_assoc_bucketed_full_calibrated_grid(grid_traces, cal_params,
                                                 numpy_ref):
    got = api.simulate(grid_traces, ALL_CORNERS, cal_params,
                       backend="jax", method="assoc", bucket="pow2",
                       shard="none", attribution=True)
    _assert_results_equal(got, numpy_ref, exact=False)


def test_pipelined_p_chunk_with_padded_tail():
    """P=3 with p_chunk=2: the async pipeline pads the last chunk (one
    phantom params column, sliced off at drain) — results must match
    the unchunked run and numpy exactly/allclose."""
    traces = [scal(128), dotp(256)]
    params = [SimParams(), SimParams(mem_latency=90.0),
              SimParams(issue_gap_base=5.0)]
    ref = api.simulate(traces, BASE_FULL, params, backend="numpy",
                       bucket="none", shard="none", attribution=True)
    got = api.simulate(traces, BASE_FULL, params, backend="jax",
                       method="scan", bucket="none", shard="none",
                       p_chunk=2, attribution=True)
    _assert_results_equal(got, ref, exact=False)


def test_shard_devices_parity_on_one_device():
    """`shard="devices"` on however many devices exist (1 in CI) must
    be exactly the unsharded program — graceful degradation."""
    traces = [scal(128), symv(16)]
    params = [SimParams(), SimParams(mem_latency=60.0)]
    ref = api.simulate(traces, BASE_FULL, params, backend="jax",
                       method="scan", bucket="none", shard="none")
    got = api.simulate(traces, BASE_FULL, params, backend="jax",
                       method="scan", bucket="none", shard="devices")
    _assert_results_equal(got, ref, exact=False)


def test_bucket_metrics_emitted():
    from repro.obs import metrics as obs_metrics
    api.simulate(list(MIXED), BASE_FULL, backend="numpy",
                 bucket="pow2", shard="none")
    stacked = stack_traces(list(MIXED))
    waste = obs_metrics.gauge("bucket.pad_waste_share").value
    base = obs_metrics.gauge("bucket.baseline_waste_share").value
    assert base == pytest.approx(bucketing.pad_waste_share(stacked))
    assert waste == pytest.approx(bucketing.pad_waste_share(
        stacked, bucketing.plan_buckets(stacked)))
    assert obs_metrics.counter("bucket.groups").value > 0


# -- the sweep cache cannot tell plans apart -----------------------------

def test_cache_keys_ignore_plan_axes(tmp_path):
    """A grid filled bucketed is fully served from cache unbucketed:
    cell keys carry no plan axes (satellite contract in
    `sweep_cache.cell_key`'s docstring)."""
    from repro.launch.sensitivity import run_grid
    from repro.launch.sweep_cache import SweepCache
    cache = SweepCache(tmp_path)
    traces = {"scal": scal(256), "gemm": gemm(16, 16, 16)}
    params = [SimParams(), SimParams(mem_latency=90.0)]
    cells = run_grid(traces, params, BASE_FULL, cache=cache,
                     backend="numpy", bucket="pow2", shard="none")
    n_cells = len(traces) * len(BASE_FULL) * len(params)
    assert cache.misses == n_cells and cache.hits == 0
    again = run_grid(traces, params, BASE_FULL, cache=cache,
                     backend="numpy", bucket="none", shard="none")
    assert cache.hits == n_cells
    for key, res in cells.items():
        assert again[key].cycles == res.cycles


# -- Pallas block padding (satellite: n % block != 0) --------------------

def test_pallas_tropical_identity_padding():
    """Regression: `_compose_pallas` used to zero-pad the batch up to a
    block multiple — zeros are NOT the tropical identity, so a padded
    row composed to finite garbage.  Identity rows must now compose to
    exact identities, and every batch size (especially n % block != 0)
    must match the jnp reference bit-for-bit."""
    import jax.numpy as jnp
    from repro.core.pallas_step import (_compose_jnp, _compose_pallas,
                                        _pick_block, _tropical_identity)
    D = 14
    rng = np.random.default_rng(7)
    for n in (1, 2, 5, 7, 8, 13):
        b = jnp.asarray(rng.normal(size=(n, D, D)) * 10)
        a = jnp.asarray(rng.normal(size=(n, D, D)) * 10)
        cr, kr = _compose_jnp(b, a)
        # Forced block=8 exercises the padded tail for every n != 8.
        cp, kp = _compose_pallas(b, a, block=8)
        np.testing.assert_array_equal(np.asarray(cp), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(kp), np.asarray(kr))
        ca, _ = _compose_pallas(b, a)          # auto block via _pick_block
        np.testing.assert_array_equal(np.asarray(ca), np.asarray(cr))
    # Identity (.) identity == identity, exactly.
    ident = _tropical_identity(3, D, jnp.asarray(0.0).dtype)
    c, _ = _compose_pallas(ident, ident, block=2)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(ident))
    # The auto block never exceeds the batch (no wasted pad compute).
    assert _pick_block(2, D) == 2
    assert _pick_block(0, D) == 1
    assert 1 <= _pick_block(10_000, 38) <= 64
