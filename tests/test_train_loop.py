"""Fault-tolerant training loop: learning, crash/restore equivalence,
straggler accounting."""
import dataclasses
import pathlib
import tempfile

import jax
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS, reduced
from repro.data.pipeline import SyntheticLM
from repro.models import init_model
from repro.train import optimizer as opt
from repro.train.loop import InjectedFailure, LoopConfig, run
from repro.train.step import StepConfig, init_state, make_train_step

CFG = dataclasses.replace(reduced(ARCHS["qwen2.5-3b"]), n_layers=2)


@pytest.fixture(scope="module")
def tstep():
    return jax.jit(make_train_step(CFG, StepConfig(
        microbatches=2, adamw=opt.AdamWConfig(lr=1e-3))),
        donate_argnums=(0,))


def _fresh():
    return (init_state(init_model(jax.random.PRNGKey(0), CFG)),
            SyntheticLM(CFG, batch=4, seq_len=32, seed=7))


def test_loss_decreases(tstep):
    with tempfile.TemporaryDirectory() as d:
        state, data = _fresh()
        res = run(tstep, state, data, CheckpointManager(d),
                  LoopConfig(total_steps=25, ckpt_every=10))
    losses = [h["loss"] for h in res.history]
    assert losses[-1] < losses[0] - 0.1
    assert res.straggler_steps <= len(losses)


def test_crash_resume_trajectory_equivalence(tstep):
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        # Uninterrupted reference.
        state, data = _fresh()
        ref = run(tstep, state, data, CheckpointManager(d1),
                  LoopConfig(total_steps=24, ckpt_every=8))
        # Crash at 13, auto-resume from the step-8 checkpoint.
        ck = CheckpointManager(d2, keep=3)
        state, data = _fresh()
        with pytest.raises(InjectedFailure):
            run(tstep, state, data, ck,
                LoopConfig(total_steps=24, ckpt_every=8, crash_at_step=13))
        state, data = _fresh()
        res = run(tstep, state, data, ck,
                  LoopConfig(total_steps=24, ckpt_every=8))
        assert res.resumed_from == 8
    l_ref = {h["step"]: h["loss"] for h in ref.history}
    l_res = {h["step"]: h["loss"] for h in res.history}
    for s in range(8, 24):
        assert abs(l_ref[s] - l_res[s]) < 1e-4, (s, l_ref[s], l_res[s])


def test_final_checkpoint_written(tstep):
    with tempfile.TemporaryDirectory() as d:
        state, data = _fresh()
        run(tstep, state, data, CheckpointManager(d),
            LoopConfig(total_steps=6, ckpt_every=100))
        assert CheckpointManager(d).latest_step() == 6
