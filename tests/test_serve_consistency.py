"""Serving correctness: prefill + step-by-step decode must reproduce the
full-forward logits exactly, for every cache mechanism in the zoo (linear
KV, ring-buffer window KV, MLA latent cache, SSD state, RG-LRU state)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import init_model, logits_fn
from repro.serve.engine import Engine

KEY = jax.random.PRNGKey(42)
S_P, N_DEC = 24, 6

FAMILIES = ["glm4-9b", "gemma3-27b", "deepseek-v2-236b",
            "recurrentgemma-2b", "mamba2-780m", "granite-moe-3b-a800m"]


@pytest.mark.parametrize("name", FAMILIES)
def test_decode_matches_forward(name):
    cfg = reduced(ARCHS[name])
    if cfg.n_experts:
        # Consistency requires drop-free routing (GShard capacity dropping
        # is data-dependent on token count and intentionally inexact).
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_model(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, S_P + N_DEC), 0, cfg.vocab_size)
    full_logits, _ = logits_fn(params, {"tokens": tokens}, cfg, mode="train")

    eng = Engine(params, cfg, s_max=64, cache_dtype=jnp.float32)
    logits, cache, pos = eng.prefill(tokens[:, :S_P])
    np.testing.assert_allclose(logits, full_logits[:, S_P - 1],
                               rtol=2e-4, atol=2e-4)
    for t in range(N_DEC - 1):
        logits, cache, pos = eng.step(cache, tokens[:, S_P + t], pos)
        np.testing.assert_allclose(logits, full_logits[:, S_P + t],
                                   rtol=2e-4, atol=2e-4)


def test_ring_buffer_wraps_correctly():
    """Decode past the sliding window: ring cache must drop the oldest
    positions, matching a full forward with window masking."""
    cfg = reduced(ARCHS["gemma3-27b"])          # window = 16 (reduced)
    cfg = dataclasses.replace(cfg, n_layers=6)
    params = init_model(KEY, cfg)
    total = 40                                   # > 2x window
    tokens = jax.random.randint(KEY, (1, total), 0, cfg.vocab_size)
    full_logits, _ = logits_fn(params, {"tokens": tokens}, cfg, mode="train")
    eng = Engine(params, cfg, s_max=64, cache_dtype=jnp.float32)
    logits, cache, pos = eng.prefill(tokens[:, :S_P])
    for t in range(total - S_P - 1):
        logits, cache, pos = eng.step(cache, tokens[:, S_P + t], pos)
        np.testing.assert_allclose(logits, full_logits[:, S_P + t],
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"step {t} (pos {S_P + t})")


def test_generate_greedy_deterministic():
    cfg = reduced(ARCHS["qwen2.5-3b"])
    params = init_model(KEY, cfg)
    eng = Engine(params, cfg, s_max=64, cache_dtype=jnp.float32)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    out1 = eng.generate(prompt, max_new=8)
    out2 = eng.generate(prompt, max_new=8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_generate_batch_independence():
    """Each batch row's generation must not depend on the other rows."""
    cfg = reduced(ARCHS["qwen2.5-3b"])
    params = init_model(KEY, cfg)
    eng = Engine(params, cfg, s_max=64, cache_dtype=jnp.float32)
    p1 = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    p2 = jax.random.randint(jax.random.fold_in(KEY, 9), (1, 8), 0,
                            cfg.vocab_size)
    both = jnp.concatenate([p1, p2], axis=0)
    out_b = eng.generate(both, max_new=6)
    out_1 = eng.generate(p1, max_new=6)
    np.testing.assert_array_equal(np.asarray(out_b[0]), np.asarray(out_1[0]))
