"""Distribution correctness: logical rules, spec safety, and multi-device
semantics (subprocess with 8 forced host devices — the in-process test
session must keep exactly 1 device)."""
import json
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from conftest import REPO, subprocess_env
from repro.distributed.sharding import (DEFAULT_RULES, logical_axes_for,
                                        resolve_spec, safe_spec, use_mesh)


def test_logical_axes_inference():
    assert logical_axes_for("embedding/embed_table", 2) == ("vocab", "embed")
    assert logical_axes_for("scan/0/mixer/wq", 4)[-3:] == \
        ("embed", "heads", "head_dim")
    assert logical_axes_for("lead/0/ffn/experts/w_in", 3) == \
        ("expert", "embed", "ff")
    assert logical_axes_for("scan/1/norm1/scale", 1) == (None,)
    assert logical_axes_for("head/lm_head", 2) == ("embed", "vocab")


def test_resolve_spec_without_mesh_is_empty():
    assert resolve_spec(("batch", "seq", None)) == P(None, None, None)


def test_safe_spec_divisibility():
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 8)
    fm = FakeMesh()
    # kv_heads=2 over model=8: dropped; heads=16 over 8: kept.
    s = safe_spec((32, 2, 16), P(None, "model", None), fm)
    assert s == P(None, None, None)
    s = safe_spec((32, 16, 64), P("data", "model", None), fm)
    assert s == P("data", "model", None)
    # 36 heads over 8: not divisible -> dropped.
    s = safe_spec((36,), P("model"), fm)
    assert s == P(None)


def test_duplicate_mesh_axis_suppressed():
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 8)
    with use_mesh(jax.make_mesh((1, 1), ("data", "model"))):
        spec = resolve_spec(("embed", "embed"))
        assert tuple(spec).count("data") <= 1


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np, json

    results = {{}}

    # 1. context-parallel decode == reference
    from repro.distributed.context_parallel import cp_decode_attention
    from repro.kernels import ref
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (2, 8, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    kvlen = jnp.array([50, 64])
    out = cp_decode_attention(q, k, v, kvlen, mesh=mesh, axis="data")
    kr = jnp.repeat(k, 4, axis=2); vr = jnp.repeat(v, 4, axis=2)
    exp = ref.decode_attention_ref(q, kr, vr, kvlen)
    results["cp_err"] = float(jnp.max(jnp.abs(out - exp)))

    # 2. compressed psum ~= exact mean over the axis
    from repro.distributed.compression import compressed_psum
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    x = jax.random.normal(ks[3], (8, 500))
    f = shard_map(lambda xs: compressed_psum(xs, "data")[0], mesh=mesh,
                  in_specs=P("data", None), out_specs=P("data", None),
                  check_rep=False)
    got = f(x)
    exp2 = jnp.broadcast_to(x.reshape(4, 2, 500).mean(0, keepdims=True),
                            (4, 2, 500)).reshape(8, 500)
    results["psum_rel_err"] = float(
        jnp.max(jnp.abs(got - exp2)) / jnp.max(jnp.abs(exp2)))

    # 3. sharded train step == single-device train step (tiny model)
    from repro.configs import ARCHS, reduced
    from repro.models import init_model
    from repro.train.step import make_train_step, init_state, StepConfig
    from repro.distributed.sharding import use_mesh, param_specs, \\
        named_shardings
    from repro.models.multimodal import make_batch
    import dataclasses
    cfg = dataclasses.replace(reduced(ARCHS["qwen2.5-3b"]), n_layers=2)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=16)
    step = make_train_step(cfg, StepConfig())
    state = init_state(params)
    _, m_plain = jax.jit(step)(state, batch)

    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    with use_mesh(mesh2):
        p_sh = named_shardings(param_specs(params), mesh2)
        from jax.sharding import NamedSharding
        state_sh = jax.device_put(state, jax.tree.map(
            lambda s: s, jax.tree_util.tree_map(
                lambda x: NamedSharding(mesh2, P()), state)))
        # place params with their real shardings
        placed_params = jax.tree.map(jax.device_put, state.params, p_sh)
        state2 = state._replace(params=placed_params)
        _, m_shard = jax.jit(step)(state2, batch)
    results["loss_plain"] = float(m_plain["loss"])
    results["loss_shard"] = float(m_shard["loss"])
    print("RESULTS:" + json.dumps(results))
""")


@pytest.mark.slow
def test_multidevice_semantics():
    script = SUBPROCESS_SCRIPT.format(src=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600,
                          env=subprocess_env())
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")]
    assert line, proc.stdout
    res = json.loads(line[0][len("RESULTS:"):])
    assert res["cp_err"] < 5e-4
    assert res["psum_rel_err"] < 0.02
    assert abs(res["loss_plain"] - res["loss_shard"]) < 1e-3
