import os
import sys
import pathlib

# Tests must see exactly ONE device (the dry-run forces 512 only inside its
# own subprocesses, per the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

REPO = pathlib.Path(__file__).resolve().parents[1]
SRC = str(REPO / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def subprocess_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return env
