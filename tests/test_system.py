"""End-to-end behaviour tests for the paper's system.

The individual subsystems are covered by their own modules; this file pins
the cross-cutting claims: the paper pipeline (traces -> simulator ->
roofline -> gap-closed) runs end to end, and the TPU framework's public API
composes (config -> model -> train -> serve) for the paper's exemplar
workload chain.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import (AraSimulator, OptConfig, gap_closed, geomean,
                        normalized)
from repro.core.calibration import load as load_params
from repro.core.traces import DEFAULT_TRACES
from repro.kernels import ops, ref
from repro.models import init_model
from repro.serve.engine import Engine
from repro.train import optimizer as opt
from repro.train.step import StepConfig, init_state, make_train_step
from repro.models.multimodal import make_batch


def test_paper_pipeline_end_to_end():
    """Fig. 3 + Fig. 4 pipeline: simulate all kernels, normalize to the
    roofline, geomean speedup in the paper's ballpark."""
    sim = AraSimulator(params=load_params())
    speedups, gaps = [], []
    for name, fn in DEFAULT_TRACES.items():
        tr = fn()
        base = sim.run(tr, OptConfig.baseline())
        full = sim.run(tr, OptConfig.full())
        speedups.append(base.cycles / full.cycles)
        gaps.append(gap_closed(base.gflops, full.gflops,
                               tr.operational_intensity))
        assert normalized(full.gflops, tr.operational_intensity) <= 1.02
    gm = geomean(speedups)
    assert 1.1 < gm < 1.6          # paper: 1.33
    assert all(g > -0.05 for g in gaps)


def test_fig1_chain_on_tpu_kernels():
    """The paper's Fig. 1 exemplar chain (vle -> vfmul -> vfadd -> vse) as
    the fused streamer kernel, validated against the oracle and against
    the unfused (write-back/reread) path."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x, y, w = (jax.random.normal(k, (1 << 14,)) for k in ks)
    fused = ops.fused_chain(x, y, w)
    unfused = ops.unfused_chain(x, y, w)
    np.testing.assert_allclose(fused, ref.chain_ref(x, y, w), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(fused, unfused, rtol=1e-5, atol=1e-5)


def test_framework_train_then_serve():
    """Public API composition: config -> init -> a few train steps ->
    serve the trained params; sampled tokens must be valid vocab ids."""
    cfg = dataclasses.replace(reduced(ARCHS["glm4-9b"]), n_layers=2)
    params = init_model(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, StepConfig(
        adamw=opt.AdamWConfig(lr=1e-3))))
    state = init_state(params)
    batch = make_batch(jax.random.PRNGKey(1), cfg, batch=2, seq=32)
    for _ in range(3):
        state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))

    eng = Engine(state.params, cfg, s_max=64, cache_dtype=jnp.float32)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                cfg.vocab_size)
    out = eng.generate(prompt, max_new=8)
    assert out.shape == (2, 8)
    assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab_size
