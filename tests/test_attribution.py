"""Deviation-attribution engine: exact stall accounting + analysis layer.

The load-bearing contract: for every kernel and every ablation cell,
``ideal + sum(stall_categories) == simulated_cycles`` — per instruction
and per kernel, scalar and batched — and the decomposition reproduces the
paper's §IV narrative (scal/axpy lose to memory-side supply at baseline,
gemm to operand delivery).
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.analysis import attribution as A
from repro.analysis import report as R
from repro.analysis import timeline as TL
from repro.core import api
from repro.core import stalls as S
from repro.core.calibration import load as load_params
from repro.core.isa import (ABLATION_GRID, KernelTrace, OpKind, OptConfig,
                            Stride, VInstr)
from repro.core.simulator import AraSimulator, SimParams
from repro.core.traces import DEFAULT_TRACES, stack_traces

ALL_CORNERS = (OptConfig.baseline(), *ABLATION_GRID)
#: Small traces where the per-instruction invariant is checked exhaustively
#: (kernel-level invariants are checked for every kernel/corner).
SMALL = ("scal", "axpy", "dotp", "gemv", "symv", "trsm", "spmv", "dwt")


def _inv_ok(ideal, stalls, measured):
    return S.check_invariant(ideal, stalls, measured,
                             rel=1e-9, abs_tol=1e-6)


@pytest.fixture(scope="module")
def params():
    return load_params()


@pytest.fixture(scope="module")
def traces():
    return {name: fn() for name, fn in DEFAULT_TRACES.items()}


@pytest.fixture(scope="module")
def corner_results(traces, params):
    sim = AraSimulator(params=params)
    return {(name, opt.label): sim.run(tr, opt)
            for name, tr in traces.items() for opt in ALL_CORNERS}


def test_kernel_invariant_every_cell(traces, corner_results):
    """Acceptance: ideal + sum(stalls) == cycles for every kernel x corner,
    with non-negative components."""
    for (name, label), res in corner_results.items():
        assert res.stalls is not None and res.stalls.shape == (9,)
        assert _inv_ok(res.ideal, res.stalls, res.cycles), (name, label)
        assert res.ideal >= -1e-9, (name, label)
        assert res.stalls.min() >= -1e-9, (name, label, res.stalls)


def test_instruction_invariant(traces, corner_results):
    for name in SMALL:
        for opt in ALL_CORNERS:
            res = corner_results[(name, opt.label)]
            for i, t in enumerate(res.timings):
                assert t.stalls is not None
                assert _inv_ok(t.ideal, t.stalls, t.complete), \
                    (name, opt.label, i)
                assert t.ideal >= -1e-9
                assert t.stalls.min() >= -1e-9


def test_batched_attribution_matches_scalar(traces, corner_results):
    batch = api.simulate(list(traces.values()), ALL_CORNERS,
                         load_params(), backend="numpy",
                         attribution=True)
    for bi, name in enumerate(traces):
        for oi, opt in enumerate(ALL_CORNERS):
            ref = corner_results[(name, opt.label)]
            np.testing.assert_allclose(batch.ideal[bi, oi, 0], ref.ideal,
                                       rtol=1e-12, atol=1e-9,
                                       err_msg=f"{name}/{opt.label}")
            np.testing.assert_allclose(batch.stalls[bi, oi, 0], ref.stalls,
                                       rtol=1e-12, atol=1e-9,
                                       err_msg=f"{name}/{opt.label}")
    # Batched tensors satisfy the invariant themselves (float64 tolerance).
    gap = batch.cycles - batch.ideal - batch.stalls.sum(axis=-1)
    assert np.abs(gap).max() <= 1e-6 + 1e-9 * batch.cycles.max()


def test_scalar_attribution_off_identical_cycles(traces, corner_results,
                                                 params):
    """attribution=False must change nothing but the bookkeeping."""
    fast = AraSimulator(params=params, attribution=False)
    for name in ("scal", "axpy", "dotp", "spmv"):
        for opt in ALL_CORNERS:
            ref = corner_results[(name, opt.label)]
            got = fast.run(traces[name], opt)
            assert got.cycles == ref.cycles, (name, opt.label)
            assert got.stalls is None and got.ideal == 0.0
            assert all(t.stalls is None for t in got.timings)
            for tg, tr_ in zip(got.timings, ref.timings):
                assert (tg.start, tg.first_out, tg.complete, tg.read_done) \
                    == (tr_.start, tr_.first_out, tr_.complete,
                        tr_.read_done)


def test_jax_backend_attribution_no_longer_raises(traces):
    """Regression: through PR 2 `attribution=True, backend='jax'` raised
    NotImplementedError; the compiled scan now carries the components."""
    res = api.simulate([traces["scal"]], [OptConfig.baseline()],
                       backend="jax", attribution=True)
    assert res.ideal is not None and res.stalls is not None
    assert res.stalls.shape == (1, 1, 1, 9)
    gap = res.cycles - res.ideal - res.stalls.sum(axis=-1)
    assert np.abs(gap).max() <= 1e-6 + 1e-9 * res.cycles.max()


def test_jax_attribution_full_grid_matches_numpy(traces):
    """Acceptance: on the full 11-kernel x 8-corner grid, the jax
    backend's stall tensors satisfy ``ideal + sum(stalls) == cycles``
    and match the numpy backend at float64 (allclose)."""
    st = stack_traces(list(traces.values()))
    params = load_params()
    ref = api.simulate(st, ALL_CORNERS, params, backend="numpy",
                       attribution=True)
    got = api.simulate(st, ALL_CORNERS, params, backend="jax",
                       attribution=True)
    np.testing.assert_allclose(got.cycles, ref.cycles, rtol=1e-9)
    np.testing.assert_allclose(got.ideal, ref.ideal, rtol=1e-9,
                               atol=1e-6)
    np.testing.assert_allclose(got.stalls, ref.stalls, rtol=1e-9,
                               atol=1e-6)
    # Phase observables ride along on both backends.
    np.testing.assert_allclose(got.lane_first_out, ref.lane_first_out,
                               rtol=1e-9, atol=1e-6)
    np.testing.assert_allclose(got.first_first_out, ref.first_first_out,
                               rtol=1e-9, atol=1e-6)
    np.testing.assert_allclose(got.finish_start, ref.finish_start,
                               rtol=1e-9, atol=1e-6)
    gap = got.cycles - got.ideal - got.stalls.sum(axis=-1)
    assert np.abs(gap).max() <= 1e-6 + 1e-9 * got.cycles.max()


# --- paper §IV narrative ---------------------------------------------------

def test_scal_axpy_mem_supply_dominates_baseline(corner_results):
    """scal/axpy at baseline lose primarily to the memory-side supply
    path (store-coupled r/w path, commit latency, tx overhead)."""
    for name in ("scal", "axpy"):
        res = corner_results[(name, "base")]
        paths = S.group_stalls(res.stalls)
        assert paths["mem_supply"] > paths["dep_issue"], (name, paths)
        assert paths["mem_supply"] > paths["operand"], (name, paths)
        assert paths["mem_supply"] > 0.1 * res.cycles, (name, paths)


def test_gemm_operand_delivery_in_top2(corner_results):
    """gemm at baseline: operand delivery (VRF bank conflict, chain delay)
    is among the top-2 critical paths (§VI.C: 14% conflict stretch)."""
    res = corner_results[("gemm", "base")]
    top = [path for path, _ in S.top_paths(res.stalls, 2)]
    assert "operand" in top, top
    cats = [c for c, _ in S.top_sources(res.stalls, 2)]
    assert "opr_bank_conflict" in cats, cats


def test_full_opt_shrinks_total_stall(corner_results):
    for name in DEFAULT_TRACES:
        base = corner_results[(name, "base")]
        full = corner_results[(name, "M+C+O")]
        assert full.stalls.sum() <= base.stalls.sum() + 1e-6, name


def test_gap_closed_by_path(corner_results):
    """Full opt closes most of scal/axpy's baseline mem-supply stall."""
    for name in ("scal", "axpy"):
        base = corner_results[(name, "base")]
        full = corner_results[(name, "M+C+O")]
        gc = A.gap_closed_by_path(base, full)
        assert set(gc) == set(S.CRITICAL_PATHS)
        assert gc["mem_supply"] > 0.5, (name, gc)
        assert all(v <= 1.0 + 1e-9 for v in gc.values())


# --- phase decomposition vs core.chaining ---------------------------------

def test_phase_decomposition_exact(traces, corner_results, params):
    """Eq. (4) reconstruction: the deviation triple reproduces measured
    cycles exactly, and Eq. (5)'s dT equals measured minus ideal."""
    for name in ("scal", "axpy", "dotp", "gemm"):
        for label in ("base", "M+C+O"):
            res = corner_results[(name, label)]
            ph = A.phase_decompose(traces[name], res, params=params)
            assert ph.deviation.t_real(ph.spec) == \
                pytest.approx(res.cycles, rel=1e-9)
            assert ph.loss == pytest.approx(res.cycles - ph.spec.t_ideal,
                                            rel=1e-9, abs=1e-6)
            assert ph.prologue_real >= 0 and ph.tail_real >= 0
            assert ph.steady_real >= -1e-9


def test_phase_deviation_shrinks_with_full_opt(traces, corner_results,
                                               params):
    """Ara-Opt moves II_eff toward 1 for the streaming kernels."""
    for name in ("scal", "axpy", "ger"):
        base = A.phase_decompose(traces[name],
                                 corner_results[(name, "base")],
                                 params=params)
        full = A.phase_decompose(traces[name],
                                 corner_results[(name, "M+C+O")],
                                 params=params)
        assert full.deviation.ii_eff < base.deviation.ii_eff, name


def test_attribute_kernel_bundle(traces, params):
    ka = A.attribute_kernel(traces["scal"], OptConfig.baseline(),
                            params=params)
    assert ka.kernel == "scal" and ka.opt_label == "base"
    assert set(ka.paths) == set(S.CRITICAL_PATHS)
    assert set(ka.stalls) == set(S.STALL_CATEGORIES)
    assert sum(ka.stalls.values()) == pytest.approx(
        ka.result.cycles - ka.result.ideal, rel=1e-9)
    assert len(ka.top2) == 2


# --- report + timeline -----------------------------------------------------

def test_report_rows_and_text(corner_results, tmp_path):
    base = {name: corner_results[(name, "base")] for name in DEFAULT_TRACES}
    rows = R.breakdown_rows(base, config="base")
    assert len(rows) == len(DEFAULT_TRACES)
    for row in rows:
        stall_sum = sum(row[c] for c in S.STALL_CATEGORIES)
        assert row["ideal"] + stall_sum == pytest.approx(row["cycles"],
                                                         rel=1e-9)
        assert row["mem_supply"] + row["dep_issue"] + row["operand"] == \
            pytest.approx(stall_sum, rel=1e-9, abs=1e-9)
        assert 0.0 <= row["stall_frac"] <= 1.0
    text = R.format_report(rows)
    assert "scal" in text and "mem_supply" in text
    path = R.write_csv(rows, tmp_path / "breakdown.csv")
    lines = path.read_text().strip().split("\n")
    assert len(lines) == len(rows) + 1
    assert lines[0].startswith("kernel,config,cycles,ideal")


def test_timeline_chrome_trace(traces, params, tmp_path):
    tr = traces["scal"]
    res = AraSimulator(params=params).run(tr, OptConfig.baseline())
    path = TL.export_chrome_trace(tmp_path / "t.json", tr, res)
    import json
    payload = json.loads(path.read_text())
    events = payload["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == len(tr.instrs)
    for e in xs:
        assert e["dur"] >= 0.0 and e["ts"] >= 0.0
        assert "ideal" in e["args"]
    names = {e["args"]["name"] for e in events if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert {"VLSU read", "VLSU write", "FPU lanes"} <= names
    assert payload["metadata"]["cycles"] == res.cycles


def test_timeline_rejects_cached_results(traces, params):
    from repro.core.simulator import SimResult
    hollow = SimResult(kernel="scal", cycles=1.0, flops=1, bytes=1,
                       timings=[])
    with pytest.raises(ValueError):
        TL.trace_events(traces["scal"], hollow)


# --- property test: random traces ------------------------------------------
# (generator shared with test_assoc.py's parity property test)

from trace_gen import build_trace as _build_trace  # noqa: E402
from trace_gen import instr_tuples as _instr_tuples_fn  # noqa: E402

_instr_tuples = _instr_tuples_fn()


@given(raw=_instr_tuples)
@settings(max_examples=40, deadline=None)
def test_property_invariant_random_traces(raw):
    """Stall categories sum exactly to measured-minus-ideal cycles on
    arbitrary traces, per instruction and per kernel, and the batched
    accounting agrees with the scalar path bit-for-bit."""
    tr = _build_trace(raw)
    corners = (OptConfig.baseline(), OptConfig.full(),
               OptConfig(True, False, True))
    sim = AraSimulator(params=SimParams())
    refs = [sim.run(tr, opt) for opt in corners]
    for res in refs:
        assert _inv_ok(res.ideal, res.stalls, res.cycles)
        assert res.stalls.min() >= -1e-9 and res.ideal >= -1e-9
        for t in res.timings:
            assert _inv_ok(t.ideal, t.stalls, t.complete)
            assert t.stalls.min() >= -1e-9 and t.ideal >= -1e-9
    batch = api.simulate(stack_traces([tr]), corners, backend="numpy",
                         attribution=True)
    for oi, res in enumerate(refs):
        assert batch.cycles[0, oi, 0] == res.cycles
        np.testing.assert_array_equal(batch.ideal[0, oi, 0], res.ideal)
        np.testing.assert_array_equal(batch.stalls[0, oi, 0], res.stalls)
