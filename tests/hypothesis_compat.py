"""Optional-hypothesis shim for the test suite.

The `[test]` extra installs hypothesis, but tier-1 must also pass in bare
environments (the container image carries only runtime deps).  Importing
`given`/`settings`/`st` from here keeps every non-property test collectable
and runnable; property tests are skipped when hypothesis is missing.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[test])"
            )(fn)
        return deco

    def settings(*args, **kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stands in for `strategies`: any strategy call returns None,
        which is fine because the test body never runs when skipped."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
