"""Per-class falsification checks: the paper's §IV narrative, asserted
on *generated* workloads instead of the 11 cherry-picked kernels.

The narrative under test (paper §IV, docs/workloads.md):

* streaming-shaped classes (``streaming``, ``strided``, ``gather``,
  ``fuzz``) lose their baseline cycles primarily to the memory-side
  supply path;
* chaining-pathology classes (``raw_chain``, ``queue_pressure``,
  ``compute_tile``) lose primarily to the dependence side (dep_issue +
  operand paths).

Checks run against the committed golden attributions (baseline corner,
default `SimParams`), which `tests/test_corpus.py` holds bit-exact — so
these are assertions about the *model*, not about simulator drift.

Where the narrative genuinely breaks, the break is committed as a
strict xfail with the mechanism documented inline (and in
docs/workloads.md): slide storms were designed as a chaining pathology
but stay memory-dominated, and reduction's per-scenario dominance flips
for short-accumulation shapes.
"""
import pathlib
import sys

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))

from repro.core import stalls as S  # noqa: E402
from repro.data import corpus  # noqa: E402

#: Classes whose baseline loss must be memory-path-dominated.
STREAMING_CLASSES = ("streaming", "strided", "gather", "fuzz")
#: Classes whose baseline loss must be dependence-side-dominated
#: (dep_issue + operand together beat mem_supply).
CHAINING_CLASSES = ("raw_chain", "queue_pressure", "compute_tile")


@pytest.fixture(scope="module")
def classes():
    return corpus.by_class(corpus.load_scenarios())


def _base_paths(scenario) -> dict[str, float]:
    return S.group_stalls(np.asarray(scenario.expected["base"]["stalls"],
                                     np.float64))


def _agg_paths(rows) -> dict[str, float]:
    agg = np.zeros(len(S.STALL_CATEGORIES))
    for s in rows:
        agg += np.asarray(s.expected["base"]["stalls"], np.float64)
    return S.group_stalls(agg)


@pytest.mark.parametrize("cls", STREAMING_CLASSES)
def test_streaming_classes_memory_dominated(classes, cls):
    """Every streaming-class scenario (not just the aggregate) loses
    most to mem_supply at baseline."""
    rows = classes[cls]
    assert rows, cls
    for s in rows:
        paths = _base_paths(s)
        assert paths["mem_supply"] > paths["dep_issue"], (s.name, paths)
        assert paths["mem_supply"] > paths["operand"], (s.name, paths)
    agg = _agg_paths(rows)
    assert agg["mem_supply"] > 0.5 * sum(agg.values()), (cls, agg)


@pytest.mark.parametrize("cls", CHAINING_CLASSES)
def test_chaining_classes_dependence_dominated(classes, cls):
    """Every chaining-pathology scenario loses most of its baseline
    cycles on the dependence side (issue + operand paths combined)."""
    rows = classes[cls]
    assert rows, cls
    for s in rows:
        paths = _base_paths(s)
        dep_side = paths["dep_issue"] + paths["operand"]
        assert dep_side > paths["mem_supply"], (s.name, paths)
    agg = _agg_paths(rows)
    assert agg["operand"] == max(agg.values()), (cls, agg)


def test_mixed_vl_majority_memory_dominated(classes):
    """Mixed-VL streams stay memory-shaped in the large majority of
    scenarios (VL jitter shrinks strips but not the byte/flop mix)."""
    rows = classes["mixed_vl"]
    dominated = sum(1 for s in rows
                    if max((p := _base_paths(s)), key=p.get)
                    == "mem_supply")
    assert dominated >= 0.8 * len(rows), (dominated, len(rows))


def test_reduction_aggregate_operand_dominated(classes):
    """In aggregate, reduction scenarios bind on operand delivery (the
    accumulator RAW chain runs through the VRF round trip)."""
    agg = _agg_paths(classes["reduction"])
    assert agg["operand"] == max(agg.values()), agg


# --- documented narrative breaks (strict xfail: if one starts passing,
# --- the breakage documentation in docs/workloads.md must be updated) ------

@pytest.mark.xfail(
    strict=True,
    reason="NARRATIVE BREAK (documented in docs/workloads.md): "
           "slide_storm was designed as a chaining pathology — slides "
           "serialize in the SLDU and feed RAW chains — but at baseline "
           "every committed scenario still loses more to mem_supply: "
           "slides carry no memory traffic, so the store stream's "
           "r/w-turnaround and commit costs dwarf the slide chain delay "
           "at these VLs.")
def test_slide_storm_dependence_dominated(classes):
    for s in classes["slide_storm"]:
        paths = _base_paths(s)
        dep_side = paths["dep_issue"] + paths["operand"]
        assert dep_side > paths["mem_supply"], (s.name, paths)


@pytest.mark.xfail(
    strict=True,
    reason="NARRATIVE BREAK (documented in docs/workloads.md): "
           "reduction is operand-dominated in aggregate but NOT per "
           "scenario — short-accumulation shapes (small n, frequent "
           "vfredsum) flip to mem_supply because the reduce tail "
           "serializes behind first-strip demand misses.")
def test_reduction_every_scenario_operand_dominated(classes):
    for s in classes["reduction"]:
        paths = _base_paths(s)
        assert max(paths, key=paths.get) == "operand", (s.name, paths)
