"""Unit + property tests for the ideal multi-lane chaining model (Eq. 1-5)."""
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.chaining import (ChainSpec, Deviation, IDEAL, attribute,
                                 ii_eff_from_rates, pipeline_efficiency,
                                 pipeline_spec)


def mk_spec(n_stages=4, d=3.0, fill=2.0, tail=5.0, vl=1024, lanes=4):
    return ChainSpec(startup_delays=(d,) * (n_stages - 1), fill_time=fill,
                     tail_time=tail, vl=vl, lanes=lanes)


def test_eq1_prologue():
    spec = mk_spec(n_stages=4, d=3.0, fill=2.0)
    assert spec.prologue == 3 * 3.0 + 2.0


def test_eq2_steady_state_ceiling():
    assert mk_spec(vl=1024, lanes=4).steady_ideal == 256
    assert mk_spec(vl=1025, lanes=4).steady_ideal == 257


def test_eq3_total():
    spec = mk_spec()
    assert spec.t_ideal == spec.prologue + spec.steady_ideal + spec.tail_time


def test_eq4_ideal_deviation_recovers_ideal():
    spec = mk_spec()
    assert IDEAL.t_real(spec) == spec.t_ideal
    assert IDEAL.loss(spec) == 0.0


@given(dp=st.floats(0, 100), ii=st.floats(1, 4), dt=st.floats(0, 100))
@settings(max_examples=100, deadline=None)
def test_eq5_loss_identity(dp, ii, dt):
    """dT == T_real - T_ideal exactly (Eq. 5 is algebra, not approximation)."""
    spec = mk_spec()
    dev = Deviation(dp=dp, ii_eff=ii, dt=dt)
    assert math.isclose(dev.loss(spec), dev.t_real(spec) - spec.t_ideal,
                        rel_tol=1e-12, abs_tol=1e-9)


@given(dp=st.floats(0, 50), ii=st.floats(1, 3), dt=st.floats(0, 50))
@settings(max_examples=100, deadline=None)
def test_real_never_faster_than_ideal(dp, ii, dt):
    spec = mk_spec()
    assert Deviation(dp, ii, dt).t_real(spec) >= spec.t_ideal - 1e-9


@given(prologue_extra=st.floats(0, 20), steady_mult=st.floats(1, 2),
       tail_extra=st.floats(0, 20))
@settings(max_examples=50, deadline=None)
def test_attribute_roundtrip(prologue_extra, steady_mult, tail_extra):
    spec = mk_spec()
    p_real = spec.prologue + prologue_extra
    s_real = spec.steady_ideal * steady_mult
    t_real_tail = spec.tail_time + tail_extra
    total = p_real + s_real + t_real_tail
    dev = attribute(spec, total, p_real, t_real_tail)
    assert math.isclose(dev.t_real(spec), total, rel_tol=1e-9)
    assert math.isclose(dev.ii_eff, steady_mult, rel_tol=1e-9)


def test_pipeline_efficiency_limits():
    assert pipeline_efficiency(1, 1) == 1.0
    assert pipeline_efficiency(10**6, 4) == pytest.approx(1.0, abs=1e-4)
    # GPipe-style bubble: M microbatches, S stages.
    assert pipeline_efficiency(8, 4) == pytest.approx(8 / 11)


def test_ii_eff_from_rates():
    # Consumer at 8 elem/cyc, memory supplying only 4: II_eff = 2.
    assert ii_eff_from_rates(8.0, [4.0]) == 2.0
    assert ii_eff_from_rates(8.0, [8.0, 16.0]) == 1.0


def test_pipeline_spec_is_chain():
    spec = pipeline_spec(num_stages=3, per_stage_delay=2.0, num_items=64,
                         item_time=1.0)
    assert spec.prologue == 2 * 2.0 + 2.0
    assert spec.steady_ideal == 64
