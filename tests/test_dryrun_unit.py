"""Dry-run machinery units: HLO collective parser, roofline math,
input_specs shapes, skip rules."""
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, cells, skip_reason
from repro.core.roofline import (RooflineTerms, TPU_V5E, gap_closed,
                                 model_flops_training, normalized, p_ideal)
from repro.launch.hlo_analysis import collective_bytes, op_histogram

HLO = """
ENTRY %main {
  %ag = bf16[256,1024]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = f32[512]{0} all-reduce(%x), to_apply=%add
  %start = (f32[128]{0}, f32[128]{0}) all-reduce-start(%y)
  %done = f32[128]{0} all-reduce-done(%start)
  %rs = bf16[64,64]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = u32[16]{0} collective-permute(%w)
  %a2a = f32[8,8]{1,0} all-to-all(%v)
  %mm = f32[10,10]{1,0} dot(%a, %b)
}
"""


def test_collective_parser_types_and_bytes():
    res = collective_bytes(HLO)
    by = res["bytes_by_type"]
    assert by["all-gather"] == 256 * 1024 * 2
    # plain all-reduce + the -start tuple (two f32[128] = 1024B)
    assert by["all-reduce"] == 512 * 4 + 2 * 128 * 4
    assert by["reduce-scatter"] == 64 * 64 * 2
    assert by["collective-permute"] == 16 * 4
    assert by["all-to-all"] == 8 * 8 * 4
    assert res["counts_by_type"]["all-reduce"] == 2   # done not re-counted
    assert res["total_bytes"] == sum(by.values())


def test_op_histogram():
    hist = dict(op_histogram(HLO))
    assert hist.get("dot") == 1


def test_roofline_terms_and_bound():
    t = RooflineTerms(flops=1.97e12, hbm_bytes=819e9 / 2,
                      collective_bytes=0.0)
    assert t.compute_s == pytest.approx(0.01)
    assert t.memory_s == pytest.approx(0.5)
    assert t.bound == "memory"
    assert t.step_time_s == pytest.approx(0.5)
    assert t.step_time_serial_s > t.step_time_s


def test_roofline_fraction_never_above_one_for_honest_inputs():
    t = RooflineTerms(flops=1e12, hbm_bytes=1e9, collective_bytes=1e8)
    # useful flops <= HLO flops => fraction <= compute_s/step_time <= 1
    assert t.roofline_fraction(1e12) <= 1.0 + 1e-9
    assert t.roofline_fraction(5e11) <= 0.5 + 1e-9


def test_paper_roofline_helpers():
    assert p_ideal(0.125) == pytest.approx(2.0)      # scal: BW-bound
    assert p_ideal(100.0) == pytest.approx(16.0)     # gemm: compute-bound
    assert normalized(0.8, 0.125) == pytest.approx(0.40)
    assert gap_closed(0.8, 1.92, 0.125) == pytest.approx(
        (1.92 - 0.8) / (2.0 - 0.8))


def test_model_flops_rule():
    assert model_flops_training(1e9, 1e6) == 6e15


def test_skip_rules_cover_brief():
    # encoder-only: no decode shapes
    hubert = ARCHS["hubert-xlarge"]
    assert skip_reason(hubert, SHAPES["decode_32k"])
    assert skip_reason(hubert, SHAPES["long_500k"])
    assert not skip_reason(hubert, SHAPES["prefill_32k"])
    # long_500k only for sub-quadratic archs
    assert skip_reason(ARCHS["glm4-9b"], SHAPES["long_500k"])
    assert skip_reason(ARCHS["deepseek-v2-236b"], SHAPES["long_500k"])
    for ok in ("gemma3-27b", "recurrentgemma-2b", "mamba2-780m"):
        assert not skip_reason(ARCHS[ok], SHAPES["long_500k"])
    # 40 total cells
    total = sum(len(cells(c)) for c in ARCHS.values())
    assert total == 40
    runnable = sum(1 for c in ARCHS.values() for _, r in cells(c)
                   if r is None)
    assert runnable == 32


def test_input_specs_match_brief_shapes():
    from repro.launch import dryrun
    cfg = ARCHS["glm4-9b"]
    b = dryrun.input_specs(cfg, SHAPES["train_4k"])
    assert b["tokens"].shape == (256, 4096)
    b = dryrun.input_specs(cfg, SHAPES["prefill_32k"])
    assert b["tokens"].shape == (32, 32768)
    b = dryrun.input_specs(cfg, SHAPES["decode_32k"])
    assert b["tokens"].shape == (128,)
    vlm = ARCHS["phi-3-vision-4.2b"]
    b = dryrun.input_specs(vlm, SHAPES["train_4k"])
    assert b["img_embeds"].shape == (256, vlm.n_img_tokens, vlm.d_model)
    audio = ARCHS["hubert-xlarge"]
    b = dryrun.input_specs(audio, SHAPES["train_4k"])
    assert b["frames"].shape == (256, 4096, audio.d_model)


def test_production_mesh_shapes():
    # Shape-only check (constructing 512 fake devices happens in the
    # dry-run subprocesses, not here where 1 device is forced).
    from repro.launch import mesh as M
    import inspect
    src = inspect.getsource(M.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src.replace("'", '"')
