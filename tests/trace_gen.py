"""Shared hypothesis generator for random-but-valid kernel traces.

Used by the property tests in `test_attribution.py` (scalar/batched
accounting invariants) and `test_assoc.py` (max-plus engine parity), so
both suites draw from the same trace distribution.
"""
from hypothesis_compat import st

from repro.core.isa import KernelTrace, OpKind, Stride, VInstr

_REGS = ("v0", "v4", "v8", "v12", "v16", "v20")
_KINDS = (OpKind.LOAD, OpKind.STORE, OpKind.COMPUTE, OpKind.REDUCE,
          OpKind.SLIDE)
_STRIDES = (Stride.UNIT, Stride.STRIDED, Stride.INDEXED)


def instr_tuples(min_size: int = 3, max_size: int = 24):
    """Strategy: a list of raw instruction tuples for `build_trace`."""
    return st.lists(
        st.tuples(st.integers(0, 4),       # kind
                  st.integers(1, 300),     # vl
                  st.integers(0, 5),       # dst register
                  st.integers(-1, 5),      # src 1 (-1: none)
                  st.integers(-1, 5),      # src 2 (-1: none)
                  st.integers(0, 2),       # stride
                  st.booleans(),           # first_strip
                  st.booleans()),          # divide op
        min_size=min_size, max_size=max_size)


def build_trace(raw) -> KernelTrace:
    """Materialize a raw tuple list into a structurally-valid trace."""
    instrs = []
    for k, vl, dst, s1, s2, stride_i, first, isdiv in raw:
        kind = _KINDS[k]
        mem = kind in (OpKind.LOAD, OpKind.STORE)
        srcs = tuple(_REGS[s] for s in (s1, s2) if s >= 0)
        if kind is OpKind.STORE and not srcs:
            srcs = (_REGS[dst],)
        if kind is OpKind.LOAD:
            srcs = srcs[:1] if _STRIDES[stride_i] is Stride.INDEXED else ()
        name = "vfdiv" if (isdiv and kind is OpKind.COMPUTE) else "vop"
        instrs.append(VInstr(
            name=name, kind=kind, vl=vl, sew=4,
            dst=None if kind is OpKind.STORE else _REGS[dst],
            srcs=srcs, stride=_STRIDES[stride_i] if mem else Stride.UNIT,
            flops=vl, stream="s", first_strip=first))
    return KernelTrace("rand", tuple(instrs), total_flops=1, total_bytes=1)
