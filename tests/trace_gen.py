"""Hypothesis strategies over the production trace generator.

Before PR 9 this module carried its own random-instruction builder; it
is now a thin wrapper over `repro.core.tracegen`, so every property test
(`test_attribution.py`, `test_assoc.py`, `test_bucketing.py`) exercises
the exact generator path that builds the committed scenario corpus —
hypothesis only picks *which* deterministic spec to expand.

`instr_tuples` keeps its historical name and ``(min_size, max_size)``
signature (bounds on the emitted instruction count); it now yields
`GenSpec` values, and `build_trace` is just `tracegen.generate`.
"""
from hypothesis_compat import st

from repro.core.tracegen import CLASSES, GenSpec, generate

#: Every workload class, including the "fuzz" instruction soup that
#: subsumes the old independent tuple builder's distribution.
_GEN_CLASSES = CLASSES


def gen_specs(min_size: int = 3, max_size: int = 24):
    """Strategy: a `GenSpec` whose expansion has between `min_size` and
    `max_size` instructions (the generator emits at least 3 per strip
    and hard-caps at ``max_instrs``)."""
    del min_size  # every class emits >= 3 instructions per strip
    return st.builds(
        lambda cls, seed, n, streams, chains, depth: GenSpec(
            cls=cls, seed=seed, n=n, n_streams=streams,
            compute_per_mem=chains, chain_depth=depth,
            max_instrs=max_size),
        cls=st.sampled_from(_GEN_CLASSES),
        seed=st.integers(0, 2**16 - 1),
        n=st.integers(1, 1024),
        streams=st.integers(1, 3),
        chains=st.integers(1, 3),
        depth=st.integers(1, 6),
    )


#: Historical alias: the property suites were written against a raw
#: tuple strategy of this name; they now draw specs.
def instr_tuples(min_size: int = 3, max_size: int = 24):
    return gen_specs(min_size, max_size)


def build_trace(spec: GenSpec):
    """Materialize a drawn spec through the shipped generator."""
    return generate(spec)
