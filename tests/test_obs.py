"""Observability subsystem contracts (PR 7).

Covered here: span nesting/ordering invariants, the <2% disabled-mode
overhead bound on the smoke grid, the runlog JSON-lines roundtrip, the
Chrome-trace schema's compatibility with `analysis/timeline.py` (one
merged Perfetto file), metrics-registry thread safety, and the
acceptance bound that timed span leaves account for >=90% of a
calibrated grid's simulate() wall-clock.
"""
import json
import threading
import time

import pytest

from repro.core import api
from repro.core.batch_sim import BatchAraSimulator
from repro.core.calibration import load as load_params
from repro.core.isa import ABLATION_GRID, OptConfig
from repro.core.simulator import AraSimulator
from repro.core.traces import axpy, dotp, scal
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

ALL_CORNERS = (OptConfig.baseline(), *ABLATION_GRID)


@pytest.fixture
def tracer_off():
    """Guarantee the module tracer is disabled and drained around a
    test, whatever state earlier tests (or REPRO_OBS) left it in."""
    was = obs_spans.enabled()
    obs_spans.disable()
    obs_spans.TRACER.drain()
    yield
    obs_spans.TRACER.drain()
    (obs_spans.enable if was else obs_spans.disable)()


@pytest.fixture
def tracer_on(tracer_off):
    obs_spans.enable()
    yield obs_spans.TRACER
    obs_spans.disable()


# --- span tree invariants --------------------------------------------------

def test_span_nesting_and_ordering(tracer_on):
    with obs_spans.span("outer", grid="smoke") as outer:
        with obs_spans.span("inner_a"):
            time.sleep(0.001)
        with obs_spans.span("inner_b") as b:
            b.set(items=3)
        outer.set(late_attr=1)
    done = obs_spans.TRACER.drain()
    by_name = {sp.name: sp for sp in done}
    assert set(by_name) == {"outer", "inner_a", "inner_b"}
    out, a, b_ = by_name["outer"], by_name["inner_a"], by_name["inner_b"]
    # Children link to the parent; the root has none.
    assert a.parent == out.sid and b_.parent == out.sid
    assert out.parent is None
    # Children close before the parent -> finish order a, b, outer.
    assert [sp.name for sp in done] == ["inner_a", "inner_b", "outer"]
    # Monotonic containment: parent interval covers each child's.
    for child in (a, b_):
        assert out.start <= child.start <= child.end <= out.end
    assert a.duration >= 0.001
    # Attrs set at open and via .set() both land.
    assert out.attrs == {"grid": "smoke", "late_attr": 1}
    assert b_.attrs == {"items": 3}


def test_span_disabled_is_shared_noop(tracer_off):
    s1 = obs_spans.span("x", a=1)
    s2 = obs_spans.span("y")
    assert s1 is s2                        # one shared _NullSpan
    with s1 as got:
        got.set(anything="goes")
    assert obs_spans.TRACER.drain() == []


def test_span_thread_tracks(tracer_on):
    def work(i):
        with obs_spans.span("thread_root", i=i):
            with obs_spans.span("thread_leaf", i=i):
                pass
    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    done = obs_spans.TRACER.drain()
    assert len(done) == 8
    roots = [sp for sp in done if sp.name == "thread_root"]
    leaves = [sp for sp in done if sp.name == "thread_leaf"]
    # Per-thread nesting never crosses threads: each leaf's parent is
    # the root with the same ordinal-attr, on the same track.
    root_by_i = {sp.attrs["i"]: sp for sp in roots}
    for leaf in leaves:
        root = root_by_i[leaf.attrs["i"]]
        assert leaf.parent == root.sid
        assert leaf.tid == root.tid
    assert all(sp.parent is None for sp in roots)


def test_simulate_emits_expected_tree(tracer_on):
    api.simulate([scal(128), axpy(128)], [OptConfig.baseline()],
                 backend="numpy")
    done = obs_spans.TRACER.drain()
    by_name = {}
    for sp in done:
        by_name.setdefault(sp.name, sp)
    assert {"simulate", "traces.stack", "plan.resolve", "exec",
            "exec.p_chunk", "exec.numpy.scan"} <= set(by_name)
    root = by_name["simulate"]
    assert root.attrs["backend"] == "numpy"
    assert root.attrs["n_traces"] == 2 and root.attrs["n_opts"] == 1
    assert by_name["exec"].parent == root.sid
    assert by_name["exec.p_chunk"].parent == by_name["exec"].sid
    assert by_name["exec.numpy.scan"].parent == by_name["exec.p_chunk"].sid


def test_jax_compile_then_execute_split(tracer_on):
    pytest.importorskip("jax")
    sim = BatchAraSimulator()                  # fresh seen-signature set
    traces = [scal(96), axpy(96)]
    api.simulate(traces, ALL_CORNERS, backend="jax", sim=sim)
    first = {sp.name for sp in obs_spans.TRACER.drain()}
    api.simulate(traces, ALL_CORNERS, backend="jax", sim=sim)
    second = {sp.name for sp in obs_spans.TRACER.drain()}
    assert "exec.jax.compile" in first
    assert "exec.jax.execute" not in first
    assert "exec.jax.execute" in second
    assert "exec.jax.compile" not in second


# --- disabled-mode overhead ------------------------------------------------

def test_disabled_overhead_under_two_percent(tracer_off):
    """Acceptance: telemetry disabled costs <2% on the smoke grid.

    A/B wall-clock differencing at this scale is noise, so the bound is
    computed structurally: (measured per-call cost of a disabled span)
    x (number of span call sites the same workload executes when
    enabled) must be under 2% of the workload's disabled wall-clock."""
    traces = [scal(256), axpy(256), dotp(256)]
    params = load_params()

    def workload():
        return api.simulate(traces, ALL_CORNERS, params, backend="numpy",
                            attribution=True)

    workload()                             # warm shared sim/caches
    t0 = time.perf_counter()
    workload()
    wall = time.perf_counter() - t0

    # How many spans does this workload open when tracing is on?
    obs_spans.enable()
    try:
        workload()
        n_spans = len(obs_spans.TRACER.drain())
    finally:
        obs_spans.disable()
    assert n_spans > 0

    n_calls = 20_000
    t0 = time.perf_counter()
    for _ in range(n_calls):
        with obs_spans.span("overhead_probe", a=1, b=2):
            pass
    per_call = (time.perf_counter() - t0) / n_calls

    assert per_call * n_spans < 0.02 * wall, (
        f"disabled-span overhead {per_call * n_spans * 1e6:.1f}us "
        f"vs 2% budget {0.02 * wall * 1e6:.1f}us "
        f"({n_spans} spans @ {per_call * 1e9:.0f}ns)")


# --- span leaves cover the wall-clock --------------------------------------

def test_span_leaves_cover_90pct_of_simulate(tracer_on):
    """Acceptance: the span tree is a decomposition, not a sampling —
    timed leaves account for >=90% of the root's wall-clock on the
    calibrated 11-kernel x 8-corner grid."""
    from repro.core.traces import DEFAULT_TRACES
    traces = [fn() for fn in DEFAULT_TRACES.values()]
    api.simulate(traces, ALL_CORNERS, load_params(), backend="numpy",
                 attribution=True)
    done = obs_spans.TRACER.drain()
    parents = {sp.parent for sp in done if sp.parent is not None}
    root = next(sp for sp in done if sp.name == "simulate")
    leaves = [sp for sp in done if sp.sid not in parents]
    leaf_total = sum(sp.duration for sp in leaves)
    assert leaf_total >= 0.90 * root.duration, (
        f"leaves {leaf_total * 1e3:.2f}ms of root "
        f"{root.duration * 1e3:.2f}ms "
        f"({100 * leaf_total / root.duration:.1f}%)")


# --- runlog roundtrip ------------------------------------------------------

def test_runlog_roundtrip(tracer_off, tmp_path):
    runlog = tmp_path / "run.jsonl"
    res = api.simulate([scal(128)], [OptConfig.baseline()],
                       backend="numpy", runlog=runlog)
    assert res.cycles.shape == (1, 1, 1)
    assert not obs_spans.enabled()         # restored after the call
    records = obs_export.read_runlog(runlog)
    spans = [r for r in records if r["kind"] == "span"]
    metrics = [r for r in records if r["kind"] == "metrics"]
    assert spans and len(metrics) == 1
    names = {r["name"] for r in spans}
    assert {"simulate", "exec", "exec.numpy.scan"} <= names
    sids = {r["sid"] for r in spans}
    for r in spans:
        assert r["dur_us"] >= 0.0
        assert r["parent"] is None or r["parent"] in sids
    root = next(r for r in spans if r["name"] == "simulate")
    assert root["attrs"]["n_traces"] == 1
    # Metrics snapshot carries the simulate counters.
    metric_names = {m["name"] for m in metrics[0]["metrics"]}
    assert {"simulate.calls", "simulate.cells",
            "simulate.wall_us"} <= metric_names
    # Appending a second run keeps the file parseable; the last metrics
    # record is cumulative.
    api.simulate([scal(128)], [OptConfig.baseline()],
                 backend="numpy", runlog=runlog)
    records2 = obs_export.read_runlog(runlog)
    metrics2 = [r for r in records2 if r["kind"] == "metrics"]
    assert len(metrics2) == 2

    def calls(block):
        return next(m["value"] for m in block["metrics"]
                    if m["name"] == "simulate.calls")
    assert calls(metrics2[-1]) >= calls(metrics2[0]) + 1


def test_runlog_summary_reports_the_claims(tracer_off, tmp_path):
    """summarize_runlog must state the compile/execute split and the
    cache hit rate (ISSUE acceptance)."""
    pytest.importorskip("jax")
    runlog = tmp_path / "run.jsonl"
    sim = BatchAraSimulator()
    api.simulate([scal(96)], [OptConfig.baseline()], backend="jax",
                 sim=sim, runlog=runlog)
    api.simulate([scal(96)], [OptConfig.baseline()], backend="jax",
                 sim=sim, runlog=runlog)
    obs_metrics.counter("sweep_cache.hits").inc(3)
    obs_metrics.counter("sweep_cache.misses").inc()
    obs_export.flush(runlog)
    text = obs_export.summarize_runlog(runlog)
    assert "jit compile/execute:" in text
    assert "compile share" in text
    assert "hit rate" in text
    assert "simulate:" in text
    assert obs_export.check_metric_names(runlog) == []


def test_check_metric_names_flags_unknown(tracer_off, tmp_path):
    runlog = tmp_path / "run.jsonl"
    runlog.write_text(json.dumps({
        "kind": "metrics",
        "metrics": [{"type": "counter", "name": "rogue.metric",
                     "label": None, "value": 1.0}]}) + "\n")
    assert obs_export.check_metric_names(runlog) == ["rogue.metric"]
    assert obs_export.main([str(runlog), "--check-metrics"]) == 1


# --- merged Chrome trace ---------------------------------------------------

def test_merged_trace_schema_compatible_with_timeline(tracer_off,
                                                      tmp_path):
    """Host spans and timeline.py's simulated Gantt share one file and
    one trace_event schema; Perfetto reads it as distinct processes."""
    runlog = tmp_path / "run.jsonl"
    tr = scal(128)
    api.simulate([tr], [OptConfig.baseline()], backend="numpy",
                 runlog=runlog)
    res = AraSimulator().run(tr, OptConfig.baseline())
    out = obs_export.export_merged_trace(
        tmp_path / "merged.json", obs_export.read_runlog(runlog),
        [(tr, res)])
    payload = json.loads(out.read_text())
    events = payload["traceEvents"]
    for e in events:
        assert e["ph"] in ("M", "X")
        assert isinstance(e["pid"], int)
        if e["ph"] == "X":                 # complete-event schema
            assert set(e) >= {"name", "cat", "ph", "pid", "tid", "ts",
                              "dur", "args"}
            assert e["ts"] >= 0.0 and e["dur"] >= 0.0
    pids = {e["pid"] for e in events}
    assert pids == {obs_export.HOST_PID, obs_export.HOST_PID + 1}
    # The host process row holds the simulate span; the cell row holds
    # one X event per instruction, exactly as export_chrome_trace does.
    host_x = [e for e in events if e["pid"] == obs_export.HOST_PID
              and e["ph"] == "X"]
    cell_x = [e for e in events if e["pid"] == obs_export.HOST_PID + 1
              and e["ph"] == "X"]
    assert any(e["name"] == "simulate" for e in host_x)
    assert len(cell_x) == len(tr.instrs)
    # Both processes announce names so Perfetto labels the rows.
    proc_meta = {e["pid"] for e in events
                 if e["ph"] == "M" and e["name"] == "process_name"}
    assert proc_meta == pids


# --- metrics registry ------------------------------------------------------

def test_metrics_registry_thread_safety():
    reg = obs_metrics.Registry()
    n_threads, n_iter = 8, 2500

    def work():
        c = reg.counter("t.count")
        h = reg.histogram("t.hist")
        g = reg.gauge("t.gauge")
        for i in range(n_iter):
            c.inc()
            h.observe(float(i))
            g.set(float(i))

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = {(s["name"], s["label"]): s for s in reg.snapshot()}
    assert snap[("t.count", None)]["value"] == n_threads * n_iter
    h = snap[("t.hist", None)]
    assert h["count"] == n_threads * n_iter
    assert h["sum"] == pytest.approx(
        n_threads * n_iter * (n_iter - 1) / 2)
    assert sum(h["counts"]) == h["count"]


def test_metrics_type_and_value_enforcement():
    reg = obs_metrics.Registry()
    reg.counter("m.x")
    with pytest.raises(TypeError):
        reg.gauge("m.x")
    with pytest.raises(ValueError):
        reg.counter("m.x").inc(-1)
    with pytest.raises(ValueError):
        obs_metrics.Histogram("m.bad", buckets=(3.0, 1.0))
    # get-or-create returns the same instrument.
    assert reg.counter("m.x") is reg.counter("m.x")
    # Labeled instruments are independent.
    reg.counter("m.lab", "a").inc(2)
    reg.counter("m.lab", "b").inc(5)
    vals = {s["label"]: s["value"] for s in reg.snapshot()
            if s["name"] == "m.lab"}
    assert vals == {"a": 2, "b": 5}


def test_emitted_metric_names_are_known(tracer_off, tmp_path):
    """Every metric the instrumented call sites emit is documented in
    KNOWN_METRICS (the registry itself doesn't lint; this does)."""
    runlog = tmp_path / "run.jsonl"
    api.simulate([scal(128)], [OptConfig.baseline(), OptConfig.full()],
                 runlog=runlog)            # backend/method resolve "auto"
    assert obs_export.check_metric_names(runlog) == []
