"""Batched phase decomposition + jax attribution parity.

Contracts: `phase_decompose_grid` equals per-cell `phase_decompose` of
the scalar simulator on every cell (bit-equal via the numpy backend,
including on arbitrary hypothesis-generated traces); the jax backend
agrees across all 8 ablation corners; phase splits thread through
gridlib into the sweep cache and the fig6 CSV rows; stacked-bar
rendering works when matplotlib is present.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.analysis.attribution import (phase_decompose,
                                        phase_decompose_grid)
from repro.analysis.report import (breakdown_rows, have_matplotlib,
                                   render_stacked_bars)
from repro.core import api
from repro.core import stalls as S
from repro.core.batch_sim import BatchResult
from repro.core.isa import ABLATION_GRID, OptConfig
from repro.core.simulator import AraSimulator, SimParams
from repro.core.traces import axpy, dotp, scal, spmv, stack_traces

ALL_CORNERS = (OptConfig.baseline(), *ABLATION_GRID)
_PARAMS = [SimParams(), SimParams(mem_latency=90.0, d_chain_base=20.0)]


def _small_traces():
    return [scal(256), axpy(256), dotp(256), spmv(16)]


@pytest.fixture(scope="module")
def batch():
    traces = _small_traces()
    res = api.simulate(stack_traces(traces), ALL_CORNERS, _PARAMS,
                       backend="numpy", attribution=True)
    return traces, res


def test_phase_grid_matches_per_cell(batch):
    traces, res = batch
    pg = phase_decompose_grid(traces, res, params=_PARAMS)
    for pi, params in enumerate(_PARAMS):
        sim = AraSimulator(params=params)
        for bi, tr in enumerate(traces):
            for oi, opt in enumerate(ALL_CORNERS):
                ref = phase_decompose(tr, sim.run(tr, opt), params=params)
                cell = pg.cell(bi, oi, pi)
                assert cell.prologue_real == ref.prologue_real
                assert cell.steady_real == ref.steady_real
                assert cell.tail_real == ref.tail_real
                assert cell.deviation == ref.deviation
                assert cell.spec == ref.spec


def test_phase_grid_reconstructs_cycles(batch):
    """Eq. (4)/(5) in tensor form: t_real == cycles and loss ==
    cycles - t_ideal, for every cell at once."""
    traces, res = batch
    pg = phase_decompose_grid(traces, res, params=_PARAMS)
    np.testing.assert_allclose(pg.t_real, res.cycles, rtol=1e-12)
    np.testing.assert_allclose(
        pg.loss, res.cycles - pg.t_ideal[:, None, :], rtol=1e-9,
        atol=1e-6)


def test_phase_grid_shape_validation(batch):
    traces, res = batch
    with pytest.raises(ValueError, match="does not match"):
        phase_decompose_grid(traces[:2], res, params=_PARAMS)
    hollow = BatchResult(names=res.names, cycles=res.cycles,
                         busy_fpu=res.busy_fpu, busy_bus=res.busy_bus,
                         flops=res.flops, bytes=res.bytes)
    with pytest.raises(ValueError, match="phase observables"):
        phase_decompose_grid(traces, hollow, params=_PARAMS)


def test_jax_attribution_parity_all_corners():
    """Satellite contract: jax-vs-numpy attribution parity across all 8
    ablation corners, >= 3 kernels, and a widened params axis."""
    traces = _small_traces()
    st_ = stack_traces(traces)
    ref = api.simulate(st_, ALL_CORNERS, _PARAMS, backend="numpy",
                       attribution=True)
    got = api.simulate(st_, ALL_CORNERS, _PARAMS, backend="jax",
                       attribution=True)
    np.testing.assert_allclose(got.cycles, ref.cycles, rtol=1e-9)
    np.testing.assert_allclose(got.ideal, ref.ideal, rtol=1e-9,
                               atol=1e-9)
    np.testing.assert_allclose(got.stalls, ref.stalls, rtol=1e-9,
                               atol=1e-9)
    # The same grid's phase decomposition agrees backend-to-backend.
    pg_ref = phase_decompose_grid(traces, ref, params=_PARAMS)
    pg_got = phase_decompose_grid(traces, got, params=_PARAMS)
    for field in ("prologue_real", "steady_real", "tail_real",
                  "dp", "ii_eff", "dt"):
        np.testing.assert_allclose(getattr(pg_got, field),
                                   getattr(pg_ref, field),
                                   rtol=1e-9, atol=1e-9, err_msg=field)


def test_path_matrix_matches_group_stalls(batch):
    _, res = batch
    sums = S.path_sums(res.stalls)             # (B, O, P, 3)
    assert sums.shape == (*res.stalls.shape[:-1], 3)
    grouped = S.group_stalls(res.stalls[0, 0, 0])
    for pi, name in enumerate(S.PATH_NAMES):
        assert sums[0, 0, 0, pi] == pytest.approx(grouped[name])
    np.testing.assert_allclose(sums.sum(-1), res.stalls.sum(-1),
                               rtol=1e-12)


# --- hypothesis property test ----------------------------------------------

from test_attribution import _build_trace, _instr_tuples  # noqa: E402


@given(raw=_instr_tuples)
@settings(max_examples=25, deadline=None)
def test_property_phase_grid_matches_per_cell(raw):
    """On arbitrary traces, the vectorized grid decomposition equals the
    scalar per-cell path bit-for-bit (numpy backend)."""
    tr = _build_trace(raw)
    corners = (OptConfig.baseline(), OptConfig.full())
    res = api.simulate(stack_traces([tr]), corners, backend="numpy",
                       attribution=True)
    pg = phase_decompose_grid([tr], res)
    sim = AraSimulator(params=SimParams())
    for oi, opt in enumerate(corners):
        ref = phase_decompose(tr, sim.run(tr, opt))
        cell = pg.cell(0, oi, 0)
        assert cell.prologue_real == ref.prologue_real
        assert cell.steady_real == ref.steady_real
        assert cell.tail_real == ref.tail_real
        assert cell.deviation == ref.deviation


# --- gridlib threading + rendering -----------------------------------------

def test_grid_cells_attach_and_cache_phases(tmp_path):
    import pathlib
    import sys
    repo = str(pathlib.Path(__file__).resolve().parents[1])
    if repo not in sys.path:
        sys.path.insert(0, repo)
    from benchmarks import gridlib
    from repro.launch.sweep_cache import SweepCache
    traces = {"scal": scal(256), "axpy": axpy(256)}
    opts = [OptConfig.baseline(), OptConfig.full()]
    cache = SweepCache(tmp_path)
    g1 = gridlib.Grid(params=SimParams(), cache=cache)
    cells = g1.cells(traces, opts, attribution=True)
    for (name, label), res in cells.items():
        assert res.phases is not None, (name, label)
        assert set(res.phases) == {"prologue", "steady", "tail",
                                   "dp", "ii_eff", "dt", "t_ideal"}
        total = (res.phases["prologue"] + res.phases["steady"]
                 + res.phases["tail"])
        assert total == pytest.approx(res.cycles, rel=1e-9)
        ref = phase_decompose(traces[name],
                              AraSimulator(params=SimParams()).run(
                                  traces[name],
                                  opts[0] if label == "base" else opts[1]))
        assert res.phases["ii_eff"] == ref.deviation.ii_eff
    # Second grid instance: phases survive the cache roundtrip.
    g2 = gridlib.Grid(params=SimParams(), cache=SweepCache(tmp_path))
    cells2 = g2.cells(traces, opts, attribution=True)
    assert g2.cache.hits == 4 and g2.cache.misses == 0
    for key, res in cells.items():
        assert cells2[key].phases == pytest.approx(res.phases)
    # Rows built from these cells carry the phase columns.
    rows = breakdown_rows({n: cells[(n, "base")] for n in traces},
                          config="base")
    assert all("ii_eff" in r and "prologue" in r for r in rows)


def test_jax_grid_does_not_pollute_cache(tmp_path):
    """Cell keys don't encode the backend and the cache's contract is
    scalar bit-exactness, so jax-backed grids must not persist their
    (allclose-only) results where numpy readers would hit them."""
    import pathlib
    import sys
    repo = str(pathlib.Path(__file__).resolve().parents[1])
    if repo not in sys.path:
        sys.path.insert(0, repo)
    from benchmarks import gridlib
    from repro.launch.sweep_cache import SweepCache
    traces = {"scal": scal(256)}
    opts = [OptConfig.baseline()]
    cache = SweepCache(tmp_path)
    gj = gridlib.Grid(params=SimParams(), cache=cache, backend="jax")
    cells = gj.cells(traces, opts, attribution=True)
    assert cells[("scal", "base")].stalls is not None
    assert len(cache) == 0                 # nothing persisted
    gn = gridlib.Grid(params=SimParams(), cache=cache)
    gn.cells(traces, opts, attribution=True)
    assert len(cache) == 1                 # numpy cells do persist


def test_plain_cached_cells_miss_attribution_phase_reads(tmp_path):
    """A cell stored without phases must not satisfy an attribution read
    (the grid re-simulates instead of emitting rows missing columns)."""
    from repro.launch.sweep_cache import SweepCache
    cache = SweepCache(tmp_path)
    key = "ab" + "0" * 62
    cache.put(key, {"cycles": 1.0, "flops": 1, "bytes": 1,
                    "busy_fpu": 0.0, "busy_bus": 0.0,
                    "ideal": 0.5, "stalls": [0.0] * 9})
    assert cache.get_result(key, "scal", attribution=True) is not None
    assert cache.get_result(key, "scal", attribution=True,
                            require_phases=True) is None


@pytest.mark.skipif(not have_matplotlib(),
                    reason="matplotlib not installed ([plot] extra)")
def test_render_stacked_bars(tmp_path):
    traces = {"scal": scal(256), "axpy": axpy(256)}
    sim = AraSimulator(params=SimParams())
    rows = []
    for opt in (OptConfig.baseline(), OptConfig.full()):
        results = {n: sim.run(tr, opt) for n, tr in traces.items()}
        rows.extend(breakdown_rows(results, config=opt.label))
    out = render_stacked_bars(rows, tmp_path / "bars.png")
    assert out.is_file() and out.stat().st_size > 0


def test_render_stacked_bars_degrades_without_matplotlib(monkeypatch,
                                                         tmp_path):
    import repro.analysis.report as R
    monkeypatch.setattr(R, "have_matplotlib", lambda: False)
    with pytest.raises(RuntimeError, match="matplotlib"):
        R.render_stacked_bars([], tmp_path / "bars.png")