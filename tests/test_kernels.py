"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py),
plus hypothesis property tests on kernel invariants.  All kernels run in
interpret mode on CPU (the TPU lowering path is identical code)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _keys(n):
    return jax.random.split(KEY, n)


# --- streamer ---------------------------------------------------------------

@pytest.mark.parametrize("n", [128, 1024, 4096, 5000, 65536])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_streamer_chain_shapes(n, dtype):
    ks = _keys(3)
    x = jax.random.normal(ks[0], (n,), dtype)
    y = jax.random.normal(ks[1], (n,), dtype)
    w = jax.random.normal(ks[2], (n,), dtype)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    expect = ref.chain_ref(x, y, w)
    np.testing.assert_allclose(ops.fused_chain(x, y, w).astype(jnp.float32),
                               expect.astype(jnp.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(
        ops.unfused_chain(x, y, w).astype(jnp.float32),
        expect.astype(jnp.float32), rtol=tol, atol=tol)


def test_fused_equals_unfused():
    """The paper's O-optimization (fusion/forwarding) must be semantics-
    preserving: fused and HBM-round-trip variants agree exactly."""
    ks = _keys(3)
    x, y, w = (jax.random.normal(k, (8192,)) for k in ks)
    np.testing.assert_array_equal(np.asarray(ops.fused_chain(x, y, w)),
                                  np.asarray(ops.fused_chain(x, y, w)))
    # FMA contraction in the fused kernel vs separate mul+add rounding.
    np.testing.assert_allclose(ops.fused_chain(x, y, w),
                               ops.unfused_chain(x, y, w), rtol=1e-4,
                               atol=1e-6)


def test_streamer_roundtrip_accounting():
    from repro.kernels.streamer import hbm_roundtrip_bytes
    assert hbm_roundtrip_bytes((1024,), jnp.float32, fused=True) == 4 * 4096
    assert hbm_roundtrip_bytes((1024,), jnp.float32, fused=False) == 6 * 4096


# --- gemm -------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 512, 128), (200, 300, 160), (64, 1000, 48),
    (129, 257, 130),
])
def test_gemm_shapes(m, k, n):
    ks = _keys(2)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    y = jax.random.normal(ks[1], (k, n), jnp.float32)
    np.testing.assert_allclose(ops.gemm(x, y), ref.gemm_ref(x, y),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("act", ["none", "relu", "gelu", "silu"])
def test_gemm_fused_epilogue(act):
    ks = _keys(3)
    x = jax.random.normal(ks[0], (160, 256), jnp.float32)
    y = jax.random.normal(ks[1], (256, 192), jnp.float32)
    b = jax.random.normal(ks[2], (192,), jnp.float32)
    np.testing.assert_allclose(ops.gemm(x, y, b, act),
                               ref.gemm_ref(x, y, b, act),
                               rtol=3e-5, atol=3e-5)


def test_gemm_unfused_epilogue_matches_fused():
    ks = _keys(3)
    x = jax.random.normal(ks[0], (128, 128), jnp.float32)
    y = jax.random.normal(ks[1], (128, 128), jnp.float32)
    b = jax.random.normal(ks[2], (128,), jnp.float32)
    np.testing.assert_allclose(ops.gemm_unfused_epilogue(x, y, b, "gelu"),
                               ops.gemm(x, y, b, "gelu"),
                               rtol=1e-4, atol=1e-4)


def test_gemm_bf16():
    ks = _keys(2)
    x = jax.random.normal(ks[0], (128, 256), jnp.bfloat16)
    y = jax.random.normal(ks[1], (256, 128), jnp.bfloat16)
    out = ops.gemm(x, y)
    expect = ref.gemm_ref(x, y)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               expect.astype(jnp.float32), rtol=2e-2,
                               atol=2e-1)


@given(m=st.integers(8, 96), k=st.integers(8, 96), n=st.integers(8, 96))
@settings(max_examples=10, deadline=None)
def test_gemm_property_random_shapes(m, k, n):
    ks = _keys(2)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    y = jax.random.normal(ks[1], (k, n), jnp.float32)
    np.testing.assert_allclose(ops.gemm(x, y, bm=32, bn=32, bk=32),
                               ref.gemm_ref(x, y), rtol=1e-4, atol=1e-4)


# --- flash attention --------------------------------------------------------

@pytest.mark.parametrize("sq,skv,h,hkv,d", [
    (128, 128, 4, 4, 64), (256, 256, 8, 2, 64), (128, 256, 4, 1, 128),
    (64, 512, 8, 8, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(sq, skv, h, hkv, d, causal):
    ks = _keys(3)
    q = jax.random.normal(ks[0], (2, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (2, skv, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (2, skv, hkv, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, bq=64, bkv=64)
    kr = jnp.repeat(k, h // hkv, axis=2)
    vr = jnp.repeat(v, h // hkv, axis=2)
    np.testing.assert_allclose(out, ref.mha_ref(q, kr, vr, causal=causal),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_softcap():
    ks = _keys(3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 4, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 4, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, logit_softcap=20.0,
                              bq=64, bkv=64)
    np.testing.assert_allclose(
        out, ref.mha_ref(q, k, v, causal=True, logit_softcap=20.0),
        rtol=2e-4, atol=2e-4)


def test_flash_attention_probability_property():
    """Attention output must lie in the convex hull of V rows: max|out|
    <= max|v| (softmax weights sum to 1)."""
    ks = _keys(3)
    q = 5.0 * jax.random.normal(ks[0], (1, 64, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, bq=32, bkv=32)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4


# --- decode attention -------------------------------------------------------

@pytest.mark.parametrize("s,bkv", [(512, 128), (1024, 256), (768, 512)])
def test_decode_attention_sweep(s, bkv):
    ks = _keys(4)
    q = jax.random.normal(ks[0], (2, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, s, 8, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, s, 8, 64), jnp.float32)
    kvlen = jnp.array([s // 2, s])
    out = ops.decode_attention(q, k, v, kvlen, bkv=bkv)
    np.testing.assert_allclose(out,
                               ref.decode_attention_ref(q, k, v, kvlen),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_combine_is_exact():
    """Split-KV combine must equal single-chunk attention (tail-drain
    algebra is exact, not approximate)."""
    ks = _keys(3)
    q = jax.random.normal(ks[0], (1, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 4, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 512, 4, 32), jnp.float32)
    out_1 = ops.decode_attention(q, k, v, None, bkv=512)   # single chunk
    out_4 = ops.decode_attention(q, k, v, None, bkv=128)   # 4-way split
    np.testing.assert_allclose(out_1, out_4, rtol=1e-5, atol=1e-5)


# --- SSD ---------------------------------------------------------------------

@pytest.mark.parametrize("l,h,p,g,n,chunk", [
    (128, 4, 32, 1, 16, 32), (256, 8, 16, 2, 32, 64), (64, 2, 64, 1, 8, 64),
])
def test_ssd_sweep(l, h, p, g, n, chunk):
    ks = _keys(5)
    x = jax.random.normal(ks[0], (2, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    b = jax.random.normal(ks[3], (2, l, g, n), jnp.float32)
    c = jax.random.normal(ks[4], (2, l, g, n), jnp.float32)
    y, hT = ops.ssd_batched(x, dt, a, b, c, chunk=chunk)
    yr, hr = jax.vmap(lambda xx, dd, bb, cc: ref.ssd_ref(xx, dd, a, bb, cc))(
        x, dt, b, c)
    np.testing.assert_allclose(y, yr, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(hT, hr.transpose(0, 1, 3, 2), rtol=3e-4,
                               atol=3e-4)


def test_ssd_chunk_invariance():
    """Chunk size is an implementation detail: results must not depend on
    it (the chaining decomposition is exact)."""
    ks = _keys(5)
    x = jax.random.normal(ks[0], (1, 128, 2, 16), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 128, 2)))
    a = -jnp.exp(jax.random.normal(ks[2], (2,)))
    b = jax.random.normal(ks[3], (1, 128, 1, 8), jnp.float32)
    c = jax.random.normal(ks[4], (1, 128, 1, 8), jnp.float32)
    y32, _ = ops.ssd_batched(x, dt, a, b, c, chunk=32)
    y128, _ = ops.ssd_batched(x, dt, a, b, c, chunk=128)
    np.testing.assert_allclose(y32, y128, rtol=1e-4, atol=1e-4)


def test_ssd_decay_bounds():
    """With A<0 and bounded inputs the state must stay bounded (stability
    of the recurrence — the chained operand cannot blow up)."""
    ks = _keys(5)
    x = jnp.ones((1, 512, 2, 8), jnp.float32)
    dt = jnp.full((1, 512, 2), 0.5)
    a = jnp.array([-1.0, -0.5])
    b = jnp.ones((1, 512, 1, 4)) * 0.5
    c = jnp.ones((1, 512, 1, 4)) * 0.5
    y, hT = ops.ssd_batched(x, dt, a, b, c, chunk=64)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.max(jnp.abs(hT))) < 100.0
