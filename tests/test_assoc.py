"""Max-plus associative-scan engine (`method="assoc"`): parity with the
sequential scan on the full calibrated grid, the attribution-sum
invariant, the Pallas-fused combine, and the memory guard."""
import numpy as np
import pytest
from hypothesis_compat import given, settings

from repro.core import api, assoc_sim, calibration
from repro.core.isa import ABLATION_GRID, OptConfig
from repro.core.simulator import SimParams
from repro.core.traces import scal

jax = pytest.importorskip("jax")

ALL_CORNERS = (OptConfig.baseline(), *ABLATION_GRID)       # 2^3 corners


@pytest.fixture(scope="module")
def grid_traces():
    """Every paper kernel at the parity (reduced) sizes, as a list."""
    return list(calibration.parity_traces().values())


@pytest.fixture(scope="module")
def cal_params():
    return calibration.load()


@pytest.fixture(scope="module")
def scan_ref(grid_traces, cal_params):
    return api.simulate(grid_traces, ALL_CORNERS, cal_params,
                        backend="jax", method="scan", attribution=True)


@pytest.fixture(scope="module")
def assoc_res(grid_traces, cal_params):
    return api.simulate(grid_traces, ALL_CORNERS, cal_params,
                        backend="jax", method="assoc", attribution=True)


def test_assoc_matches_scan_full_grid(scan_ref, assoc_res):
    """Acceptance: float64-allclose cycles vs the scan on every paper
    kernel x all 8 ablation corners x calibrated params."""
    np.testing.assert_allclose(assoc_res.cycles, scan_ref.cycles,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(assoc_res.busy_fpu, scan_ref.busy_fpu,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(assoc_res.busy_bus, scan_ref.busy_bus,
                               rtol=1e-9, atol=1e-6)


def test_assoc_attribution_parity(scan_ref, assoc_res):
    np.testing.assert_allclose(assoc_res.ideal, scan_ref.ideal,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(assoc_res.stalls, scan_ref.stalls,
                               rtol=1e-7, atol=1e-6)


def test_assoc_attribution_sum_invariant(assoc_res):
    """Exact accounting: ideal + sum(stalls) == cycles, stalls >= 0."""
    total = assoc_res.ideal + assoc_res.stalls.sum(axis=-1)
    np.testing.assert_allclose(total, assoc_res.cycles,
                               rtol=1e-12, atol=1e-6)
    assert assoc_res.stalls.min() >= -1e-6
    assert assoc_res.ideal.min() >= 0.0


def test_assoc_without_attribution(grid_traces, cal_params, scan_ref):
    res = api.simulate(grid_traces, ALL_CORNERS, cal_params,
                       backend="jax", method="assoc", attribution=False)
    assert res.stalls is None and res.ideal is None
    np.testing.assert_allclose(res.cycles, scan_ref.cycles,
                               rtol=1e-9, atol=1e-9)


def test_basis_dim_and_bytes_estimate():
    assert assoc_sim.basis_dim(10) == 8 + 30
    small = assoc_sim.assoc_bytes(64, 1, 1, 4, attribution=False)
    big = assoc_sim.assoc_bytes(4096, 11, 8, 10, attribution=True)
    assert 0 < small < big


def test_memory_guard(monkeypatch):
    monkeypatch.setenv(assoc_sim.MEM_LIMIT_ENV, "1")
    with pytest.raises(ValueError, match="scan"):
        api.simulate(scal(64), [OptConfig.baseline()],
                     backend="jax", method="assoc")


def test_numpy_assoc_rejected():
    with pytest.raises(ValueError, match="assoc"):
        api.simulate(scal(64), [OptConfig.baseline()],
                     backend="numpy", method="assoc")


# --- Pallas-fused combine ---------------------------------------------------

def test_pallas_matches_jnp():
    """The Pallas kernel (interpreter mode on CPU) is bit-identical to
    the jnp reference: values AND argmax binding indices, -inf included."""
    from repro.core.pallas_step import tropical_compose
    rng = np.random.default_rng(0)
    for shape in ((3, 7, 7), (2, 5, 12, 12)):
        a = rng.normal(size=shape) * 10
        b = rng.normal(size=shape) * 10
        a[rng.random(shape) < 0.3] = -np.inf
        b[rng.random(shape) < 0.3] = -np.inf
        cj, kj = tropical_compose(jax.numpy.asarray(b),
                                  jax.numpy.asarray(a), use_pallas=False)
        cp, kp = tropical_compose(jax.numpy.asarray(b),
                                  jax.numpy.asarray(a), use_pallas=True,
                                  interpret=True)
        np.testing.assert_array_equal(np.asarray(cp), np.asarray(cj))
        np.testing.assert_array_equal(np.asarray(kp), np.asarray(kj))


def test_pallas_end_to_end_smoke(cal_params):
    """Tiny grid through the assoc engine with the Pallas combine: must
    agree with the jnp-combine path exactly."""
    traces = [scal(128)]
    ref = api.simulate(traces, [OptConfig.baseline(), OptConfig.full()],
                       cal_params, backend="jax", method="assoc",
                       attribution=True)
    got = api.simulate(traces, [OptConfig.baseline(), OptConfig.full()],
                       cal_params, backend="jax", method="assoc",
                       attribution=True, use_pallas=True)
    np.testing.assert_array_equal(got.cycles, ref.cycles)
    np.testing.assert_array_equal(got.stalls, ref.stalls)


# --- property test: random traces -------------------------------------------

from trace_gen import build_trace, instr_tuples  # noqa: E402


@given(raw=instr_tuples())
@settings(max_examples=20, deadline=None)
def test_property_assoc_matches_numpy_random_traces(raw):
    """On arbitrary traces the assoc engine agrees with the numpy scan
    (float64-allclose) and keeps the exact attribution-sum invariant."""
    tr = build_trace(raw)
    corners = (OptConfig.baseline(), OptConfig.full(),
               OptConfig(True, False, True))
    ref = api.simulate([tr], corners, SimParams(),
                       backend="numpy", method="scan", attribution=True)
    got = api.simulate([tr], corners, SimParams(),
                       backend="jax", method="assoc", attribution=True)
    np.testing.assert_allclose(got.cycles, ref.cycles,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(got.ideal, ref.ideal,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(got.stalls, ref.stalls,
                               rtol=1e-7, atol=1e-6)
    total = got.ideal + got.stalls.sum(axis=-1)
    np.testing.assert_allclose(total, got.cycles, rtol=1e-12, atol=1e-6)
