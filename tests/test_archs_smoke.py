"""Per-architecture smoke tests (required by the brief): a REDUCED config of
the same family runs one forward + one train step on CPU, asserting output
shapes and the absence of NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import init_model, logits_fn, loss_fn
from repro.models.multimodal import make_batch
from repro.train import optimizer as opt
from repro.train.step import StepConfig, init_state, make_train_step

KEY = jax.random.PRNGKey(11)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_train_step(name):
    cfg = reduced(ARCHS[name])
    params = init_model(KEY, cfg)
    batch = make_batch(KEY, cfg, batch=2, seq=32)

    logits, _ = logits_fn(params, batch, cfg, mode="train")
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    step = make_train_step(cfg, StepConfig(
        adamw=opt.AdamWConfig(lr=1e-3)))
    state = init_state(params)
    state2, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # Params actually moved.
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert delta > 0


@pytest.mark.parametrize("name", ["glm4-9b", "mamba2-780m",
                                  "recurrentgemma-2b"])
def test_loss_decreases_quickly(name):
    """A few steps on a fixed batch must reduce loss (end-to-end gradient
    sanity for each model family)."""
    cfg = reduced(ARCHS[name])
    params = init_model(KEY, cfg)
    batch = make_batch(KEY, cfg, batch=2, seq=16)
    step = jax.jit(make_train_step(cfg, StepConfig(
        adamw=opt.AdamWConfig(lr=3e-3))))
    state = init_state(params)
    first = None
    for _ in range(5):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first - 0.05


def test_scan_layout_covers_all_layers():
    from repro.models.transformer import stack_layout
    for name, cfg in ARCHS.items():
        lead, n_rep, scan_kinds, tail = stack_layout(cfg)
        assert len(lead) + n_rep * len(scan_kinds) + len(tail) == \
            cfg.n_layers, name


def test_pattern_respected():
    cfg = ARCHS["gemma3-27b"]
    kinds = [cfg.mixer_at(i) for i in range(12)]
    assert kinds == ["local"] * 5 + ["attn"] + ["local"] * 5 + ["attn"]
    cfg = ARCHS["recurrentgemma-2b"]
    kinds = [cfg.mixer_at(i) for i in range(6)]
    assert kinds == ["rglru", "rglru", "local"] * 2


def test_deepseek_first_layer_dense():
    cfg = ARCHS["deepseek-v2-236b"]
    assert cfg.ffn_at(0) == "glu"
    assert cfg.ffn_at(1) == "moe"


def test_vlm_image_prefix_masked_in_loss():
    cfg = reduced(ARCHS["phi-3-vision-4.2b"])
    params = init_model(KEY, cfg)
    batch = make_batch(KEY, cfg, batch=2, seq=32)
    loss, metrics = loss_fn(params, batch, cfg)
    # n_img_tokens masked out of (2 x 32) targets:
    assert metrics["tokens"] == 2 * (32 - cfg.n_img_tokens)
