"""Batched sweep engine: parity with the scalar simulator + sweep cache."""
import numpy as np
import pytest

from repro.core import api
from repro.core.batch_sim import BatchAraSimulator, make_views
from repro.core.isa import ABLATION_GRID, OptConfig
from repro.core.simulator import AraSimulator, SimParams
from repro.core.traces import (DEFAULT_TRACES, PAD, axpy, dotp, scal,
                               stack_traces)
from repro.launch.sweep_cache import SweepCache, cell_key

ALL_CORNERS = (OptConfig.baseline(), *ABLATION_GRID)       # 2^3 corners


@pytest.fixture(scope="module")
def paper_traces():
    return {name: fn() for name, fn in DEFAULT_TRACES.items()}


@pytest.fixture(scope="module")
def scalar_grid(paper_traces):
    sim = AraSimulator()
    return {(name, opt.label): sim.run(tr, opt)
            for name, tr in paper_traces.items() for opt in ALL_CORNERS}


@pytest.fixture(scope="module")
def batch_grid(paper_traces):
    return api.simulate(list(paper_traces.values()), ALL_CORNERS,
                        backend="numpy")


def test_stack_traces_structure(paper_traces):
    traces = list(paper_traces.values())
    st = stack_traces(traces)
    assert st.batch == len(traces)
    assert st.max_instrs == max(len(t.instrs) for t in traces)
    for b, tr in enumerate(traces):
        n = int(st.n_instrs[b])
        assert n == len(tr.instrs)
        assert (st.kind[b, n:] == PAD).all()
        assert (st.dst[b, :n] != PAD).sum() == \
            sum(1 for i in tr.instrs if i.dst is not None)
        assert int(st.total_flops[b]) == tr.total_flops


def test_batch_matches_scalar_all_corners(paper_traces, scalar_grid,
                                          batch_grid):
    """Acceptance: every paper kernel x all 8 ablation corners within
    1e-6 relative of `AraSimulator.run` (numpy backend is bit-exact)."""
    for bi, name in enumerate(paper_traces):
        for oi, opt in enumerate(ALL_CORNERS):
            ref = scalar_grid[(name, opt.label)]
            got = batch_grid.cycles[bi, oi, 0]
            assert got == pytest.approx(ref.cycles, rel=1e-6), \
                (name, opt.label)
            assert batch_grid.busy_fpu[bi, oi, 0] == \
                pytest.approx(ref.busy_fpu, rel=1e-6, abs=1e-9)
            assert batch_grid.busy_bus[bi, oi, 0] == \
                pytest.approx(ref.busy_bus, rel=1e-6, abs=1e-9)
            assert batch_grid.gflops[bi, oi, 0] == \
                pytest.approx(ref.gflops, rel=1e-6)


def test_params_axis_matches_scalar():
    traces = [scal(512), axpy(512)]
    plist = [SimParams(), SimParams(mem_latency=90.0, issue_gap_base=5.0)]
    res = api.simulate(traces, [OptConfig.baseline(), OptConfig.full()],
                       plist, backend="numpy")
    for pi, params in enumerate(plist):
        sim = AraSimulator(params=params)
        for bi, tr in enumerate(traces):
            for oi, opt in enumerate((OptConfig.baseline(),
                                      OptConfig.full())):
                assert res.cycles[bi, oi, pi] == \
                    pytest.approx(sim.run(tr, opt).cycles, rel=1e-6)


def test_jax_backend_matches_numpy():
    traces = [scal(256), axpy(256), dotp(256)]
    bsim = BatchAraSimulator()
    st = stack_traces(traces)
    ref = api.simulate(st, ALL_CORNERS, backend="numpy", sim=bsim)
    got = api.simulate(st, ALL_CORNERS, backend="jax", sim=bsim)
    np.testing.assert_allclose(got.cycles, ref.cycles, rtol=1e-6)
    np.testing.assert_allclose(got.busy_fpu, ref.busy_fpu, rtol=1e-6)
    np.testing.assert_allclose(got.busy_bus, ref.busy_bus, rtol=1e-6)
    # Phase observables ride along on both backends, attribution or not.
    for field in ("lane_first_out", "first_first_out", "finish_start"):
        a, b = getattr(got, field), getattr(ref, field)
        assert a is not None and b is not None
        np.testing.assert_allclose(a, b, rtol=1e-6, err_msg=field)


def test_phase_observables_match_scalar_timings(paper_traces, batch_grid):
    """The batched phase observables equal what the scalar timings say:
    earliest lane first_out, instruction 0's first_out, finisher start."""
    from repro.core.isa import OpKind
    sim = AraSimulator()
    for bi, (name, tr) in enumerate(paper_traces.items()):
        for oi, opt in enumerate(ALL_CORNERS):
            res = sim.run(tr, opt)
            lane = [t.first_out for t, i in zip(res.timings, tr.instrs)
                    if i.kind not in (OpKind.LOAD, OpKind.STORE)]
            finisher = max(res.timings, key=lambda t: t.complete)
            assert batch_grid.first_first_out[bi, oi, 0] == \
                res.timings[0].first_out, (name, opt.label)
            assert batch_grid.finish_start[bi, oi, 0] == \
                finisher.start, (name, opt.label)
            got_lane = batch_grid.lane_first_out[bi, oi, 0]
            if lane:
                assert got_lane == min(lane), (name, opt.label)
            else:
                assert np.isinf(got_lane), (name, opt.label)


def test_speedup_vs_baseline(batch_grid):
    sp = batch_grid.speedup_vs(0)
    assert np.allclose(sp[:, 0, :], 1.0)
    full_col = len(ALL_CORNERS) - 1          # OptConfig.full() is last
    assert (sp[:, full_col, 0] >= 0.97).all()


def test_make_views_cross_order():
    opts = [OptConfig.baseline(), OptConfig.full()]
    plist = [SimParams(), SimParams(mem_latency=99.0)]
    v = make_views(opts, plist)
    assert v.width == 4                      # opt-major cells
    assert list(v.mem_latency) == [38.0, 99.0, 38.0, 99.0]
    assert list(v.opt_memory) == [False, False, True, True]


def test_attribution_parity_scalar_vs_batched():
    """Satellite contract: the batched attribution tensors equal the
    scalar simulator's stall accounting, and decompose cycles exactly."""
    traces = [scal(512), axpy(512), dotp(512)]
    plist = [SimParams(), SimParams(mem_latency=90.0, d_chain_base=20.0)]
    res = api.simulate(traces, ALL_CORNERS, plist, backend="numpy",
                       attribution=True)
    assert res.ideal.shape == res.cycles.shape
    assert res.stalls.shape == (*res.cycles.shape, 9)
    for pi, params in enumerate(plist):
        sim = AraSimulator(params=params)
        for bi, tr in enumerate(traces):
            for oi, opt in enumerate(ALL_CORNERS):
                ref = sim.run(tr, opt)
                assert res.cycles[bi, oi, pi] == ref.cycles
                np.testing.assert_allclose(res.ideal[bi, oi, pi], ref.ideal,
                                           rtol=1e-12, atol=1e-9)
                np.testing.assert_allclose(res.stalls[bi, oi, pi],
                                           ref.stalls, rtol=1e-12,
                                           atol=1e-9)
    gap = res.cycles - res.ideal - res.stalls.sum(axis=-1)
    assert np.abs(gap).max() <= 1e-6 + 1e-9 * res.cycles.max()


def test_attribution_off_by_default():
    res = api.simulate([scal(256)], [OptConfig.baseline()],
                       backend="numpy")
    assert res.ideal is None and res.stalls is None


# --- sweep cache ----------------------------------------------------------

def test_sweep_cache_hit_roundtrip(tmp_path):
    cache = SweepCache(tmp_path)
    tr = scal(256)
    sim = AraSimulator()
    res = sim.run(tr, OptConfig.full())
    key = cell_key(tr, OptConfig.full())
    assert cache.get_result(key, tr.name) is None
    assert cache.misses == 1
    cache.put_result(key, res)
    back = cache.get_result(key, tr.name)
    assert cache.hits == 1
    assert back.cycles == res.cycles
    assert back.flops == res.flops
    assert back.gflops == pytest.approx(res.gflops)


def test_cell_key_content_addressing(tmp_path):
    tr = scal(256)
    k1 = cell_key(tr, OptConfig.full())
    assert k1 == cell_key(scal(256), OptConfig.full())   # deterministic
    assert k1 != cell_key(scal(512), OptConfig.full())   # content-sensitive
    assert k1 != cell_key(tr, OptConfig.baseline())
    assert k1 != cell_key(tr, OptConfig.full(),
                          SimParams(mem_latency=39.0))


def test_cache_attribution_roundtrip(tmp_path):
    cache = SweepCache(tmp_path)
    tr = scal(256)
    res = AraSimulator().run(tr, OptConfig.full())
    assert res.stalls is not None
    key = cell_key(tr, OptConfig.full())
    cache.put_result(key, res)
    back = cache.get_result(key, tr.name, attribution=True)
    assert back is not None
    assert back.ideal == res.ideal
    np.testing.assert_array_equal(back.stalls, res.stalls)


def test_cache_attribution_miss_on_plain_cells(tmp_path):
    """Cells stored without stall vectors must not satisfy attribution
    reads — the consumer re-simulates with accounting on."""
    cache = SweepCache(tmp_path)
    key = "ab" + "0" * 62
    cache.put(key, {"cycles": 1.0, "flops": 1, "bytes": 1,
                    "busy_fpu": 0.0, "busy_bus": 0.0})
    assert cache.get_result(key, "scal") is not None
    assert cache.get_result(key, "scal", attribution=True) is None


def test_cache_prune_max_entries(tmp_path):
    import time
    cache = SweepCache(tmp_path)
    keys = [f"{i:02x}" + "0" * 62 for i in range(8)]
    for i, k in enumerate(keys):
        cache.put(k, {"i": i})
        os_mtime = tmp_path / k[:2] / f"{k}.json"
        os_mtime.touch()
        time.sleep(0.01)                   # distinct mtimes
    assert len(cache) == 8
    removed = cache.prune(max_entries=3)
    assert removed == 5
    assert len(cache) == 3
    # Newest three survive.
    for k in keys[-3:]:
        assert cache.get(k) is not None
    for k in keys[:5]:
        assert cache.get(k) is None


def test_cache_eviction_accounting(tmp_path):
    """Regression (PR 7): GC removals are counted, exposed via
    `evictions` and `stats()`, and hit/miss accounting survives the
    split between raw reads and classified lookups."""
    import time
    cache = SweepCache(tmp_path)
    keys = [f"{i:02x}" + "0" * 62 for i in range(6)]
    for k in keys:
        cache.put(k, {"x": 1})
        time.sleep(0.01)
    assert cache.evictions == 0
    removed = cache.prune(max_entries=2)
    assert removed == 4
    assert cache.evictions == 4
    assert cache.get(keys[0]) is None      # evicted -> miss
    assert cache.get(keys[-1]) is not None  # survivor -> hit
    s = cache.stats()
    assert s["hits"] == 1 and s["misses"] == 1
    assert s["evictions"] == 4
    assert s["hit_rate"] == pytest.approx(0.5)
    # Bounded instances count their auto-GC the same way.
    auto = SweepCache(tmp_path / "auto", max_entries=3)
    for i in range(6):
        auto.put(f"{i:02x}" + "1" * 62, {"x": 1})
        time.sleep(0.01)
    assert auto.evictions >= 1
    assert auto.evictions == auto.stats()["evictions"]


def test_cache_auto_gc_on_put(tmp_path):
    import time
    cache = SweepCache(tmp_path, max_entries=4)
    for i in range(10):
        cache.put(f"{i:02x}" + "0" * 62, {"i": i})
        time.sleep(0.01)
    assert len(cache) <= 4
    assert cache.get(f"{9:02x}" + "0" * 62) is not None   # newest kept


def test_cache_prune_max_entries_protects_keep_keys(tmp_path):
    import time
    cache = SweepCache(tmp_path)
    keys = [f"{i:02x}" + "0" * 62 for i in range(6)]
    for k in keys:
        cache.put(k, {"x": 1})
        time.sleep(0.01)
    # Oldest key is protected even though it would be evicted by age.
    cache.prune(keep_keys=[keys[0]], max_entries=2)
    assert cache.get(keys[0]) is not None
    assert cache.get(keys[-1]) is not None
    assert cache.get(keys[1]) is None


def test_cache_max_entries_enforced_across_instances(tmp_path):
    """A bounded instance must not trust its local count forever when
    another instance fills the same root."""
    bounded = SweepCache(tmp_path, max_entries=8)
    bounded.put("00" + "0" * 62, {"x": 1})        # arm the lazy counter
    other = SweepCache(tmp_path)
    for i in range(1, 200):
        other.put(f"{i:03x}" + "0" * 61, {"x": 1})
    assert len(bounded) > 8
    for i in range(200, 280):
        bounded.put(f"{i:03x}" + "0" * 61, {"x": 1})
    assert len(bounded) <= 8 + 64                 # resync window bound


def test_cache_prune_keep_keys(tmp_path):
    cache = SweepCache(tmp_path)
    keys = [f"{i:02x}" + "0" * 62 for i in range(4)]
    for k in keys:
        cache.put(k, {"x": 1})
    assert cache.prune(keep_keys=keys[:2]) == 2
    assert cache.get(keys[0]) is not None
    assert cache.get(keys[3]) is None
    assert cache.prune() == 2              # legacy full flush
    assert len(cache) == 0


def test_grid_attribution_cells(tmp_path):
    import pathlib
    import sys
    repo = str(pathlib.Path(__file__).resolve().parents[1])
    if repo not in sys.path:
        sys.path.insert(0, repo)
    from benchmarks import gridlib
    traces = {"scal": scal(256), "axpy": axpy(256)}
    opts = [OptConfig.baseline(), OptConfig.full()]
    cache = SweepCache(tmp_path)
    g1 = gridlib.Grid(params=SimParams(), cache=cache)
    # Plain cells first: stored without stall vectors...
    g1.cells(traces, opts)
    # ...so the attribution pass re-simulates and re-stores them.
    cells = g1.cells(traces, opts, attribution=True)
    sim = AraSimulator(params=SimParams())
    for (name, label), res in cells.items():
        opt = opts[0] if label == "base" else opts[1]
        ref = sim.run(traces[name], opt)
        assert res.stalls is not None
        np.testing.assert_allclose(res.stalls, ref.stalls,
                                   rtol=1e-12, atol=1e-9)
        assert res.ideal == pytest.approx(ref.ideal, rel=1e-12)
    # Second attribution read is served from the cache.
    g2 = gridlib.Grid(params=SimParams(), cache=SweepCache(tmp_path))
    cells2 = g2.cells(traces, opts, attribution=True)
    assert g2.cache.hits == 4 and g2.cache.misses == 0
    for k in cells:
        np.testing.assert_array_equal(cells2[k].stalls, cells[k].stalls)


def test_grid_uses_cache(tmp_path):
    import pathlib
    import sys
    repo = str(pathlib.Path(__file__).resolve().parents[1])
    if repo not in sys.path:
        sys.path.insert(0, repo)
    from benchmarks import gridlib
    traces = {"scal": scal(256), "dotp": dotp(256)}
    cache = SweepCache(tmp_path)
    g1 = gridlib.Grid(params=SimParams(), cache=cache)
    cells1 = g1.cells(traces, [OptConfig.baseline(), OptConfig.full()])
    assert cache.hits == 0
    g2 = gridlib.Grid(params=SimParams(), cache=SweepCache(tmp_path))
    cells2 = g2.cells(traces, [OptConfig.baseline(), OptConfig.full()])
    assert g2.cache.hits == 4 and g2.cache.misses == 0
    for k in cells1:
        assert cells2[k].cycles == cells1[k].cycles
    # Cached cells agree with the scalar simulator.
    sim = AraSimulator(params=SimParams())
    ref = sim.run(traces["scal"], OptConfig.full())
    assert cells2[("scal", OptConfig.full().label)].cycles == \
        pytest.approx(ref.cycles, rel=1e-6)
