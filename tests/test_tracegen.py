"""Generator determinism + intensity-classification properties.

Covers the PR-9 contracts: same seed => byte-identical trace, the
intensity class is a pure function of the op mix (stable under any
instruction reordering), and raising the compute share never lowers the
intensity class.  Plain parametrized tests keep the contracts enforced
in bare environments; hypothesis widens the spec coverage when the
`[test]` extra is installed.
"""
import sys
import pathlib

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))

from hypothesis_compat import given, settings  # noqa: E402

from repro.core import tracegen as G  # noqa: E402
from repro.core import roofline  # noqa: E402

from trace_gen import build_trace, gen_specs  # noqa: E402


# --- determinism ------------------------------------------------------------

@pytest.mark.parametrize("cls", G.CLASSES)
def test_same_seed_byte_identical(cls):
    spec = G.sample_spec(cls, seed=7, index=3)
    a, b = G.generate(spec), G.generate(spec)
    assert G.trace_bytes(a) == G.trace_bytes(b)
    assert a == b                          # frozen-dataclass deep equality


def test_different_seeds_differ():
    base = G.GenSpec(cls="fuzz", seed=0)
    other = G.GenSpec(cls="fuzz", seed=1)
    assert G.trace_bytes(G.generate(base)) != \
        G.trace_bytes(G.generate(other))


@pytest.mark.parametrize("cls", G.CLASSES)
def test_sample_spec_deterministic(cls):
    assert G.sample_spec(cls, seed=5, index=9) == \
        G.sample_spec(cls, seed=5, index=9)


def test_serialization_roundtrip():
    for cls in G.CLASSES:
        spec = G.sample_spec(cls, seed=2, index=0)
        tr = G.generate(spec)
        assert G.trace_from_dict(G.trace_to_dict(tr)) == tr
        assert G.spec_from_dict(G.spec_to_dict(spec)) == spec


def test_unknown_class_rejected():
    with pytest.raises(ValueError):
        G.generate(G.GenSpec(cls="nope"))
    with pytest.raises(ValueError):
        G.sample_spec("nope")


def test_max_instrs_cap_and_floor():
    for cls in G.CLASSES:
        tr = G.generate(G.GenSpec(cls=cls, seed=0, n=4096, max_instrs=24))
        assert 3 <= len(tr.instrs) <= 24, (cls, len(tr.instrs))


@given(spec=gen_specs(max_size=48))
@settings(max_examples=30, deadline=None)
def test_property_seed_determinism(spec):
    assert G.trace_bytes(G.generate(spec)) == \
        G.trace_bytes(build_trace(spec))


# --- classification ---------------------------------------------------------

def test_intensity_class_monotone_in_oi():
    """Walking operational intensity upward never walks the class back
    toward memory_bound."""
    ois = np.geomspace(1e-3, 1e3, 200)
    idx = [G.intensity_index(G.intensity_class(oi)) for oi in ois]
    assert all(b >= a for a, b in zip(idx, idx[1:]))
    assert G.intensity_class(0.01) == "memory_bound"
    ridge = roofline.ARA_PEAK_GFLOPS / roofline.ARA_PEAK_BW
    assert G.intensity_class(ridge) == "balanced"
    assert G.intensity_class(100 * ridge) == "compute_bound"


@pytest.mark.parametrize("cls", [c for c in G.CLASSES if c != "fuzz"])
def test_class_stable_under_reordering(cls):
    """Any instruction permutation that preserves the op mix preserves
    the intensity class (classification is a function of the totals)."""
    rng = np.random.default_rng(11)
    spec = G.sample_spec(cls, seed=4, index=1)
    tr = G.generate(spec)
    for _ in range(3):
        perm = rng.permutation(len(tr.instrs))
        shuffled = G.retotaled(tr, [tr.instrs[i] for i in perm])
        assert shuffled.total_flops == tr.total_flops
        assert shuffled.total_bytes == tr.total_bytes
        assert G.classify(shuffled) == G.classify(tr)


@pytest.mark.parametrize("cls", ["streaming", "reduction", "raw_chain",
                                 "compute_tile"])
def test_compute_share_monotonicity(cls):
    """Raising the compute share (more chains, deeper chains) never
    lowers the intensity class, spec-to-spec, when no truncation bites
    (ample max_instrs)."""
    import dataclasses
    base = dataclasses.replace(G.sample_spec(cls, seed=1, index=0),
                               max_instrs=4096)
    prev_idx, prev_oi = -1, -1.0
    for chains in (1, 2, 4, 8):
        spec = dataclasses.replace(base, compute_per_mem=chains)
        tr = G.generate(spec)
        oi = tr.operational_intensity
        idx = G.intensity_index(G.classify(tr))
        assert oi >= prev_oi - 1e-12, (cls, chains)
        assert idx >= prev_idx, (cls, chains)
        prev_idx, prev_oi = idx, oi


@given(spec=gen_specs(max_size=64))
@settings(max_examples=30, deadline=None)
def test_property_reorder_stability(spec):
    tr = build_trace(spec)
    rng = np.random.default_rng(spec.seed)
    perm = rng.permutation(len(tr.instrs))
    shuffled = G.retotaled(tr, [tr.instrs[i] for i in perm])
    assert G.classify(shuffled) == G.classify(tr)
