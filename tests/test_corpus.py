"""Golden-corpus regression suite: the committed scenario corpus is a
160-case extension of the parity tests.

For every committed scenario: the trace regenerates byte-identically
from its spec, numpy cycles are bit-exact against the committed golden
totals, ``ideal + sum(stalls) == cycles`` holds exactly, and the jax
scan backend agrees allclose on every scenario (the assoc engine on a
per-class sample — its D^2 working set makes the full corpus a
memory-hog on CPU CI, and per-class coverage already exercises every
structural shape).
"""
import pathlib
import sys

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))

from repro.core import api, tracegen  # noqa: E402
from repro.core.isa import OptConfig  # noqa: E402
from repro.core.simulator import SimParams  # noqa: E402
from repro.data import corpus  # noqa: E402

CORNERS = (OptConfig.baseline(), OptConfig.full())


@pytest.fixture(scope="module")
def scenarios():
    return corpus.load_scenarios()


@pytest.fixture(scope="module")
def numpy_batch(scenarios):
    """One batched numpy attribution pass over the whole corpus."""
    return api.simulate([s.trace for s in scenarios], list(CORNERS),
                        SimParams(), backend="numpy", method="scan",
                        bucket="none", attribution=True)


def test_corpus_shape(scenarios):
    manifest = corpus.load_manifest()
    assert manifest["n_scenarios"] == len(scenarios) >= 150
    classes = corpus.by_class(scenarios)
    assert len(classes) >= 8
    assert set(classes) == set(manifest["classes"])
    for cls, rows in classes.items():
        assert len(rows) == manifest["classes"][cls]
        assert all(s.name.startswith(cls) for s in rows)
    assert len({s.name for s in scenarios}) == len(scenarios)


def test_committed_traces_regenerate_byte_identical(scenarios):
    """Every committed instruction stream is exactly what its committed
    spec expands to — the corpus carries no hand-edited traces."""
    for s in scenarios:
        regen = tracegen.generate(s.spec)
        assert tracegen.trace_bytes(regen) == \
            tracegen.trace_bytes(s.trace), s.name


def test_committed_classification_consistent(scenarios):
    for s in scenarios:
        assert s.intensity == tracegen.classify(s.trace), s.name
        assert s.oi == pytest.approx(s.trace.operational_intensity,
                                     rel=1e-12)
        assert s.intensity in tracegen.INTENSITY_CLASSES


def test_numpy_golden_bit_exact(scenarios, numpy_batch):
    """numpy cycles/ideal/stalls match the committed goldens bit-for-bit
    at both corners."""
    for bi, s in enumerate(scenarios):
        for oi, opt in enumerate(CORNERS):
            exp = s.expected[opt.label]
            assert float(numpy_batch.cycles[bi, oi, 0]) == exp["cycles"], \
                (s.name, opt.label)
            assert float(numpy_batch.ideal[bi, oi, 0]) == exp["ideal"], \
                (s.name, opt.label)
            np.testing.assert_array_equal(
                numpy_batch.stalls[bi, oi, 0],
                np.asarray(exp["stalls"], np.float64),
                err_msg=f"{s.name} {opt.label}")


def test_attribution_invariant_exact(numpy_batch):
    """ideal + sum(stalls) == cycles, exactly, on every corpus cell."""
    total = numpy_batch.ideal + numpy_batch.stalls.sum(axis=-1)
    gap = np.abs(total - numpy_batch.cycles)
    assert gap.max() <= 1e-6 + 1e-9 * numpy_batch.cycles.max()


def test_full_opt_never_slower(numpy_batch):
    """M+C+O cycles <= baseline cycles on every generated workload —
    the paper's headline claim holds outside its own benchmarks."""
    assert (numpy_batch.cycles[:, 1, 0]
            <= numpy_batch.cycles[:, 0, 0] + 1e-9).all()


def test_jax_scan_allclose_full_corpus(scenarios):
    """jax lax.scan parity on every committed scenario (one compiled
    program, attribution carried through)."""
    got = api.simulate([s.trace for s in scenarios], list(CORNERS),
                       SimParams(), backend="jax", method="scan",
                       bucket="none", attribution=True)
    exp_cycles = np.array([[s.expected[o.label]["cycles"]
                            for o in CORNERS] for s in scenarios])
    exp_ideal = np.array([[s.expected[o.label]["ideal"]
                           for o in CORNERS] for s in scenarios])
    exp_stalls = np.array([[s.expected[o.label]["stalls"]
                            for o in CORNERS] for s in scenarios])
    np.testing.assert_allclose(got.cycles[:, :, 0], exp_cycles,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(got.ideal[:, :, 0], exp_ideal,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(got.stalls[:, :, 0], exp_stalls,
                               rtol=1e-7, atol=1e-6)


def test_jax_assoc_allclose_per_class_sample(scenarios):
    """Max-plus assoc-engine parity on the shortest scenario of every
    class (bounded D^2 memory; every structural shape covered)."""
    sample = [min(rows, key=lambda s: s.n_instrs)
              for rows in corpus.by_class(scenarios).values()]
    got = api.simulate([s.trace for s in sample], list(CORNERS),
                       SimParams(), backend="jax", method="assoc",
                       bucket="none", attribution=True)
    for bi, s in enumerate(sample):
        for oi, opt in enumerate(CORNERS):
            exp = s.expected[opt.label]
            assert float(got.cycles[bi, oi, 0]) == \
                pytest.approx(exp["cycles"], rel=1e-9, abs=1e-6), \
                (s.name, opt.label)
            np.testing.assert_allclose(
                got.stalls[bi, oi, 0],
                np.asarray(exp["stalls"], np.float64),
                rtol=1e-7, atol=1e-6, err_msg=f"{s.name} {opt.label}")


def test_corpus_through_bucketed_planner(scenarios):
    """The corpus is a genuinely mixed-length workload: the pow2
    planner buckets it, and bucketed results stay bit-exact (numpy)."""
    from repro.core import bucketing
    from repro.core.traces import stack_traces
    stacked = stack_traces([s.trace for s in scenarios])
    waste = bucketing.pad_waste_share(stacked)
    assert waste > 0.25, waste      # mixed lengths => real pad waste
    plain = api.simulate(stacked, list(CORNERS), SimParams(),
                         backend="numpy", bucket="none")
    bucketed = api.simulate(stacked, list(CORNERS), SimParams(),
                            backend="numpy", bucket="pow2")
    np.testing.assert_array_equal(bucketed.cycles, plain.cycles)
