"""Optimizer, data pipeline, checkpoint, compression: unit + property."""
import dataclasses
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS, reduced
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed import compression as comp
from repro.train import optimizer as opt

KEY = jax.random.PRNGKey(5)


# --- optimizer -----------------------------------------------------------------

def _numpy_adamw(params, grads, m, v, step, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * grads
    v = b2 * v + (1 - b2) * grads ** 2
    mh = m / (1 - b1 ** step)
    vh = v / (1 - b2 ** step)
    return params - lr * (mh / (np.sqrt(vh) + eps) + wd * params), m, v


def test_adamw_matches_numpy_reference():
    cfg = opt.AdamWConfig(lr=1e-2, clip_norm=1e9, weight_decay=0.1)
    p = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([[0.5, 0.5]])}
    g = {"w": jnp.array([0.1, 0.2, -0.3]), "b": jnp.array([[1.0, -1.0]])}
    state = opt.init(p)
    newp, state, _ = opt.update(g, state, p, cfg, cfg.lr)
    for k in p:
        ref, _, _ = _numpy_adamw(np.asarray(p[k]), np.asarray(g[k]),
                                 np.zeros_like(p[k]), np.zeros_like(p[k]),
                                 1, cfg.lr, cfg.b1, cfg.b2, cfg.eps,
                                 cfg.weight_decay)
        np.testing.assert_allclose(newp[k], ref, rtol=1e-5)


@given(scale=st.floats(0.1, 100.0))
@settings(max_examples=20, deadline=None)
def test_clip_by_global_norm_property(scale):
    g = {"a": scale * jnp.ones((10,)), "b": -scale * jnp.ones((5,))}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    out_norm = opt.global_norm(clipped)
    assert float(out_norm) <= 1.0 + 1e-4
    if float(norm) <= 1.0:                 # below threshold: untouched
        np.testing.assert_allclose(clipped["a"], g["a"], rtol=1e-6)


def test_cosine_schedule_shape():
    sched = opt.cosine_schedule(1e-3, warmup=10, total=100)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1e-3)
    assert float(sched(100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(sched(55)) < float(sched(20))


# --- data pipeline --------------------------------------------------------------

CFG = reduced(ARCHS["qwen2.5-3b"])


def test_stream_deterministic_and_seekable():
    a = SyntheticLM(CFG, batch=2, seq_len=16, seed=3)
    b1 = [next(a) for _ in range(5)]
    b = SyntheticLM(CFG, batch=2, seq_len=16, seed=3)
    b.restore({"step": 3, "seed": 3, "kind": "markov"})
    np.testing.assert_array_equal(b1[3]["tokens"], next(b)["tokens"])
    np.testing.assert_array_equal(b1[4]["tokens"], next(b)["tokens"])


def test_stream_targets_are_shifted_tokens():
    s = SyntheticLM(CFG, batch=2, seq_len=16, seed=0)
    batch = next(s)
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["targets"][:, :-1])


def test_host_sharding_disjoint():
    a = SyntheticLM(CFG, batch=2, seq_len=16, seed=3, process_index=0,
                    process_count=2)
    b = SyntheticLM(CFG, batch=2, seq_len=16, seed=3, process_index=1,
                    process_count=2)
    assert not np.array_equal(next(a)["tokens"], next(b)["tokens"])


def test_prefetcher_preserves_order():
    s = SyntheticLM(CFG, batch=1, seq_len=8, seed=1)
    expected = [next(SyntheticLM(CFG, batch=1, seq_len=8, seed=1))
                for _ in range(1)]
    pf = Prefetcher(SyntheticLM(CFG, batch=1, seq_len=8, seed=1), depth=3)
    try:
        got = [next(pf) for _ in range(4)]
        ref_src = SyntheticLM(CFG, batch=1, seq_len=8, seed=1)
        for g in got:
            np.testing.assert_array_equal(g["tokens"],
                                          next(ref_src)["tokens"])
    finally:
        pf.close()


def test_markov_stream_is_learnable_structure():
    """Bigram stream must have lower conditional entropy than uniform."""
    s = SyntheticLM(CFG, batch=8, seq_len=64, seed=2)
    batch = next(s)
    toks = np.asarray(batch["tokens"])
    v = CFG.vocab_size
    joint = np.zeros((v, v))
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            joint[a, b] += 1
    cond = joint / np.maximum(joint.sum(1, keepdims=True), 1)
    ent = -np.nansum(cond * np.log(np.where(cond > 0, cond, 1)), axis=1)
    assert ent[joint.sum(1) > 0].mean() < 0.9 * np.log(v)


# --- checkpoint -------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                       "c": jnp.array(7, jnp.int32)}}


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        for s in (10, 20, 30):
            mgr.save(s, _tree(), extra={"x": s})
        assert mgr.latest_step() == 30
        assert len(list(pathlib.Path(d).glob("step_*"))) == 2  # GC'd
        restored, extra = mgr.restore(None, _tree())
        assert extra["x"] == 30
        np.testing.assert_array_equal(restored["a"], _tree()["a"])
        assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_async_then_wait():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=True)
        mgr.save(5, _tree())
        mgr.wait()
        assert mgr.latest_step() == 5


def test_checkpoint_detects_corruption():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(1, _tree())
        victim = next(pathlib.Path(d).glob("step_*/a.npy"))
        raw = bytearray(victim.read_bytes())
        raw[-1] ^= 0xFF
        victim.write_bytes(bytes(raw))
        with pytest.raises(IOError):
            mgr.restore(1, _tree())


def test_checkpoint_atomic_no_partial_dirs():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(1, _tree())
        assert not list(pathlib.Path(d).glob(".tmp*"))


# --- compression ------------------------------------------------------------------

@given(scale=st.floats(1e-3, 1e3))
@settings(max_examples=25, deadline=None)
def test_quantize_error_bound(scale):
    """Blockwise int8: |err| <= scale_block/2 = max|x_block|/254 per elem."""
    x = scale * jax.random.normal(KEY, (1000,))
    q, s = comp.quantize(x)
    err = comp.quantization_error(x)
    bound = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(err))) <= bound * 0.51 + 1e-9


def test_dequantize_roundtrip_shape_dtype():
    x = jax.random.normal(KEY, (3, 77), jnp.float32)
    q, s = comp.quantize(x)
    y = comp.dequantize(q, s, x.shape, x.dtype)
    assert y.shape == x.shape and y.dtype == x.dtype
    assert float(jnp.max(jnp.abs(x - y))) < 0.02 * float(jnp.max(jnp.abs(x)))


def test_error_feedback_reduces_bias():
    """With error feedback, the time-average of dequantized values must
    converge to the true value (unbiased accumulation)."""
    x = 0.01 * jnp.ones((256,))            # tiny values: worst quant case
    err = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    for _ in range(50):
        q, s = comp.quantize(x + err)
        deq = comp.dequantize(q, s, x.shape, x.dtype)
        err = (x + err) - deq
        acc = acc + deq
    np.testing.assert_allclose(acc / 50, x, rtol=0.05)


def test_compressed_bytes_ratio():
    tree = {"w": jnp.zeros((1024, 1024))}
    raw, compressed = comp.compressed_bytes(tree)
    assert raw == 4 * 1024 * 1024
    assert compressed < raw / 3.5          # ~4x reduction
