"""Blocked GEMM with VMEM accumulator and fused epilogue.

TPU adaptation of the paper's operand-delivery optimization for the
high-arithmetic-intensity kernels (gemm/syrk/trsm):

* A and B tiles stream HBM->VMEM under the grid pipeline (next-VL prefetch:
  tile (i, j, k+1) is in flight while (i, j, k) multiplies on the MXU).
* The C tile lives in a VMEM scratch accumulator across the k-loop — the
  "dual-source operand queue": one operand source is the HBM stream (A/B),
  the other is the VMEM-resident accumulator, and the MXU result is
  *forwarded* back to the accumulator without an HBM round-trip.
* The epilogue (bias + activation + optional residual) is fused into the
  final k step, eliminating the separate elementwise kernels a baseline
  would launch (the produce->write-back->reread path).

Tile sizes default to 128x128x128 — MXU-native (128x128 systolic array),
8/128-aligned for f32 VMEM tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _apply_act(x, activation: str):
    if activation == "none":
        return x
    if activation == "relu":
        return jax.nn.relu(x)
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "silu":
        return jax.nn.silu(x)
    raise ValueError(activation)


def _gemm_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk, activation):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = _apply_act(acc_ref[...], activation).astype(o_ref.dtype)


def _gemm_bias_kernel(x_ref, y_ref, b_ref, o_ref, acc_ref, *, nk, activation):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        out = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_act(out, activation).astype(o_ref.dtype)


def gemm(x: jax.Array, y: jax.Array, bias: jax.Array | None = None,
         activation: str = "none", *, bm: int = 128, bn: int = 128,
         bk: int = 128, interpret: bool = True) -> jax.Array:
    """C = act(x @ y + bias) with MXU-tiled blocking.

    x: (M, K), y: (K, N), bias: (N,) or None.  M/N/K need not be multiples
    of the block sizes (Pallas masks the remainder blocks).
    """
    m, kdim = x.shape
    k2, n = y.shape
    assert kdim == k2, (x.shape, y.shape)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, kdim)
    # Pad to block multiples (zero-padding K is exact for the accumulation).
    mp, np_, kp = (-m % bm_), (-n % bn_), (-kdim % bk_)
    if mp or np_ or kp:
        x = jnp.pad(x, ((0, mp), (0, kp)))
        y = jnp.pad(y, ((0, kp), (0, np_)))
        if bias is not None:
            bias = jnp.pad(bias, (0, np_))
        out = gemm(x, y, bias, activation, bm=bm_, bn=bn_, bk=bk_,
                   interpret=interpret)
        return out[:m, :n]
    nk = pl.cdiv(kdim, bk_)
    grid = (pl.cdiv(m, bm_), pl.cdiv(n, bn_), nk)
    in_specs = [
        pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
    ]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn_), lambda i, j, kk: (0, j)))
        kernel = functools.partial(_gemm_bias_kernel, nk=nk,
                                   activation=activation)
        args = (x, y, bias.reshape(1, n))
    else:
        kernel = functools.partial(_gemm_kernel, nk=nk,
                                   activation=activation)
        args = (x, y)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(*args)


def gemm_unfused_epilogue(x: jax.Array, y: jax.Array, bias: jax.Array,
                          activation: str = "gelu", *,
                          interpret: bool = True, **kw) -> jax.Array:
    """Baseline operand path: GEMM kernel, then a separate bias+act kernel
    — the intermediate C round-trips HBM (write-back -> reread)."""
    c = gemm(x, y, None, "none", interpret=interpret, **kw)

    def _ep(c_ref, b_ref, o_ref):
        o_ref[...] = _apply_act(
            c_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32),
            activation).astype(o_ref.dtype)

    m, n = c.shape
    bm_, bn_ = min(128, m), min(512, n)
    grid = (pl.cdiv(m, bm_), pl.cdiv(n, bn_))
    return pl.pallas_call(
        _ep,
        grid=grid,
        in_specs=[pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
                  pl.BlockSpec((1, bn_), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(c, bias.reshape(1, n))


def gemm_flops_bytes(m: int, n: int, k: int, dtype=jnp.bfloat16,
                     fused_epilogue: bool = True) -> tuple[int, int]:
    """Napkin-math helper for §Perf: flops and minimum HBM bytes."""
    itemsize = jnp.dtype(dtype).itemsize
    flops = 2 * m * n * k
    io = (m * k + k * n + m * n) * itemsize
    if not fused_epilogue:
        io += 2 * m * n * itemsize          # C write-back + reread
    return flops, io
