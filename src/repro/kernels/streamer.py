"""Streaming element-wise chain kernels — the paper's Fig. 1 on a TPU.

The paper's exemplar chain ``vle32 -> vfmul -> vfadd -> vse32`` maps to the
TPU as a streaming kernel over HBM-resident vectors:

* **Baseline (paper's produce->write-back->reread path)**: one kernel per
  vector op.  The intermediate ``x*y`` round-trips through HBM between the
  mul kernel and the add kernel — exactly the VRF write-back/reread
  inefficiency of §IV.C, at HBM scale.

* **Ara-Opt analogue (multi-source forwarding + next-VL prefetch)**: a
  single fused kernel.  The Pallas grid pipeline prefetches block g+1 from
  HBM into VMEM while block g computes (next-VL prefetch; the BlockSpec
  index_map is the address-stream descriptor), and the mul result is
  forwarded to the add in VREGs without ever leaving the core (multi-source
  forwarding).

Block shape: (rows, lanes) with lanes a multiple of 128 (VPU lane width) and
rows a multiple of 8 (f32 sublane) — MXU/VPU-aligned VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (8, 512)


def _chain_kernel(x_ref, y_ref, w_ref, o_ref):
    # vfmul -> vfadd fused: the product stays in vector registers.
    o_ref[...] = x_ref[...] * y_ref[...] + w_ref[...]


def _mul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] * y_ref[...]


def _add_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


def _grid_and_specs(shape: tuple[int, int], block: tuple[int, int]):
    rows, cols = shape
    br, bc = block
    br, bc = min(br, rows), min(bc, cols)
    grid = (pl.cdiv(rows, br), pl.cdiv(cols, bc))
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return grid, spec


def _as2d(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    """Reshape an arbitrary array to 2-D (rows, 128k) for lane alignment."""
    orig = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = 128 if n % 128 == 0 else n
    return flat.reshape(n // cols, cols), orig


def fused_chain(x: jax.Array, y: jax.Array, w: jax.Array,
                block: tuple[int, int] = DEFAULT_BLOCK,
                interpret: bool = True) -> jax.Array:
    """out = x*y + w in ONE kernel (forwarding + prefetch)."""
    x2, orig = _as2d(x)
    y2, _ = _as2d(y)
    w2, _ = _as2d(w)
    grid, spec = _grid_and_specs(x2.shape, block)
    out = pl.pallas_call(
        _chain_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, y2, w2)
    return out.reshape(orig)


def unfused_chain(x: jax.Array, y: jax.Array, w: jax.Array,
                  block: tuple[int, int] = DEFAULT_BLOCK,
                  interpret: bool = True) -> jax.Array:
    """out = x*y + w as TWO kernels with an HBM round-trip between them —
    the baseline 'write-back then reread' operand path."""
    x2, orig = _as2d(x)
    y2, _ = _as2d(y)
    w2, _ = _as2d(w)
    grid, spec = _grid_and_specs(x2.shape, block)
    call = functools.partial(pl.pallas_call, grid=grid,
                             out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
                             interpret=interpret)
    t = call(_mul_kernel, in_specs=[spec, spec], out_specs=spec)(x2, y2)
    out = call(_add_kernel, in_specs=[spec, spec], out_specs=spec)(t, w2)
    return out.reshape(orig)


def axpy(alpha: jax.Array | float, x: jax.Array, y: jax.Array,
         block: tuple[int, int] = DEFAULT_BLOCK,
         interpret: bool = True) -> jax.Array:
    """alpha*x + y with alpha in SMEM-like scalar prefetch position."""
    x2, orig = _as2d(x)
    y2, _ = _as2d(y)
    grid, spec = _grid_and_specs(x2.shape, block)
    alpha_arr = jnp.asarray(alpha, x.dtype).reshape(1)
    out = pl.pallas_call(
        _axpy_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i, j: (0,)), spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(alpha_arr, x2, y2)
    return out.reshape(orig)


def hbm_roundtrip_bytes(shape: tuple[int, ...], dtype=jnp.float32,
                        fused: bool = True) -> int:
    """Analytic HBM traffic of the two variants — the M/O-term napkin math
    used in EXPERIMENTS.md §Perf (fused: 4 streams; unfused: 6 streams)."""
    n = 1
    for s in shape:
        n *= s
    itemsize = jnp.dtype(dtype).itemsize
    streams = 4 if fused else 6
    return streams * n * itemsize
