"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth a kernel is tested against
(tests/kernels/*): no tiling, no pipelining, numerically straightforward.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --- streamer ---------------------------------------------------------------

def chain_ref(x: jax.Array, y: jax.Array, w: jax.Array) -> jax.Array:
    """The paper's Fig. 1 chain vle->vfmul->vfadd->vse: out = x*y + w."""
    return x * y + w


def axpy_ref(alpha, x: jax.Array, y: jax.Array) -> jax.Array:
    return alpha * x + y


def scal_ref(alpha, x: jax.Array) -> jax.Array:
    return alpha * x


# --- gemm -------------------------------------------------------------------

def gemm_ref(x: jax.Array, y: jax.Array, bias: jax.Array | None = None,
             activation: str = "none") -> jax.Array:
    out = jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if activation == "relu":
        out = jax.nn.relu(out)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    elif activation == "silu":
        out = jax.nn.silu(out)
    elif activation != "none":
        raise ValueError(activation)
    return out.astype(x.dtype)


# --- attention --------------------------------------------------------------

def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array,
            causal: bool = True, scale: float | None = None,
            logit_softcap: float = 0.0) -> jax.Array:
    """Reference attention.  q: (B, Sq, H, D); k/v: (B, Skv, H, D)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_softcap > 0.0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    if causal:
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        mask = qi >= ki
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array | int | None = None,
                         scale: float | None = None) -> jax.Array:
    """Single-token decode attention.  q: (B, H, D); k/v: (B, S, H, D).
    Positions >= kv_len are masked (cache padding)."""
    b, s, h, d = k.shape
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if kv_len is not None:
        mask = jnp.arange(s)[None, None, :] < jnp.asarray(kv_len).reshape(-1, 1, 1)
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# --- Mamba-2 SSD ------------------------------------------------------------

def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array, h0: jax.Array | None = None
            ) -> tuple[jax.Array, jax.Array]:
    """Sequential state-space-duality scan (the semantics SSD computes).

    x : (L, H, P)   inputs per head
    dt: (L, H)      positive step sizes
    a : (H,)        negative scalar decay per head (A in Mamba-2)
    b : (L, G, N)   input projections (G groups; H % G == 0)
    c : (L, G, N)   output projections
    h0: (H, P, N)   optional initial state
    returns (y: (L, H, P), h_final: (H, P, N))
    """
    l, h, p = x.shape
    g, n = b.shape[1], b.shape[2]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=1)        # (L, H, N)
    ch = jnp.repeat(c, rep, axis=1)
    if h0 is None:
        h0 = jnp.zeros((h, p, n), jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp              # (H,P), (H,), (H,N), (H,N)
        decay = jnp.exp(a * dtt)           # (H,)
        dbx = jnp.einsum("hp,hn,h->hpn", xt.astype(jnp.float32),
                         bt.astype(jnp.float32), dtt.astype(jnp.float32))
        state = decay[:, None, None] * state + dbx
        yt = jnp.einsum("hpn,hn->hp", state, ct.astype(jnp.float32))
        return state, yt

    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                          (x, dt, bh, ch))
    return ys.astype(x.dtype), hT
