"""Pallas TPU kernels for the perf-critical compute paths, with pure-jnp
oracles (ref.py) and jit'd wrappers (ops.py)."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
