"""Public jit'd wrappers around the Pallas kernels.

Every wrapper auto-selects ``interpret=True`` off-TPU so the same call sites
run on this CPU container (validated against ref.py) and compile natively
on a real TPU.  Model code calls these; nothing else in the framework
imports pallas directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import gemm as _gemm
from repro.kernels import ssd as _ssd
from repro.kernels import streamer as _streamer


@functools.cache
def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# --- streamer ---------------------------------------------------------------

def fused_chain(x, y, w):
    return _streamer.fused_chain(x, y, w, interpret=_interpret_default())


def unfused_chain(x, y, w):
    return _streamer.unfused_chain(x, y, w, interpret=_interpret_default())


def axpy(alpha, x, y):
    return _streamer.axpy(alpha, x, y, interpret=_interpret_default())


# --- gemm -------------------------------------------------------------------

def gemm(x, y, bias=None, activation="none", **kw):
    return _gemm.gemm(x, y, bias, activation,
                      interpret=_interpret_default(), **kw)


def gemm_unfused_epilogue(x, y, bias, activation="gelu", **kw):
    return _gemm.gemm_unfused_epilogue(
        x, y, bias, activation, interpret=_interpret_default(), **kw)


# --- attention --------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, scale=None, logit_softcap=0.0,
                    bq=128, bkv=128):
    return _fa.flash_attention(
        q, k, v, causal=causal, scale=scale, logit_softcap=logit_softcap,
        bq=bq, bkv=bkv, interpret=_interpret_default())


def decode_attention(q, k, v, kv_len=None, *, scale=None, bkv=512):
    return _dec.decode_attention(q, k, v, kv_len, scale=scale, bkv=bkv,
                                 interpret=_interpret_default())


def gqa_decode(q, k, v, kv_len=None, **kw):
    """GQA decode: q (B, Hq, D), k/v (B, S, Hkv, D) with Hq % Hkv == 0."""
    b, hq, d = q.shape
    hkv = k.shape[2]
    groups = hq // hkv
    kf = jnp.repeat(k, groups, axis=2)
    vf = jnp.repeat(v, groups, axis=2)
    return decode_attention(q, kf, vf, kv_len, **kw)


# --- ssd --------------------------------------------------------------------

def ssd(x, dt, a, b, c, *, chunk=128):
    return _ssd.ssd(x, dt, a, b, c, chunk=chunk,
                    interpret=_interpret_default())


def ssd_batched(x, dt, a, b, c, *, chunk=128):
    """Batched SSD: x (B, L, H, P), dt (B, L, H), a (H,), b/c (B, L, G, N).
    Expands groups, folds (B, H) into the kernel's program axis."""
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, l, p)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, l)
    af = jnp.tile(a, bsz)
    bf = jnp.repeat(b, rep, axis=2).transpose(0, 2, 1, 3).reshape(
        bsz * h, l, n)
    cf = jnp.repeat(c, rep, axis=2).transpose(0, 2, 1, 3).reshape(
        bsz * h, l, n)
    y, hT = ssd(xf, dtf, af, bf, cf, chunk=chunk)
    y = y.reshape(bsz, h, l, p).transpose(0, 2, 1, 3)
    return y, hT.reshape(bsz, h, n, p)
