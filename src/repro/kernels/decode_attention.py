"""Split-KV flash-decode — multi-lane parallelism + tail combine.

Decode attention (one query token vs. a long KV cache) has no query-axis
parallelism, so the kernel splits the KV sequence across grid "lanes"
(KV chunks), each producing a partial (m, l, o) triple, then drains a
one-time combine tail — prologue / steady-state / tail exactly as the
paper's chaining model decomposes it (§II.C).  On a real v5e the chunks map
to parallel cores/megacore; sequence-sharded decode across chips reuses the
same combine algebra via shard_map (distributed/context_parallel.py).

q: (B, H, D); k/v: (B, S, H, D) -> out (B, H, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _partial_kernel(q_ref, k_ref, v_ref, kvlen_ref, m_ref, l_ref, o_ref, *,
                    bkv: int, scale: float):
    chunk = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (H, D)
    k = k_ref[0].astype(jnp.float32)                  # (H, bkv, D)
    v = v_ref[0].astype(jnp.float32)                  # (H, bkv, D)
    # Per-head scores: (H, bkv) = q (H, D) . k (H, bkv, D).
    s = jax.lax.dot_general(q, k, (((1,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    pos = chunk * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < kvlen_ref[0]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)             # (H, 1)
    # Guard fully-masked chunks (exp would be exp(NEG_INF - NEG_INF)).
    safe_m = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.where(valid, jnp.exp(s - safe_m), 0.0)
    l = jnp.sum(p, axis=1, keepdims=True)
    o = jax.lax.dot_general(p, v, (((1,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)  # (H, D)
    m_ref[0, 0] = m
    l_ref[0, 0] = l
    o_ref[0, 0] = o


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array | int | None = None, *,
                     scale: float | None = None, bkv: int = 512,
                     interpret: bool = True) -> jax.Array:
    """Flash-decode: parallel partials over KV chunks + combine tail."""
    b, h, d = q.shape
    _, s, hk, _ = k.shape
    assert hk == h, "fold GQA groups before calling (see ops.gqa_decode)"
    if scale is None:
        scale = d ** -0.5
    if kv_len is None:
        kv_len = jnp.full((b,), s, jnp.int32)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))

    bkv_ = min(bkv, s)
    nchunks = pl.cdiv(s, bkv_)
    pad = nchunks * bkv_ - s
    if pad:
        # Zero-pad to a block multiple: padded positions are masked by the
        # kv_len test (zeros, not interpret-mode NaNs, so 0*pad stays 0).
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    kf = k.transpose(0, 2, 1, 3)                       # (B, H, S, D)
    vf = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_partial_kernel, bkv=bkv_, scale=scale)
    m, l, o = pl.pallas_call(
        kernel,
        grid=(b, nchunks),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            # One KV chunk per grid step, all heads.
            pl.BlockSpec((1, h, bkv_, d), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, h, bkv_, d), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, h, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, h, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, h, d), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nchunks, h, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, nchunks, h, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, nchunks, h, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, kf, vf, kv_len)
    return combine_partials(m, l, o).astype(q.dtype)


def combine_partials(m: jax.Array, l: jax.Array, o: jax.Array) -> jax.Array:
    """Tail drain: renormalize and merge per-chunk partial softmax triples.

    m/l: (B, C, H, 1), o: (B, C, H, D).  The same algebra combines
    sequence-sharded partials across chips (psum form) — see
    distributed/context_parallel.py.
    """
    m_g = jnp.max(m, axis=1, keepdims=True)            # (B, 1, H, 1)
    w = jnp.exp(m - m_g)                               # (B, C, H, 1)
    l_g = jnp.sum(l * w, axis=1)                       # (B, H, 1)
    o_g = jnp.sum(o * w, axis=1)                       # (B, H, D)
    return o_g / jnp.maximum(l_g, 1e-30)
