"""Flash attention (prefill) — streaming softmax as multi-lane chaining.

The softmax-attention chain QK^T -> softmax -> PV is the framework's
archetype of the paper's dependent instruction chain: the online-softmax
recurrence lets the PV "instruction" chain off the QK "instruction" one KV
block at a time instead of waiting for the full score matrix — the same
first-results-available overlap as vector chaining, with KV blocks playing
the role of element groups.

VMEM residency: running (m, l, acc) statistics are the dual-source operand
queue — one source is the HBM KV stream, the other the VMEM-resident
accumulator; neither round-trips HBM (§IV.C's write-back/reread path is what
a naive attention does when it materializes S = QK^T).

Grid: (batch*heads, q_blocks, kv_blocks); kv is the innermost (sequential)
axis so the scratch carries across kv steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  nkv: int, bq: int, bkv: int, causal: bool, scale: float,
                  q_offset: int, logit_softcap: float):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bkv, d)
        v = v_ref[0].astype(jnp.float32)              # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        if causal:
            rows = q_idx * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 0) + q_offset
            cols = kv_idx * bkv + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                           # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # Skip fully-masked KV blocks (block-level early-exit: the
        # "dynamic local issue" analogue — don't occupy the unit with work
        # that cannot contribute).
        first_row = q_idx * bq + q_offset
        pl.when((kv_idx * bkv) <= (first_row + bq - 1))(_compute)
    else:
        _compute()

    @pl.when(kv_idx == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    logit_softcap: float = 0.0, bq: int = 128,
                    bkv: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) with H % Hkv == 0 (GQA: q
    heads are folded onto their kv head).  Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    assert h % hkv == 0
    groups = h // hkv
    if scale is None:
        scale = d ** -0.5
    # Fold batch/head; replicate kv heads across their query group.
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), groups, axis=1
                    ).reshape(b * h, skv, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), groups, axis=1
                    ).reshape(b * h, skv, d)

    bq_ = min(bq, sq)
    bkv_ = min(bkv, skv)
    nq = pl.cdiv(sq, bq_)
    nkv = pl.cdiv(skv, bkv_)
    q_offset = skv - sq if causal else 0

    kernel = functools.partial(
        _flash_kernel, nkv=nkv, bq=bq_, bkv=bkv_, causal=causal,
        scale=scale, q_offset=q_offset, logit_softcap=logit_softcap)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bkv_, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bkv_, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),   # running max
            pltpu.VMEM((bq_, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq_, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def attention_flops_bytes(b, sq, skv, h, d, dtype=jnp.bfloat16,
                          flash: bool = True) -> tuple[int, int]:
    """Napkin math for §Perf: naive attention materializes S and P
    (2*b*h*sq*skv extra reads+writes each)."""
    itemsize = jnp.dtype(dtype).itemsize
    flops = 4 * b * h * sq * skv * d
    io = (b * sq * h * d * 2 + b * skv * h * d * 2) * itemsize
    if not flash:
        io += 4 * b * h * sq * skv * itemsize
    return flops, io
