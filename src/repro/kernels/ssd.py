"""Mamba-2 SSD (state-space duality) chunked kernel.

SSD *is* the paper's chaining model applied to a recurrence: the sequence is
strip-mined into chunks (element groups); within a chunk the computation is
a dense, MXU-friendly "steady state" (causal-masked C B^T attention-like
matmuls); across chunks a small (P x N) state is carried — the chained
operand that lets chunk g+1 start from chunk g's first results without
re-reading the sequence.  The state lives in VMEM scratch across grid steps
(never round-trips HBM): multi-source forwarding for the recurrence.

Per (batch*head) program, grid axis 1 walks chunks sequentially:

  within chunk (steady state):
      L[t,s]   = exp(cum_a[t] - cum_a[s]) * (t >= s)
      y_intra  = ((C K^T) .* L) @ (dt * x)
  across chunks (chaining):
      y_inter  = exp(cum_a[t]) * (C @ h_prev)
      h_new    = exp(total_a) * h_prev + K^T_decayed @ (dt * x)

Shapes: x (BH, L, P), dt (BH, L, 1), a (BH, 1, 1) scalar decay, b/c
(BH, L, N).  GQA-style groups are expanded by the ops wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref,
                *, nchunks: int, bl: int):
    # NB: pallas passes refs as (inputs..., outputs..., scratch...): the
    # carried state h_ref is the trailing scratch.
    chunk = pl.program_id(1)

    @pl.when(chunk == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # (bl, P)
    dt = dt_ref[0].astype(jnp.float32)        # (bl, 1)
    a = a_ref[0, 0, 0].astype(jnp.float32)    # scalar (negative)
    bmat = b_ref[0].astype(jnp.float32)       # (bl, N)
    cmat = c_ref[0].astype(jnp.float32)       # (bl, N)

    adt = a * dt                              # (bl, 1)
    cum = jnp.cumsum(adt, axis=0)             # (bl, 1) inclusive
    seg = cum - adt                           # exclusive cumsum
    total = cum[bl - 1, 0]                    # sum over chunk

    # Intra-chunk: causal decay mask L[t, s] = exp(cum[t] - cum[s]), t>=s.
    lmask = jnp.exp(cum - cum.T)              # (bl, bl) via broadcast
    rows = jax.lax.broadcasted_iota(jnp.int32, (bl, bl), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bl, bl), 1)
    lmask = jnp.where(rows >= cols, lmask, 0.0)
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    dtx = dt * x                              # (bl, P)
    y_intra = jax.lax.dot((scores * lmask), dtx,
                          preferred_element_type=jnp.float32)

    # Inter-chunk: contribution of the carried state.
    h = h_ref[...]                            # (N, P)
    y_inter = jnp.exp(cum) * jax.lax.dot(cmat, h,
                                         preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # State update: decay-weighted chunk summary + decayed previous state.
    decay_to_end = jnp.exp(total - cum)       # (bl, 1)
    bw = bmat * decay_to_end                  # (bl, N)
    h_new = jnp.exp(total) * h + jax.lax.dot_general(
        bw, dtx, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (N, P)
    h_ref[...] = h_new

    @pl.when(chunk == nchunks - 1)
    def _emit_state():
        hout_ref[0] = h_new


def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
        c: jax.Array, *, chunk: int = 128,
        interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (BH, L, P); dt: (BH, L); a: (BH,); b/c: (BH, L, N).
    Returns (y: (BH, L, P), h_final: (BH, N, P)).  L % chunk == 0 is
    required (pad upstream); chunk should be a multiple of 8.
    """
    bh, l, p = x.shape
    n = b.shape[-1]
    bl = min(chunk, l)
    assert l % bl == 0, (l, bl)
    nchunks = l // bl
    dt3 = dt.reshape(bh, l, 1)
    a3 = a.reshape(bh, 1, 1)

    kernel = functools.partial(_ssd_kernel, nchunks=nchunks, bl=bl)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(bh, nchunks),
        in_specs=[
            pl.BlockSpec((1, bl, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bl, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bl, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bl, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bl, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, p), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, l, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt3, a3, b, c)
    return y, h_final
