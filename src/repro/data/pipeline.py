"""Data pipeline: deterministic synthetic streams + prefetching.

The host-side prefetch queue is the data-layer instance of the paper's
next-VL prefetch: batch g+1 is materialized (and, on real hardware,
host->device transferred) while step g computes, so the accelerator's
"memory-side data supply" never gaps.  `state()`/`restore()` make the
stream exactly resumable from a checkpoint (fault tolerance).

Sources:
  * "uniform" — i.i.d. tokens (loss floor = ln V; shape/scale testing).
  * "markov"  — a fixed random bigram chain (learnable; training demos and
    convergence tests).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticLM:
    """Deterministic, seekable token stream sharded across hosts."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, kind: str = "markov",
                 process_index: int = 0, process_count: int = 1):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.kind = kind
        self.pidx = process_index
        self.pcount = process_count
        self._step = 0
        if kind == "markov":
            rng = np.random.default_rng(seed)
            v = cfg.vocab_size
            logits = rng.standard_normal((v, v)) * 2.0
            self._trans = np.exp(logits - logits.max(1, keepdims=True))
            self._trans /= self._trans.sum(1, keepdims=True)
            self._cum = np.cumsum(self._trans, axis=1)

    # -- resumability -----------------------------------------------------
    def state(self) -> dict:
        return {"step": self._step, "seed": self.seed, "kind": self.kind}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.seed and state["kind"] == self.kind, \
            "restoring a checkpoint from a different data configuration"
        self._step = int(state["step"])

    # -- generation ---------------------------------------------------------
    def _gen(self, step: int) -> dict:
        # Each (step, host) pair is an independent deterministic stream —
        # hosts never overlap (disjoint shards of the global batch).
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.pidx)
        v = self.cfg.vocab_size
        b, s = self.batch, self.seq
        if self.kind == "uniform":
            toks = rng.integers(0, v, size=(b, s + 1), dtype=np.int32)
        else:
            toks = np.empty((b, s + 1), np.int32)
            toks[:, 0] = rng.integers(0, v, size=b)
            u = rng.random((b, s))
            for t in range(s):
                rows = self._cum[toks[:, t]]               # (b, v)
                toks[:, t + 1] = (rows < u[:, t, None]).sum(axis=1)
                np.clip(toks[:, t + 1], 0, v - 1, out=toks[:, t + 1])
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if self.cfg.modality == "audio":
            frames = rng.standard_normal((b, s, self.cfg.d_model)) * 0.02
            batch = {"frames": frames.astype(np.float32),
                     "targets": toks[:, 1:]}
        elif self.cfg.modality == "vlm":
            img = rng.standard_normal(
                (b, self.cfg.n_img_tokens, self.cfg.d_model)) * 0.02
            batch["img_embeds"] = img.astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        out = self._gen(self._step)
        self._step += 1
        return out


class Prefetcher:
    """Depth-k background prefetch queue (next-VL prefetch, data layer)."""

    def __init__(self, source: SyntheticLM, depth: int = 2):
        self.source = source
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        while not self._stop.is_set():
            try:
                item = next(self.source)
            except StopIteration:                     # pragma: no cover
                break
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __next__(self) -> dict:
        return self._q.get()

    def __iter__(self):
        return self

    def state(self) -> dict:
        # Unconsumed prefetched batches are replayed after restore.
        return {"step": self.source._step - self._q.qsize(),
                "seed": self.source.seed, "kind": self.source.kind}

    def close(self):
        self._stop.set()
