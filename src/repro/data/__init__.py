"""Workload data: the committed generated-scenario corpus and the
calibration pipeline inputs (docs/workloads.md)."""
