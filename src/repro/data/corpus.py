"""Committed scenario corpus: load / persist generated RVV workloads.

The corpus under ``src/repro/data/corpus/`` is the workload frontier of
ROADMAP item 3: ~160 generated scenarios across the workload classes of
`repro.core.tracegen`, each committed with its full instruction stream,
its arithmetic-intensity class, and golden per-corner simulation totals
(numpy backend, default `SimParams`, baseline and M+C+O corners).

Wire format (diff-friendly, byte-deterministic):

* ``<class>.jsonl`` — one scenario per line, ``json.dumps(...,
  sort_keys=True, separators=(",", ":"))`` of `scenario_to_dict`;
* ``manifest.json`` — seed, per-class counts, format version.

`tools/gen_corpus.py` regenerates the tree (``--check`` byte-diffs a
fresh regeneration against the committed files in CI);
`tests/test_corpus.py` re-simulates every scenario and holds the golden
totals bit-exact.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Mapping, Sequence

from repro.core import tracegen
from repro.core.isa import KernelTrace

__all__ = [
    "CORPUS_DIR", "FORMAT_VERSION", "Scenario", "scenario_to_dict",
    "scenario_from_dict", "dump_corpus", "load_manifest",
    "load_scenarios", "corpus_traces", "by_class",
]

#: Committed corpus location (inside the package, next to this module).
CORPUS_DIR = pathlib.Path(__file__).resolve().parent / "corpus"

FORMAT_VERSION = 1

#: Ablation corners the golden totals cover, keyed by `OptConfig.label`.
EXPECTED_CORNERS = ("base", "M+C+O")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One committed workload: spec + expanded trace + golden totals.

    ``expected`` maps an ablation-corner label to ``{"cycles": float,
    "ideal": float, "stalls": [9 floats]}`` — numpy-backend totals at
    default `SimParams`, held bit-exact by `tests/test_corpus.py`.
    """
    name: str
    cls: str
    spec: tracegen.GenSpec
    trace: KernelTrace
    intensity: str
    oi: float
    expected: Mapping[str, Mapping]

    @property
    def n_instrs(self) -> int:
        return len(self.trace.instrs)


def scenario_to_dict(s: Scenario) -> dict:
    return {
        "name": s.name,
        "cls": s.cls,
        "spec": tracegen.spec_to_dict(s.spec),
        "trace": tracegen.trace_to_dict(s.trace),
        "intensity": s.intensity,
        "oi": s.oi,
        "expected": {k: dict(v) for k, v in s.expected.items()},
    }


def scenario_from_dict(d: dict) -> Scenario:
    return Scenario(
        name=d["name"], cls=d["cls"],
        spec=tracegen.spec_from_dict(d["spec"]),
        trace=tracegen.trace_from_dict(d["trace"]),
        intensity=d["intensity"], oi=float(d["oi"]),
        expected=d["expected"])


def _scenario_line(s: Scenario) -> str:
    return json.dumps(scenario_to_dict(s), sort_keys=True,
                      separators=(",", ":"))


def dump_corpus(scenarios: Sequence[Scenario], root: pathlib.Path,
                seed: int) -> dict:
    """Write the per-class ``.jsonl`` files plus ``manifest.json`` under
    `root`; returns the manifest payload.  Output is a pure function of
    the scenario list, so regenerating from the same seed byte-matches."""
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    classes: dict[str, list[Scenario]] = {}
    for s in scenarios:
        classes.setdefault(s.cls, []).append(s)
    for cls, rows in sorted(classes.items()):
        text = "\n".join(_scenario_line(s) for s in rows) + "\n"
        (root / f"{cls}.jsonl").write_text(text)
    manifest = {
        "format": FORMAT_VERSION,
        "seed": seed,
        "params": "SimParams() defaults",
        "corners": list(EXPECTED_CORNERS),
        "classes": {cls: len(rows)
                    for cls, rows in sorted(classes.items())},
        "n_scenarios": len(scenarios),
    }
    (root / "manifest.json").write_text(
        json.dumps(manifest, sort_keys=True, indent=1) + "\n")
    return manifest


def load_manifest(root: pathlib.Path = CORPUS_DIR) -> dict:
    return json.loads((pathlib.Path(root) / "manifest.json").read_text())


def load_scenarios(classes: Iterable[str] | None = None,
                   per_class: int | None = None,
                   root: pathlib.Path = CORPUS_DIR) -> list[Scenario]:
    """Load committed scenarios, manifest class order, optionally
    filtered to `classes` and truncated to the first `per_class` of each
    (the smoke profile's budget)."""
    root = pathlib.Path(root)
    manifest = load_manifest(root)
    wanted = list(classes) if classes is not None \
        else sorted(manifest["classes"])
    out: list[Scenario] = []
    for cls in wanted:
        path = root / f"{cls}.jsonl"
        if not path.exists():
            raise FileNotFoundError(
                f"corpus class file missing: {path} "
                f"(regenerate with tools/gen_corpus.py)")
        rows = [scenario_from_dict(json.loads(line))
                for line in path.read_text().splitlines() if line]
        out.extend(rows[:per_class] if per_class is not None else rows)
    return out


def corpus_traces(classes: Iterable[str] | None = None,
                  per_class: int | None = None,
                  root: pathlib.Path = CORPUS_DIR
                  ) -> dict[str, KernelTrace]:
    """Scenario-name -> trace mapping, shaped for `gridlib.Grid.cells`."""
    return {s.name: s.trace
            for s in load_scenarios(classes, per_class, root)}


def by_class(scenarios: Sequence[Scenario]
             ) -> dict[str, list[Scenario]]:
    out: dict[str, list[Scenario]] = {}
    for s in scenarios:
        out.setdefault(s.cls, []).append(s)
    return out
