"""Attribution-guided design-space search: the simulator, inverted.

The paper hand-picks three coordinated optimizations (M/C/O) at one
strength each and evaluates eight corners.  This module *discovers*
designs instead: it searches the widened space of opt-class flags x
continuous strength knobs (`repro.launch.costmodel.SEARCH_SPACE`),
maximizing a throughput objective subject to a hardware cost bound
(`costmodel.design_cost`, anchored to Table II), and returns a Pareto
frontier of score vs. cost instead of a single Ara-Opt point.

Everything the earlier PRs built feeds the loop:

* **batched population scoring** — every generation's new candidates
  are grouped by opt corner and scored through
  `repro.core.api.simulate_groups`: one shared trace stack, one
  batched `(trace x corner x candidates)` call per corner, never a
  per-candidate scalar simulation (asserted via obs metrics in
  `tests/test_design_search.py`);
* **attribution-guided mutation** — each scored design carries the
  stall tensors' binding critical path aggregated over the evaluation
  set, and mutations bias knob proposals toward the knobs acting on
  that path (`sensitivity.KNOB_PATHS`), or toward enabling the class
  whose hardware addresses it;
* **Sobol-informed co-moves** — a Saltelli design over the strength
  space (`sensitivity.sobol_design`) is scored once up front, and the
  total-minus-first-order interaction masses pick knob *pairs* to
  mutate jointly (`sensitivity.co_move_pairs`);
* **the scenario corpus as evaluation set** — ``eval_set="corpus"``
  scores candidates on the committed 160-scenario corpus (budgeted per
  class like `benchmarks.gridlib`), with per-class gap-closed columns
  in every frontier record; ``eval_set="grid"`` scores on the
  calibration grid the recorded 1.29 geomean lives on.

Algorithms: ``evolve`` (elitist evolutionary loop, crossover +
mutation), ``beam`` (top-k frontier expansion), ``random`` (multi-seed
LHS restarts), ``chain`` (width-1 beam — the hillclimb CLI's mode).
All are seed-deterministic: same seed -> identical search log and
frontier (tested).

Artifacts: `benchmarks/fig9_search.py` runs the canonical budget and
commits `experiments/search/pareto.json`; docs/search.md documents the
objective/constraint vocabulary.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import random
from typing import Mapping, Sequence

import numpy as np

from repro.core import api
from repro.core.batch_sim import BatchAraSimulator
from repro.core.calibration import grid_traces, load as load_calibrated
from repro.core.isa import KernelTrace, OptConfig, geomean
from repro.core.simulator import SimParams
from repro.core.stalls import PATH_NAMES, path_sums
from repro.core.traces import stack_traces
from repro.launch.costmodel import (SEARCH_SPACE, SPACE_BY_NAME,
                                    design_cost)
from repro.launch.sensitivity import (KNOB_PATHS, co_move_pairs,
                                      sobol_design, sobol_indices)
from repro.launch.sweep_cache import design_fingerprint
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

__all__ = [
    "DesignPoint", "ScoredDesign", "SearchResult", "make_design",
    "baseline_design", "ara_opt_design", "paper_corners",
    "PopulationScorer", "pareto_front", "dominates", "run_search",
    "frontier_payload", "write_pareto", "check_committed",
    "CANONICAL_BUDGET", "PARETO_PATH", "ALGORITHMS", "OBJECTIVES",
]

_REPO = pathlib.Path(__file__).resolve().parents[3]
PARETO_PATH = _REPO / "experiments" / "search" / "pareto.json"

ALGORITHMS = ("evolve", "beam", "random", "chain")
OBJECTIVES = ("speedup", "gap_closed")

#: Class whose hardware addresses each critical path — the flag-flip
#: bias of attribution-guided mutation.
PATH_CLASS = {"mem_supply": "M", "dep_issue": "C", "operand": "O"}

#: Geomean-gap objective floor: gap-closed is negative for designs
#: slower than baseline, so the geomean aggregates the clamped value
#: (raw per-trace/per-class means are still reported unclamped).
GAP_FLOOR = 1e-3

#: The committed-frontier budget (`experiments/search/pareto.json`):
#: small enough for the CI smoke job to regenerate, large enough that
#: the evolved best beats the injected Ara-Opt corner.  fig9's full
#: profile scales generations/population up from here.
CANONICAL_BUDGET = dict(
    algorithm="evolve", objective="speedup", eval_set="corpus",
    per_class=2, seed=0, generations=4, population=14, beam_width=4,
    branch=4, restarts=3, sobol_n=8,
)


# -- the design space ------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One candidate design: M/C/O flags + enabled-class strengths.

    ``strengths`` holds only the knobs of *enabled* classes (absent
    hardware has no knobs), name-sorted and bound-clipped — the
    canonical form, so two routes to the same design hash identically
    (`key`) and the evaluated-archive never re-scores a repeat.
    """
    memory: bool
    control: bool
    operand: bool
    strengths: tuple[tuple[str, float], ...]

    @property
    def opt(self) -> OptConfig:
        return OptConfig(self.memory, self.control, self.operand)

    @property
    def label(self) -> str:
        return self.opt.label

    def enabled(self, cls: str) -> bool:
        return {"M": self.memory, "C": self.control,
                "O": self.operand}[cls]

    def params(self, center: SimParams) -> SimParams:
        """Concrete `SimParams`: the center's (calibrated) baseline-side
        knobs with this design's strengths on top.  Disabled-class
        knobs stay at the center — the simulator never reads them with
        the class off."""
        return dataclasses.replace(center, **dict(self.strengths))

    @property
    def key(self) -> str:
        """Archive identity (content hash; trace-independent)."""
        return design_fingerprint(
            self.opt, dataclasses.replace(SimParams(),
                                          **dict(self.strengths)))[:16]

    def to_json(self) -> dict:
        return {"memory": self.memory, "control": self.control,
                "operand": self.operand,
                "strengths": dict(self.strengths)}

    @classmethod
    def from_json(cls, d: Mapping) -> "DesignPoint":
        return make_design(bool(d["memory"]), bool(d["control"]),
                           bool(d["operand"]), d.get("strengths", {}))


def make_design(memory: bool, control: bool, operand: bool,
                strengths: Mapping[str, float] = (),
                center: SimParams | None = None) -> DesignPoint:
    """Canonicalize a design: clip strengths to `SEARCH_SPACE` bounds,
    fill missing enabled-class knobs from `center` (the paper defaults
    when None), drop disabled-class knobs."""
    strengths = dict(strengths)
    flags = {"M": memory, "C": control, "O": operand}
    kept: list[tuple[str, float]] = []
    for dim in SEARCH_SPACE:
        if not flags[dim.cls]:
            continue
        v = strengths.get(dim.name)
        if v is None:
            v = (getattr(center, dim.name) if center is not None
                 else dim.default)
        kept.append((dim.name, dim.clip(float(v))))
    return DesignPoint(memory, control, operand, tuple(sorted(kept)))


def baseline_design() -> DesignPoint:
    """The paper's baseline Ara corner: every class off, no knobs."""
    return make_design(False, False, False)


def ara_opt_design(center: SimParams | None = None) -> DesignPoint:
    """The paper's Ara-Opt corner: every class on at the strengths of
    `center` (defaults to the calibrated point, so this design's
    calibrated-grid score IS `ara_calibrated.json`'s recorded
    geomean)."""
    center = center if center is not None else load_calibrated()
    return make_design(True, True, True, center=center)


def paper_corners(center: SimParams | None = None) -> list[DesignPoint]:
    """Injected seeds: baseline, the three single classes, Ara-Opt."""
    center = center if center is not None else load_calibrated()
    return [
        baseline_design(),
        make_design(True, False, False, center=center),
        make_design(False, True, False, center=center),
        make_design(False, False, True, center=center),
        ara_opt_design(center),
    ]


# -- population scoring ----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScoredDesign:
    """A design plus everything the search and frontier records need."""
    design: DesignPoint
    score: float                 # the objective being maximized
    cost: float                  # the scalar being minimized (area mm2)
    area_mm2: float
    power_mw: float
    geomean_speedup: float       # geomean speedup on the eval set
    gap_closed: float            # mean gap-closed on the eval set
    gap_by_class: tuple[tuple[str, float], ...]
    dominant_path: str           # binding critical path, eval-aggregated
    path_shares: tuple[tuple[str, float], ...]

    @property
    def key(self) -> str:
        return self.design.key


class PopulationScorer:
    """Scores whole populations of designs in batched calls.

    The evaluation traces are stacked **once**; the baseline reference
    column (cycles + ideal, identical for every candidate because
    baseline-side knobs are pinned to the center) is simulated **once**
    at construction; and each `score()` call groups its designs by opt
    corner and runs one batched `(trace x corner-population)` call per
    corner through `api.simulate_groups`.  Attribution is always on —
    the stall tensors are what guide mutation.
    """

    def __init__(self, traces: Mapping[str, KernelTrace],
                 classes: Mapping[str, str] | None = None,
                 center: SimParams | None = None,
                 objective: str = "speedup",
                 backend: str = "numpy", method: str = "scan",
                 sim: BatchAraSimulator | None = None):
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {objective!r} "
                             f"(known: {', '.join(OBJECTIVES)})")
        self.names = list(traces)
        self.classes = dict(classes or {})
        self.center = center if center is not None else load_calibrated()
        self.objective = objective
        self.backend = backend
        self.method = method
        self.sim = sim if sim is not None else BatchAraSimulator()
        self.stacked = stack_traces([traces[k] for k in self.names])
        base = api.simulate(self.stacked, [OptConfig.baseline()],
                            [self.center], backend=backend,
                            method=method, attribution=True,
                            sim=self.sim)
        self.cycles_base = base.cycles[:, 0, 0]          # (B,)
        self.ideal_base = base.ideal[:, 0, 0]            # (B,)

    def score(self, designs: Sequence[DesignPoint]) -> list[ScoredDesign]:
        """One batched-population evaluation; preserves input order."""
        designs = list(designs)
        if not designs:
            return []
        obs_metrics.counter("search.populations").inc()
        obs_metrics.counter("search.candidates").inc(len(designs))
        by_corner: dict[str, list[int]] = {}
        for i, d in enumerate(designs):
            by_corner.setdefault(d.label, []).append(i)
        labels = sorted(by_corner)
        groups = [([designs[by_corner[lbl][0]].opt],
                   [designs[i].params(self.center)
                    for i in by_corner[lbl]]) for lbl in labels]
        with obs_spans.span("search.score", n_designs=len(designs),
                            n_corners=len(labels)):
            results = api.simulate_groups(
                self.stacked, groups, backend=self.backend,
                method=self.method, attribution=True, sim=self.sim)
        out: list[ScoredDesign | None] = [None] * len(designs)
        for lbl, res in zip(labels, results):
            cyc = res.cycles[:, 0, :]                     # (B, P)
            paths = path_sums(res.stalls[:, 0, :, :])     # (B, P, 3)
            for pi, di in enumerate(by_corner[lbl]):
                out[di] = self._finish(designs[di], cyc[:, pi],
                                       paths[:, pi, :])
        return out  # type: ignore[return-value]

    def _finish(self, design: DesignPoint, cycles: np.ndarray,
                paths: np.ndarray) -> ScoredDesign:
        speedups = self.cycles_base / np.maximum(cycles, 1e-9)
        stall_base = np.maximum(self.cycles_base - self.ideal_base, 1e-9)
        gaps = (self.cycles_base - cycles) / stall_base
        sp_geo = geomean([float(s) for s in speedups])
        gap_geo = geomean([max(float(g), GAP_FLOOR) for g in gaps])
        by_cls: dict[str, list[float]] = {}
        for name, g in zip(self.names, gaps):
            by_cls.setdefault(self.classes.get(name, name),
                              []).append(float(g))
        gap_by_class = tuple((c, sum(v) / len(v))
                             for c, v in sorted(by_cls.items()))
        totals = paths.sum(axis=0)                        # (3,)
        share = totals / max(float(totals.sum()), 1e-9)
        dominant = PATH_NAMES[int(np.argmax(totals))]
        cost = design_cost(design.opt, design.params(self.center))
        return ScoredDesign(
            design=design,
            score=sp_geo if self.objective == "speedup" else gap_geo,
            cost=cost["cost"], area_mm2=cost["area_mm2"],
            power_mw=cost["power_mw"], geomean_speedup=sp_geo,
            gap_closed=float(np.mean(gaps)), gap_by_class=gap_by_class,
            dominant_path=dominant,
            path_shares=tuple(zip(PATH_NAMES, map(float, share))))


def eval_traces(eval_set: str, per_class: int | None = None
                ) -> tuple[dict[str, KernelTrace], dict[str, str]]:
    """The searcher's evaluation set: traces + scenario-class labels.

    ``grid`` is the calibration grid (11 paper kernels, each its own
    class); ``corpus`` the committed scenario corpus, ``per_class``
    budgeted like `benchmarks.gridlib.CORPUS_PER_CLASS`.
    """
    if eval_set == "grid":
        traces = grid_traces()
        return traces, {name: name for name in traces}
    if eval_set == "corpus":
        from repro.data import corpus as C
        scenarios = C.load_scenarios(per_class=per_class)
        return ({s.name: s.trace for s in scenarios},
                {s.name: s.cls for s in scenarios})
    raise ValueError(f"unknown eval_set {eval_set!r} "
                     "(known: grid, corpus)")


# -- Pareto ---------------------------------------------------------------

def dominates(a: ScoredDesign, b: ScoredDesign) -> bool:
    """`a` dominates `b`: no worse on both axes, better on one
    (score is maximized, cost minimized)."""
    return (a.score >= b.score and a.cost <= b.cost
            and (a.score > b.score or a.cost < b.cost))


def pareto_front(points: Sequence[ScoredDesign]) -> list[ScoredDesign]:
    """Mutually non-dominated subset, cheapest first (pure function;
    property-tested: non-dominated within itself AND dominating or
    tying every excluded point).  Exact (score, cost) duplicates keep
    only the first by key order."""
    pts = sorted(points, key=lambda p: (p.cost, -p.score, p.key))
    front: list[ScoredDesign] = []
    seen: set[tuple[float, float]] = set()
    best_score = -float("inf")
    for p in pts:
        if p.score > best_score:
            if (p.score, p.cost) not in seen:
                front.append(p)
                seen.add((p.score, p.cost))
            best_score = p.score
    return front


# -- proposal operators ----------------------------------------------------

def _weighted_choice(rng: random.Random, items: Sequence,
                     weights: Sequence[float]):
    total = float(sum(weights))
    r = rng.random() * total
    acc = 0.0
    for item, w in zip(items, weights):
        acc += w
        if r <= acc:
            return item
    return items[-1]


def _jitter(rng: random.Random, name: str, value: float,
            step: float) -> float:
    """Gaussian step in the knob's normalized [lo, hi] coordinate."""
    dim = SPACE_BY_NAME[name]
    x = (dim.clip(value) - dim.lo) / (dim.hi - dim.lo)
    x = min(1.0, max(0.0, x + rng.gauss(0.0, step)))
    return dim.lo + x * (dim.hi - dim.lo)


def mutate(scored: ScoredDesign, rng: random.Random,
           center: SimParams, step: float = 0.15,
           pairs: Sequence[tuple[str, str]] = (),
           flag_prob: float = 0.15,
           pair_prob: float = 0.35) -> DesignPoint:
    """One attribution-guided mutation of a scored design.

    With probability `flag_prob` a class flag flips — biased toward
    *enabling* the class whose hardware addresses the design's binding
    critical path.  Otherwise 1-2 strength knobs jitter, sampled 4x
    more often from the knobs acting on that path (`KNOB_PATHS`); with
    probability `pair_prob` a Sobol co-move `pair` (both knobs inside
    enabled classes) is jittered jointly instead.
    """
    d = scored.design
    flags = {"M": d.memory, "C": d.control, "O": d.operand}
    strengths = dict(d.strengths)
    bind_cls = PATH_CLASS.get(scored.dominant_path)
    if rng.random() < flag_prob:
        if bind_cls is not None and not flags[bind_cls]:
            flip = bind_cls                   # enable the binding class
        else:
            flip = rng.choice(("M", "C", "O"))
        flags[flip] = not flags[flip]
        return make_design(flags["M"], flags["C"], flags["O"],
                           strengths, center=center)
    knobs = [d0.name for d0 in SEARCH_SPACE if flags[d0.cls]]
    if not knobs:                             # baseline corner: enable one
        flip = bind_cls or rng.choice(("M", "C", "O"))
        flags[flip] = True
        return make_design(flags["M"], flags["C"], flags["O"],
                           strengths, center=center)
    live_pairs = [p for p in pairs if p[0] in knobs and p[1] in knobs]
    if live_pairs and rng.random() < pair_prob:
        chosen = list(rng.choice(live_pairs))
    else:
        weights = [4.0 if KNOB_PATHS.get(k) == scored.dominant_path
                   else 1.0 for k in knobs]
        chosen = [_weighted_choice(rng, knobs, weights)]
        if len(knobs) > 1 and rng.random() < 0.4:
            rest = [k for k in knobs if k not in chosen]
            wrest = [4.0 if KNOB_PATHS.get(k) == scored.dominant_path
                     else 1.0 for k in rest]
            chosen.append(_weighted_choice(rng, rest, wrest))
    for k in chosen:
        cur = strengths.get(k, float(getattr(center, k)))
        strengths[k] = _jitter(rng, k, cur, step)
    return make_design(flags["M"], flags["C"], flags["O"], strengths,
                       center=center)


def crossover(a: ScoredDesign, b: ScoredDesign, rng: random.Random,
              center: SimParams) -> DesignPoint:
    """Uniform crossover: each flag and each strength knob inherits
    from a random parent (strengths fall back to whichever parent has
    the knob's class enabled, the center otherwise)."""
    da, db = a.design, b.design
    flags = {
        "M": (da if rng.random() < 0.5 else db).memory,
        "C": (da if rng.random() < 0.5 else db).control,
        "O": (da if rng.random() < 0.5 else db).operand,
    }
    sa, sb = dict(da.strengths), dict(db.strengths)
    strengths = {}
    for dim in SEARCH_SPACE:
        if not flags[dim.cls]:
            continue
        pick = [p for p in ((sa if rng.random() < 0.5 else sb), sa, sb)
                if dim.name in p]
        if pick:
            strengths[dim.name] = pick[0][dim.name]
    return make_design(flags["M"], flags["C"], flags["O"], strengths,
                       center=center)


def _lhs_designs(rng: random.Random, n: int,
                 center: SimParams) -> list[DesignPoint]:
    """`n` Latin-hypercube random designs over the full strength space,
    with rng-drawn class flags (never all-off — that's the injected
    baseline's job)."""
    from repro.launch.sensitivity import lhs_candidates
    space = [(d.name, d.lo, d.hi) for d in SEARCH_SPACE]
    rows = lhs_candidates(space, n, rng) if n else []
    out = []
    for row in rows:
        flags = [rng.random() < 0.75 for _ in range(3)]
        if not any(flags):
            flags = [True, True, True]
        out.append(make_design(*flags, row, center=center))
    return out


# -- the search loop -------------------------------------------------------

@dataclasses.dataclass
class SearchResult:
    """Everything one search run produced."""
    best: ScoredDesign               # argmax score subject to the bound
    frontier: list[ScoredDesign]     # Pareto front over ALL evaluated
    evaluated: list[ScoredDesign]    # archive, evaluation order
    history: list[dict]              # per-generation search log
    config: dict                     # reproduces the run
    calibrated: dict[str, float] = dataclasses.field(default_factory=dict)


def _selection_key(bound: float):
    """Feasible-first, score-descending, then cost, then key (total
    deterministic order)."""
    def key(s: ScoredDesign):
        return (s.cost > bound, -s.score, s.cost, s.key)
    return key


def _sobol_pairs(scorer: PopulationScorer, seed: int, n: int,
                 top: int = 3) -> list[tuple[str, str]]:
    """Score a Saltelli design over the strength space once (one
    batched `(trace x {base, full} x variants)` call) and rank knob
    pairs by interaction mass."""
    if n <= 0:
        return []
    space = [(d.name, d.lo, d.hi) for d in SEARCH_SPACE]
    design = sobol_design(center=scorer.center, n=n, seed=seed,
                          space=space)
    res = api.simulate(scorer.stacked,
                       [OptConfig.baseline(), OptConfig.full()],
                       list(design.variants), backend=scorer.backend,
                       method=scorer.method, sim=scorer.sim)
    sp = res.cycles[:, 0, :] / np.maximum(res.cycles[:, 1, :], 1e-9)
    f = np.exp(np.log(np.maximum(sp, 1e-30)).mean(axis=0))   # (P,)
    return co_move_pairs(sobol_indices(design, f), top=top)


def run_search(algorithm: str = "evolve", objective: str = "speedup",
               eval_set: str = "grid", seed: int = 0,
               generations: int = 6, population: int = 24,
               beam_width: int = 6, branch: int = 4, restarts: int = 4,
               cost_bound: float | None = None, sobol_n: int = 8,
               per_class: int | None = None,
               center: SimParams | None = None,
               backend: str = "numpy", method: str = "scan",
               inject: Sequence[DesignPoint] | None = None,
               scorer: PopulationScorer | None = None) -> SearchResult:
    """Run one seeded search; see the module docstring for the loop.

    ``cost_bound`` defaults to the calibrated Ara-Opt corner's own cost
    — "find designs at most as expensive as the paper's" — and the
    injected corners (`paper_corners`) guarantee the search never loses
    to Ara-Opt on its own evaluation set.  The returned ``best`` is the
    highest-scoring *feasible* design; the ``frontier`` spans all
    costs.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r} "
                         f"(known: {', '.join(ALGORITHMS)})")
    requested = algorithm
    rng = random.Random(seed)
    center = center if center is not None else load_calibrated()
    if scorer is None:
        traces, classes = eval_traces(eval_set, per_class)
        scorer = PopulationScorer(traces, classes, center=center,
                                  objective=objective, backend=backend,
                                  method=method)
    if cost_bound is None:
        cost_bound = design_cost(OptConfig.full(), center)["cost"]
    pairs = _sobol_pairs(scorer, seed, sobol_n)

    archive: dict[str, ScoredDesign] = {}
    history: list[dict] = []

    def evaluate(designs: Sequence[DesignPoint]) -> int:
        fresh, seen = [], set()
        for d in designs:
            if d.key not in archive and d.key not in seen:
                fresh.append(d)
                seen.add(d.key)
        for s in scorer.score(fresh):
            archive[s.key] = s
        return len(fresh)

    def record(gen: int, n_new: int) -> None:
        ranked = sorted(archive.values(), key=_selection_key(cost_bound))
        front = pareto_front(list(archive.values()))
        best = ranked[0]
        history.append({
            "gen": gen, "evaluated": n_new, "archive": len(archive),
            "best_key": best.key, "best_score": best.score,
            "best_cost": best.cost, "frontier_size": len(front),
        })
        obs_metrics.gauge("search.frontier_size").set(len(front))

    if algorithm == "chain":
        beam_width, algorithm = 1, "beam"
        branch = max(branch, 6)

    seeds = (list(inject) if inject is not None
             else paper_corners(center))
    if algorithm == "random":
        # Multi-seed random restarts: `restarts` independent LHS
        # populations, each its own batched scoring call.
        n_new = evaluate(seeds + _lhs_designs(rng, population, center))
        record(0, n_new)
        for r in range(1, restarts):
            rr = random.Random(seed + 1000 * r)
            record(r, evaluate(_lhs_designs(rr, population, center)))
    else:
        n_init = max(population - len(seeds), 0)
        n_new = evaluate(seeds + _lhs_designs(rng, n_init, center))
        record(0, n_new)
        for gen in range(1, generations + 1):
            ranked = sorted(archive.values(),
                            key=_selection_key(cost_bound))
            proposals: list[DesignPoint] = []
            if algorithm == "beam":
                for parent in ranked[:beam_width]:
                    proposals += [mutate(parent, rng, center,
                                         pairs=pairs)
                                  for _ in range(branch)]
            else:                              # evolve
                parents = ranked[:max(population // 2, 2)]
                while len(proposals) < population:
                    if len(parents) >= 2 and rng.random() < 0.4:
                        a, b = rng.sample(parents, 2)
                        child = crossover(a, b, rng, center)
                        better = a if a.score >= b.score else b
                        proposals.append(mutate(
                            ScoredDesign(**{
                                **dataclasses.asdict(better),
                                "design": child}), rng, center,
                            pairs=pairs, flag_prob=0.05))
                    else:
                        parent = _weighted_choice(
                            rng, parents,
                            [len(parents) - i
                             for i in range(len(parents))])
                        proposals.append(mutate(parent, rng, center,
                                                pairs=pairs))
            record(gen, evaluate(proposals))

    evaluated = list(archive.values())
    front = pareto_front(evaluated)
    best = sorted(evaluated, key=_selection_key(cost_bound))[0]
    config = {"algorithm": requested,
              "objective": objective, "eval_set": eval_set,
              "seed": seed, "generations": generations,
              "population": population, "beam_width": beam_width,
              "branch": branch, "restarts": restarts,
              "sobol_n": sobol_n, "per_class": per_class,
              "cost_bound": cost_bound, "backend": backend,
              "method": method, "co_move_pairs": [list(p) for p in pairs]}
    return SearchResult(best=best, frontier=front, evaluated=evaluated,
                        history=history, config=config)


def annotate_calibrated(result: SearchResult,
                        center: SimParams | None = None,
                        backend: str = "numpy",
                        method: str = "scan") -> dict[str, float]:
    """Geomean speedup of every evaluated design on the *calibrated
    11-kernel grid* — one batched scoring pass.  This is the column the
    CI drift gate compares against `ara_calibrated.json`'s recorded
    geomean: the injected Ara-Opt corner is always among the evaluated
    (and feasible at exactly the default cost bound), so the best
    feasible calibrated geomean can never fall below the recorded
    value."""
    center = center if center is not None else load_calibrated()
    scorer = PopulationScorer(grid_traces(), center=center,
                              objective="speedup", backend=backend,
                              method=method)
    designs = {s.key: s.design for s in result.evaluated}
    keys = sorted(designs)
    scored = scorer.score([designs[k] for k in keys])
    result.calibrated = {k: s.geomean_speedup
                         for k, s in zip(keys, scored)}
    return result.calibrated


# -- committed frontier ----------------------------------------------------

def _record(s: ScoredDesign, calibrated: Mapping[str, float]) -> dict:
    rec = {"key": s.key, "design": s.design.to_json(),
           "label": s.design.label, "score": s.score, "cost": s.cost,
           "area_mm2": s.area_mm2, "power_mw": s.power_mw,
           "geomean_speedup": s.geomean_speedup,
           "gap_closed": s.gap_closed,
           "gap_closed_by_class": dict(s.gap_by_class),
           "dominant_path": s.dominant_path,
           "path_shares": dict(s.path_shares)}
    if s.key in calibrated:
        rec["calibrated_geomean"] = calibrated[s.key]
    return rec


def frontier_payload(result: SearchResult) -> dict:
    """JSON payload of a search run (`experiments/search/pareto.json`)."""
    if not result.calibrated:
        annotate_calibrated(result)
    cal = result.calibrated
    bound = result.config.get("cost_bound", float("inf"))
    feasible = [s for s in result.evaluated
                if s.cost <= bound and s.key in cal]
    best_cal = max(feasible, key=lambda s: (cal[s.key], s.key),
                   default=result.best)
    return {
        "config": result.config,
        "best": _record(result.best, cal),
        "best_calibrated": _record(best_cal, cal),
        "frontier": [_record(s, cal) for s in result.frontier],
        "history": result.history,
        "n_evaluated": len(result.evaluated),
    }


def canonical_search(**overrides) -> SearchResult:
    """The committed-frontier run: `CANONICAL_BUDGET` exactly, unless
    overridden (fig9's full profile raises the budget)."""
    kw = dict(CANONICAL_BUDGET)
    kw.update(overrides)
    return run_search(**kw)


def write_pareto(path: pathlib.Path = PARETO_PATH,
                 result: SearchResult | None = None) -> dict:
    result = result if result is not None else canonical_search()
    payload = frontier_payload(result)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return payload


def _front_points(payload: dict) -> set[tuple[float, float]]:
    return {(round(r["score"], 9), round(r["cost"], 9))
            for r in payload["frontier"]}


def check_committed(path: pathlib.Path = PARETO_PATH,
                    regen: dict | None = None) -> list[str]:
    """CI gate: the committed frontier regenerates dominance-equivalent
    at the canonical budget, stays mutually non-dominated, and its best
    design's calibrated-grid geomean has not drifted below
    `ara_calibrated.json`'s recorded value.  Returns error strings
    (empty = pass)."""
    from repro.core.calibration import load_payload
    errors: list[str] = []
    if not path.exists():
        return [f"{path} is missing (run design_search.write_pareto)"]
    committed = json.loads(path.read_text())
    pts = [(r["score"], r["cost"]) for r in committed["frontier"]]
    for i, (si, ci) in enumerate(pts):
        for j, (sj, cj) in enumerate(pts):
            if i != j and sj >= si and cj <= ci and (sj > si or cj < ci):
                errors.append(f"committed frontier point {i} is "
                              f"dominated by point {j}")
    recorded = load_payload().get("geomean_speedup")
    best_cal = committed.get("best_calibrated",
                             committed["best"]).get("calibrated_geomean")
    if recorded is not None and best_cal is not None \
            and best_cal < recorded - 1e-6:
        errors.append(
            f"committed best_calibrated design's geomean {best_cal:.6f} "
            f"drifted below ara_calibrated.json's {recorded:.6f}")
    if regen is None:
        regen = frontier_payload(canonical_search())
    if _front_points(regen) != _front_points(committed):
        errors.append(
            "regenerated frontier is not dominance-equivalent to the "
            f"committed one: {sorted(_front_points(regen))} vs "
            f"{sorted(_front_points(committed))}")
    return errors


def main(argv: Sequence[str] | None = None) -> None:  # pragma: no cover
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--algorithm", choices=ALGORITHMS,
                    default="evolve")
    ap.add_argument("--objective", choices=OBJECTIVES, default="speedup")
    ap.add_argument("--eval-set", choices=("grid", "corpus"),
                    default="grid")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--generations", type=int, default=6)
    ap.add_argument("--population", type=int, default=24)
    ap.add_argument("--beam-width", type=int, default=6)
    ap.add_argument("--restarts", type=int, default=4)
    ap.add_argument("--per-class", type=int, default=None)
    ap.add_argument("--cost-bound", type=float, default=None)
    ap.add_argument("--backend", default="numpy")
    ap.add_argument("--method", default="scan")
    ap.add_argument("--write-pareto", action="store_true",
                    help="run the canonical committed budget and write "
                         "experiments/search/pareto.json")
    ap.add_argument("--check", action="store_true",
                    help="regenerate at the canonical budget and verify "
                         "the committed pareto.json (CI gate)")
    args = ap.parse_args(argv)
    if args.check:
        errors = check_committed()
        for e in errors:
            print(f"ERROR: {e}")
        if errors:
            raise SystemExit(1)
        print("committed pareto.json OK")
        return
    if args.write_pareto:
        payload = write_pareto()
        print(json.dumps(payload["best"], indent=2))
        print(f"wrote {PARETO_PATH} "
              f"({len(payload['frontier'])} frontier points)")
        return
    result = run_search(algorithm=args.algorithm,
                        objective=args.objective,
                        eval_set=args.eval_set, seed=args.seed,
                        generations=args.generations,
                        population=args.population,
                        beam_width=args.beam_width,
                        restarts=args.restarts,
                        per_class=args.per_class,
                        cost_bound=args.cost_bound,
                        backend=args.backend, method=args.method)
    annotate_calibrated(result)
    print(json.dumps(frontier_payload(result), indent=2))


if __name__ == "__main__":  # pragma: no cover
    main()
