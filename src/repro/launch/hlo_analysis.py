"""Collective accounting from compiled HLO text (§Roofline inputs).

XLA's cost_analysis does not expose collective bytes, so we parse the
compiled module: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op's result shape is summed (async
``-start`` ops counted once; ``-done`` skipped).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*([^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _bytes_of_type_str(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type result bytes (per-device program => per-device
    wire-side approximation)."""
    out: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue                        # async completion: counted at start
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, op, _ = m.groups()
        b = _bytes_of_type_str(type_str)
        out[op] += b
        counts[op] += 1
    return {"bytes_by_type": dict(out),
            "counts_by_type": dict(counts),
            "total_bytes": float(sum(out.values()))}


def op_histogram(hlo_text: str, top: int = 15) -> list[tuple[str, int]]:
    """Crude op-name histogram of the compiled module (perf debugging:
    counts duplicated fusions, remat recompute, relayouts)."""
    ops = re.findall(r"=\s*\S+\s+([a-z][\w-]*)\(", hlo_text)
    hist: dict[str, int] = defaultdict(int)
    for o in ops:
        hist[o] += 1
    return sorted(hist.items(), key=lambda kv: -kv[1])[:top]
