"""Batched serving driver (smoke-scale on CPU; production mesh on TPU).

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b \
        --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models import init_model
from repro.serve.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    key = jax.random.PRNGKey(args.seed)
    params = init_model(key, cfg)
    eng = Engine(params, cfg, s_max=args.s_max, cache_dtype=jnp.float32)

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.perf_counter()
    logits, cache, pos = eng.prefill(prompt)
    prefill_s = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [tok]
    t1 = time.perf_counter()
    for i in range(args.max_new - 1):
        logits, cache, pos = eng.step(cache, tok, pos)
        tok = (jnp.argmax(logits, -1).astype(jnp.int32)
               if args.temperature <= 0 else
               jax.random.categorical(jax.random.fold_in(key, i),
                                      logits / args.temperature
                                      ).astype(jnp.int32))
        outs.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t1

    total_tokens = args.batch * args.max_new
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"prefill: {prefill_s * 1e3:.1f} ms "
          f"({args.batch * args.prompt_len / max(prefill_s, 1e-9):.0f} tok/s)")
    print(f"decode:  {decode_s * 1e3:.1f} ms "
          f"({total_tokens / max(decode_s, 1e-9):.0f} tok/s incl. compile)")
    sample = jnp.stack(outs, axis=1)[0, :16]
    print("sample tokens[0,:16]:", list(map(int, sample)))


if __name__ == "__main__":
    main()
