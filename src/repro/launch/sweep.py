"""Dry-run sweep driver: one subprocess per cell (isolation: an OOM or
crash in one cell cannot kill the sweep; each gets a fresh XLA).

Per (arch x shape):
  * production compile on the single-pod 16x16 mesh        (dryrun.py)
  * production compile on the multi-pod 2x16x16 mesh       (dryrun.py)

(The scan-corrected cost-extrapolation step is gone: `costmodel.py` is
now the design-space hardware cost model consumed by
`repro.launch.design_search`, not a lowering analysis.)

Results land in experiments/dryrun/*.json; benchmarks/dryrun_table.py and
EXPERIMENTS.md §Roofline read them.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

REPO = pathlib.Path(__file__).resolve().parents[3]
OUT = REPO / "experiments" / "dryrun"


def _run(mod: str, arch: str, shape: str, mesh: str, timeout: int,
         tag: str = "", override: str = "") -> dict:
    cmd = [sys.executable, "-m", mod, "--arch", arch, "--shape", shape,
           "--mesh", mesh, "--out", str(OUT)]
    if tag:
        cmd += ["--tag", tag]
    if override:
        cmd += ["--override", override]
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        ok = proc.returncode == 0
        msg = (proc.stdout.strip().splitlines() or [""])[-1] if ok else \
            (proc.stderr.strip().splitlines() or [""])[-1]
    except subprocess.TimeoutExpired:
        ok, msg = False, f"timeout>{timeout}s"
    return {"ok": ok, "elapsed": round(time.time() - t0, 1), "msg": msg}


def main() -> None:
    from repro.configs import ARCHS, SHAPES, skip_reason

    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=sorted(ARCHS))
    ap.add_argument("--shapes", nargs="*", default=list(SHAPES))
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--skip-multipod", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    args = ap.parse_args()

    OUT.mkdir(parents=True, exist_ok=True)
    log = open(OUT / "sweep.log", "a")

    def emit(rec):
        line = json.dumps(rec)
        print(line, flush=True)
        log.write(line + "\n")
        log.flush()

    for arch in args.archs:
        cfg = ARCHS[arch]
        for shape in args.shapes:
            reason = skip_reason(cfg, SHAPES[shape])
            if reason:
                # Record the skip as a first-class result.
                for mesh in ("single-pod", "multi-pod"):
                    p = OUT / f"{arch}__{shape}__{mesh}.json"
                    p.write_text(json.dumps({
                        "arch": arch, "shape": shape, "mesh": mesh,
                        "status": "skipped", "reason": reason}, indent=2))
                emit({"cell": f"{arch}/{shape}", "skipped": reason})
                continue
            plan = [("repro.launch.dryrun", "single-pod", "")]
            if not args.skip_multipod:
                plan.append(("repro.launch.dryrun", "multi-pod", ""))
            for mod, mesh, tag in plan:
                target = OUT / f"{arch}__{shape}__{mesh}.json"
                if args.only_missing and target.exists():
                    prev = json.loads(target.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        continue
                res = _run(mod, arch, shape, mesh, args.timeout, tag)
                emit({"cell": f"{arch}/{shape}/{mesh}",
                      "mod": mod.split(".")[-1], **res})
    log.close()


if __name__ == "__main__":
    main()
