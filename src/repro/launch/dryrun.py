import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import (device count
# locks at first init), which is why they precede even the module docstring
# — a __future__ import cannot be used in this file.

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real train_step / serve_step with production
shardings on the 16x16 (single-pod) and 2x16x16 (multi-pod) host-device
meshes, compiles it (SPMD partitioner + scheduler run for real), and
records:
  * compiled.memory_analysis()  — per-device bytes (proves it fits),
  * compiled.cost_analysis()    — per-device HLO FLOPs / bytes accessed,
  * collective bytes parsed from the compiled HLO (hlo_analysis.py),
  * the three §Roofline terms + MODEL_FLOPS ratio.

Run one cell:   python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
Run the sweep:  python -m repro.launch.sweep   (subprocess per cell)
"""

import argparse
import dataclasses
import functools
import json
import pathlib
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, ShapeSpec, SHAPES, skip_reason
from repro.configs.base import ModelConfig
from repro.core.roofline import (RooflineTerms, TPU_V5E,
                                 model_flops_inference,
                                 model_flops_training)
from repro.distributed.sharding import (named_shardings, param_specs,
                                        resolve_spec, safe_spec, use_mesh)
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models.lm import loss_fn
from repro.models.transformer import decode_step, init_cache, init_model, \
    logits_fn
from repro.train import optimizer as opt
from repro.train.step import StepConfig, TrainState, init_state, \
    make_train_step

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / \
    "dryrun"


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins — no allocation, the pattern
# required by the brief).
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind in ("train",):
        batch: dict[str, Any] = {"targets": tok}
        if cfg.modality == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16)
        else:
            batch["tokens"] = tok
            if cfg.modality == "vlm":
                batch["img_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.modality == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16)
        else:
            batch["tokens"] = tok
            if cfg.modality == "vlm":
                batch["img_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against an s-long cache
    return {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32)}


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Any:
    """NamedShardings for the input batch (batch dim over pod+data)."""
    def spec_for(path_shape):
        nd = len(path_shape.shape)
        spec = resolve_spec(("batch",) + (None,) * (nd - 1))
        return NamedSharding(mesh, safe_spec(path_shape.shape, spec, mesh))
    return jax.tree.map(spec_for, input_specs(cfg, shape))


def _cache_sharding(cfg: ModelConfig, shape: ShapeSpec, mesh,
                    cache_abs) -> Any:
    """Decode-cache shardings.  batch over (pod, data) normally; for
    long_500k (batch=1) the KV sequence dim is context-parallel over
    'data' instead (logical axis seq_cp)."""
    seq_cp = shape.global_batch < mesh.shape.get("data", 1)

    def leaf(path, leaf_abs):
        names = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        nd = len(leaf_abs.shape)
        stacked = "scan" in names           # leading n_rep dim
        base = 1 if stacked else 0
        logical: list[str | None] = [None] * nd
        if nd > base:
            logical[base] = None if seq_cp else "batch"
        # KV/linear caches: (B, S, KV, D) or (B, S, R): seq dim = base+1
        is_seq_cache = any(n in ("k", "v", "ckv", "krope") for n in names)
        if is_seq_cache and nd >= base + 2 and seq_cp:
            logical[base + 1] = "seq_cp"
        if is_seq_cache and nd == base + 4:
            logical[base + 2] = "kv_heads"
        if any(n == "ssm" for n in names) and nd >= base + 2:
            logical[base + 1] = "heads"     # SSM state: shard heads
        spec = safe_spec(leaf_abs.shape, resolve_spec(tuple(logical)), mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, cache_abs)


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _microbatches(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    per_dev_batch = max(shape.global_batch // dp, 1)
    # Keep per-device microbatch around 2 sequences at 4k.
    mb = max(1, min(per_dev_batch // 2, 8))
    while shape.global_batch % (mb * dp) and mb > 1:
        mb -= 1
    return mb


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt_overrides: dict | None = None,
               microbatches: int | None = None) -> dict:
    cfg = ARCHS[arch]
    if opt_overrides:
        overrides = dict(opt_overrides)
        microbatches = overrides.pop("microbatches", microbatches)
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi-pod" if multi_pod else "single-pod",
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    record: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi-pod" if multi_pod else "single-pod",
        "chips": chips, "status": "error",
    }
    rules = None
    if cfg.sharding_mode == "serve_tp":
        # Serving-oriented layout: parameters live TP-sharded over "model"
        # only (no FSDP dim) so decode steps never all-gather weights —
        # the §Perf fix for decode's dominant collective.
        rules = {"embed": None}
    elif cfg.sharding_mode == "fsdp":
        # Pure-FSDP alternative to Megatron-TP (§Perf lever): params fully
        # sharded over (data, model), batch/tokens sharded over BOTH ICI
        # axes; no tensor-parallel activation collectives — weight
        # all-gathers instead.
        rules = {"embed": ("data", "model"), "heads": None,
                 "kv_heads": None, "ff": None, "vocab": None,
                 "expert": None, "batch": ("pod", "data", "model")}
    t0 = time.time()
    with use_mesh(mesh, rules):
        params_abs = jax.eval_shape(
            functools.partial(init_model, cfg=cfg), jax.random.PRNGKey(0))
        p_specs = param_specs(params_abs)
        p_sh = named_shardings(p_specs, mesh)
        batch_abs = input_specs(cfg, shape)
        b_sh = batch_specs(cfg, shape, mesh)

        if shape.kind == "train":
            mb = microbatches or _microbatches(cfg, shape, mesh)
            record["microbatches"] = mb
            step_cfg = StepConfig(microbatches=mb)
            train_step = make_train_step(cfg, step_cfg)
            state_abs = jax.eval_shape(init_state, params_abs)
            state_sh = TrainState(
                params=p_sh,
                opt=opt.AdamWState(
                    step=NamedSharding(mesh, P()),
                    m=p_sh, v=jax.tree.map(lambda s: s, p_sh)),
                rng=NamedSharding(mesh, P()))
            lowered = jax.jit(
                train_step,
                in_shardings=(state_sh, b_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_abs, batch_abs)
            record["tokens_per_step"] = shape.global_batch * shape.seq_len
            model_flops = model_flops_training(
                cfg.active_param_count(), record["tokens_per_step"])
        elif shape.kind == "prefill":
            fwd = functools.partial(logits_fn, cfg=cfg, mode="prefill")
            lowered = jax.jit(
                fwd, in_shardings=(p_sh, b_sh), out_shardings=None,
            ).lower(params_abs, batch_abs)
            record["tokens_per_step"] = shape.global_batch * shape.seq_len
            model_flops = model_flops_inference(
                cfg.active_param_count(), record["tokens_per_step"])
        else:  # decode
            cache_abs = jax.eval_shape(
                functools.partial(init_cache, cfg, shape.global_batch,
                                  shape.seq_len))
            c_sh = _cache_sharding(cfg, shape, mesh, cache_abs)
            tok_sh = NamedSharding(mesh, safe_spec(
                (shape.global_batch,), resolve_spec(("batch",)), mesh))
            dstep = functools.partial(decode_step, cfg=cfg)
            lowered = jax.jit(
                dstep,
                in_shardings=(p_sh, c_sh, tok_sh, tok_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            ).lower(params_abs, cache_abs,
                    jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
                    jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32))
            record["tokens_per_step"] = shape.global_batch
            model_flops = model_flops_inference(
                cfg.active_param_count(), shape.global_batch)

        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            record["memory"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "code_bytes": int(mem.generated_code_size_in_bytes),
            }
            live = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes)
            record["memory"]["live_bytes_per_device"] = int(live)
            record["memory"]["fits_16gb_hbm"] = bool(live < 16e9)

        cost = compiled.cost_analysis() or {}
        flops = float(cost.get("flops", 0.0))
        hbm = float(cost.get("bytes accessed", 0.0))
        coll = collective_bytes(compiled.as_text())
        record["cost"] = {"flops_per_device": flops,
                          "hbm_bytes_per_device": hbm}
        record["collectives"] = coll

        terms = RooflineTerms(flops=flops, hbm_bytes=hbm,
                              collective_bytes=coll["total_bytes"])
        record["roofline"] = terms.to_dict()
        record["model_flops_total"] = model_flops
        record["model_flops_per_device"] = model_flops / chips
        record["useful_flops_ratio"] = (
            model_flops / chips / flops if flops else 0.0)
        record["roofline_fraction"] = terms.roofline_fraction(
            model_flops / chips)
        record["status"] = "ok"
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single-pod",
                    choices=["single-pod", "multi-pod"])
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", default="",
                    help="JSON ModelConfig overrides (perf experiments)")
    args = ap.parse_args()
    overrides = json.loads(args.override) if args.override else None
    rec = lower_cell(args.arch, args.shape, args.mesh == "multi-pod",
                     overrides)
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    tag = f"__{args.tag}" if args.tag else ""
    name = f"{args.arch}__{args.shape}__{args.mesh}{tag}.json"
    (outdir / name).write_text(json.dumps(rec, indent=2))
    print(json.dumps({k: rec.get(k) for k in
                      ("arch", "shape", "mesh", "status", "compile_s",
                       "roofline_fraction")}, indent=None))
    if rec["status"] not in ("ok", "skipped"):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
