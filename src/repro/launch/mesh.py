"""Production mesh construction (required interface from the brief)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading DCN 'pod' axis
    (2 pods = 512 chips).  A FUNCTION, not a module constant: importing this
    module never touches jax device state."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
