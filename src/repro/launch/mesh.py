"""Production mesh construction (required interface from the brief).

Also home of the **P-axis sweep sharding** used by the execution
planner (`repro.core.api.simulate(..., shard="devices")`): a 1-D mesh
over the local devices plus a `shard_map` wrapper that splits the
params columns of the compiled batched sweep across them.  The sweep
is embarrassingly parallel along its width axis (every `(opt, params)`
cell is an independent column of the scanned state), so the shard
needs no collectives — each device runs the same program on its slice
of the params axis and the results concatenate back.  On a one-device
host the mesh has a single shard and the sharded program is exactly
the unsharded one (parity-tested in tests/test_bucketing.py), so
callers never special-case device count.
"""
from __future__ import annotations

import functools

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading DCN 'pod' axis
    (2 pods = 512 chips).  A FUNCTION, not a module constant: importing this
    module never touches jax device state."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


@functools.lru_cache(maxsize=1)
def make_sweep_mesh():
    """1-D mesh over all local devices, axis ``p`` (the params axis of
    a batched sweep).  Cached: device topology is fixed per process."""
    return jax.make_mesh((len(jax.devices()),), ("p",))


def sharded_sweep(fn, fields, views, R: int, n_opts: int,
                  attribution: bool = False, mesh=None):
    """Run the compiled batched sweep with its params axis sharded.

    ``fn`` is `batch_sim._build_jax_sweep`'s jitted callable taking
    ``(fields, views, R)`` where each view is a flat opt-major ``(W,)``
    array with ``W = n_opts * P``; returns its 7-tuple with every
    ``(B, W)``(/``(B, W, NCOMP)`` for the attribution components)
    output produced under `shard_map`.  The views reshape to
    ``(n_opts, P)``, P pads up to a multiple of the mesh size by
    repeating the last column (sliced off after), and each device
    computes its own params columns — no collectives, no cross-device
    traffic beyond the final gather.  Trace fields are replicated: they
    are small next to the ``(B, R, W)`` scan state.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = mesh or make_sweep_mesh()
    n_dev = int(np.prod(mesh.devices.shape))
    W = int(np.shape(views[0])[0])
    n_p = W // n_opts
    p_pad = -(-n_p // n_dev) * n_dev
    v2 = []
    for v in views:
        v = np.asarray(v).reshape(n_opts, n_p)
        if p_pad != n_p:
            v = np.concatenate(
                [v, np.repeat(v[:, -1:], p_pad - n_p, axis=1)], axis=1)
        v2.append(v)
    v2 = tuple(v2)

    def local(fields, views):
        flat = tuple(v.reshape(-1) for v in views)   # (n_opts * P_loc,)
        outs = fn(fields, flat, R)
        # (B, O*P_loc)[, NCOMP] -> (B, O, P_loc)[, NCOMP]: stitch along
        # the params axis, not the flat shard-major width axis.
        return tuple(o.reshape(o.shape[0], n_opts, -1, *o.shape[2:])
                     for o in outs)

    spec_f = jax.tree_util.tree_map(lambda _: P(), fields)
    spec_v = jax.tree_util.tree_map(lambda _: P(None, "p"), v2)
    spec_comp = (P(None, None, "p", None) if attribution
                 else P(None, None, "p"))
    out = shard_map(
        local, mesh=mesh, in_specs=(spec_f, spec_v),
        out_specs=(P(None, None, "p"),) * 6 + (spec_comp,),
        check_rep=False)(fields, v2)
    # Back to the caller's flat (B, W) layout, padding dropped.
    return tuple(
        o[:, :, :n_p].reshape(o.shape[0], n_opts * n_p, *o.shape[3:])
        for o in out)
