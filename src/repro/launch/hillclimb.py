import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

DOC = """§Perf hillclimb runner: hypothesis -> change -> re-lower -> re-analyse.

Each iteration is a ModelConfig override set applied to one (arch x shape)
cell; the scan-corrected three-term roofline is recomputed and appended to
experiments/perf/<cell>.jsonl.  EXPERIMENTS.md §Perf narrates these logs.

    python -m repro.launch.hillclimb --arch qwen2.5-3b --shape train_4k \
        --tag fsdp --override '{"sharding_mode": "fsdp"}' \
        --hypothesis "TP all-reduce bytes dominate; pure FSDP swaps ..."
"""

import argparse
import json
import pathlib
import time

from repro.configs import ARCHS, SHAPES
from repro.launch.costmodel import analyze, roofline_from_analysis

REPO = pathlib.Path(__file__).resolve().parents[3]
PERF_DIR = REPO / "experiments" / "perf"


def run_iteration(arch: str, shape: str, tag: str, overrides: dict | None,
                  hypothesis: str = "") -> dict:
    from repro.launch.dryrun import lower_cell
    cfg = ARCHS[arch]
    t0 = time.time()
    analysis = analyze(arch, shape, multi_pod=False,
                       extra_overrides=overrides)
    rec = {"arch": arch, "shape": shape, "tag": tag,
           "overrides": overrides or {}, "hypothesis": hypothesis,
           "elapsed_s": round(time.time() - t0, 1),
           "status": analysis["status"]}
    if analysis["status"] == "ok":
        # model flops per device (production definition, from lower_cell's
        # bookkeeping without compiling the full production graph).
        shape_spec = SHAPES[shape]
        chips = 256
        if shape_spec.kind == "train":
            mf = 6.0 * cfg.active_param_count() * \
                shape_spec.global_batch * shape_spec.seq_len
        elif shape_spec.kind == "prefill":
            mf = 2.0 * cfg.active_param_count() * \
                shape_spec.global_batch * shape_spec.seq_len
        else:
            mf = 2.0 * cfg.active_param_count() * shape_spec.global_batch
        rec["roofline"] = roofline_from_analysis(analysis, mf / chips)
        rec["totals"] = analysis["total_remat"]
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    log = PERF_DIR / f"{arch}__{shape}.jsonl"
    with open(log, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--tag", required=True)
    ap.add_argument("--override", default="")
    ap.add_argument("--hypothesis", default="")
    args = ap.parse_args()
    overrides = json.loads(args.override) if args.override else None
    rec = run_iteration(args.arch, args.shape, args.tag, overrides,
                        args.hypothesis)
    out = {k: rec.get(k) for k in ("tag", "status", "elapsed_s")}
    if "roofline" in rec:
        r = rec["roofline"]
        out.update({k: round(r[k], 6) for k in
                    ("compute_s", "memory_s", "collective_s")})
        out["bound"] = r["bound"]
        out["roofline_fraction"] = round(r["roofline_fraction"], 5)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
