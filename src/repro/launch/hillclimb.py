"""Single-chain hillclimb over the design space: a thin CLI veneer.

Kept for muscle memory — ``python -m repro.launch.hillclimb`` runs the
design-space search (`repro.launch.design_search`) in its ``chain``
mode: a width-1 beam that mutates the incumbent each generation and
keeps whatever scores best, i.e. a classic stochastic hillclimb with
the same attribution-guided proposal distribution, batched population
scoring, and cost bound as the full searcher.  For anything beyond a
quick climb (Pareto frontiers, evolutionary search, random restarts)
call ``python -m repro.launch.design_search`` directly.
"""
from __future__ import annotations

import argparse
import json
from typing import Sequence

from repro.launch import design_search

__all__ = ["climb", "main"]


def climb(seed: int = 0, generations: int = 8, branch: int = 6,
          eval_set: str = "grid", objective: str = "speedup",
          per_class: int | None = None,
          cost_bound: float | None = None) -> design_search.SearchResult:
    """One seeded hillclimb chain; see `design_search.run_search`."""
    return design_search.run_search(
        algorithm="chain", objective=objective, eval_set=eval_set,
        seed=seed, generations=generations, branch=branch,
        per_class=per_class, cost_bound=cost_bound)


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--generations", type=int, default=8)
    ap.add_argument("--branch", type=int, default=6,
                    help="mutations proposed per generation")
    ap.add_argument("--eval-set", choices=("grid", "corpus"),
                    default="grid")
    ap.add_argument("--objective", choices=design_search.OBJECTIVES,
                    default="speedup")
    ap.add_argument("--per-class", type=int, default=None)
    ap.add_argument("--cost-bound", type=float, default=None)
    args = ap.parse_args(argv)
    result = climb(seed=args.seed, generations=args.generations,
                   branch=args.branch, eval_set=args.eval_set,
                   objective=args.objective, per_class=args.per_class,
                   cost_bound=args.cost_bound)
    best = result.best
    print(json.dumps({
        "best": best.design.to_json(), "label": best.design.label,
        "score": best.score, "cost": best.cost,
        "geomean_speedup": best.geomean_speedup,
        "dominant_path": best.dominant_path,
        "generations": len(result.history) - 1,
        "evaluated": len(result.evaluated),
    }, indent=2))


if __name__ == "__main__":
    main()
