"""Parameter-sensitivity sweep subsystem: the wide params axis.

The repro's artifacts evaluate a single calibrated `SimParams` point
(`ara_calibrated.json`); this module asks the question the paper's
calibration leaves open — *which microarchitectural knobs does the
reproduced speedup actually hinge on?* — by stacking
hundreds-to-thousands of `SimParams` variants into one wide P axis and
running them through `repro.core.batch_sim.BatchAraSimulator` in a
single batched call per cache-miss signature.

Three sampler designs build the axis around a center point
(`Design.variants[0]` is always the unmodified center):

  * `oat_design`   — per-field 1-D traversals (one-at-a-time): every
    knob swept across its bounds with all other knobs at the center;
  * `pair_design`  — pairwise 2-D grids for interaction surfaces;
  * `lhs_design`   — Latin-hypercube joint samples for robustness bands
    (`lhs_candidates` is the raw stratified sampler, reused by
    `repro.core.calibration` for population seeding).

Reductions collapse the `(kernel x opt x variant)` cycle/stall tensors
to per-knob **elasticities** (d ln cycles / d ln knob), **tornado
rankings** (per-kernel speedup swing, the paper-facing "what does the
1.33x geomean hinge on" ordering), and **gap-closed-ratio** values
(fraction of baseline stall cycles the full optimization removes, per
variant — a surface over `pair_design` grids).

Execution: `run_grid` is cache-backed through the content-addressed
`repro.launch.sweep_cache` (cells are keyed by the params block, so a
re-run of the same design is free) and chunks the P axis
(`repro.core.api.simulate(..., p_chunk=...)`) so `large`-profile grids
fit memory.  This is the first subsystem where the **jax backend is
the intended default for wide grids on accelerator hosts**: strategy
resolution now lives in `repro.core.api.resolve_plan`, which picks the
backend (and the scan-vs-assoc instruction-axis method) from the
measured crossover points recorded in docs/backends.md, so auto never
degrades a laptop/CI run.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.analysis.attribution import phase_decompose_grid
from repro.core import api
from repro.core.batch_sim import BatchAraSimulator
from repro.core.calibration import SPACE
from repro.core.calibration import load as load_calibrated
from repro.core.isa import KernelTrace, MachineConfig, OptConfig
from repro.core.simulator import SimParams, SimResult
from repro.core.stalls import PATH_INDICES, STALL_CATEGORIES
from repro.core.traces import stack_traces
from repro.launch.sweep_cache import (SweepCache, cell_key,
                                      params_fingerprint,
                                      trace_fingerprint)
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

#: Critical path each `SimParams` knob acts on (docs/sensitivity.md
#: documents the same mapping; `div_factor` is inherent serialization —
#: it moves ideal time, not a stall category).
KNOB_PATHS: dict[str, str] = {
    "mem_latency": "mem_supply",
    "prefetch_hit": "mem_supply",
    "tx_ovh_base": "mem_supply",
    "tx_ovh_opt": "mem_supply",
    "idx_ovh_base": "mem_supply",
    "idx_ovh_opt": "mem_supply",
    "rw_turnaround_base": "mem_supply",
    "rw_turnaround_opt": "mem_supply",
    "store_commit_base": "mem_supply",
    "store_commit_opt": "mem_supply",
    "issue_gap_base": "dep_issue",
    "issue_gap_opt": "dep_issue",
    "war_release_ovh": "dep_issue",
    "d_chain_base": "operand",
    "d_fwd": "operand",
    "conflict_base": "operand",
    "conflict_opt": "operand",
    "queue_adv_base": "operand",
    "queue_adv_opt": "operand",
    "div_factor": "inherent",
}

_SPACE_BOUNDS = {name: (lo, hi) for name, lo, hi in SPACE}

#: Grid width above which ``auto`` prefers the jax backend on
#: accelerator hosts — the canonical measured crossover now lives in
#: `repro.core.api.JAX_WIDTH_CROSSOVER` (docs/backends.md records the
#: measurements); this alias is kept for existing imports.
JAX_WIDTH_THRESHOLD = api.JAX_WIDTH_CROSSOVER

#: Default P-axis chunk so `large`-profile grids fit memory: hazard
#: state is `(B, R, W, NCOMP)` with `W = O * P`, so a 2-opt x 256-param
#: chunk stays in the tens of MB even for register-rich matrix kernels.
DEFAULT_P_CHUNK = 256


def all_knobs() -> tuple[str, ...]:
    """Every `SimParams` field, in declaration order."""
    return tuple(f.name for f in dataclasses.fields(SimParams))


def knob_bounds(center: SimParams, name: str, span: float = 2.0,
                local: bool = False) -> tuple[float, float]:
    """Traversal bounds for one knob.

    Calibration-searched knobs reuse the `calibration.SPACE` bounds
    (widened to include the center if it drifted outside); the rest get
    a multiplicative `[center/span, center*span]` band, or `[0, 1]` for
    zero-valued centers (additive knobs like `store_commit_opt`).
    `local` skips the SPACE branch and always uses the multiplicative
    band — the LHS robustness design jitters *around* the calibrated
    point rather than re-exploring the whole search space.
    """
    c = float(getattr(center, name))
    if name in _SPACE_BOUNDS and not local:
        lo, hi = _SPACE_BOUNDS[name]
        return min(lo, c), max(hi, c)
    if c == 0.0:
        return 0.0, 1.0
    return c / span, c * span


@dataclasses.dataclass(frozen=True)
class Design:
    """A params-axis design: the P axis plus its bookkeeping.

    `variants[0]` is always the unmodified center; `assignments[i]`
    records exactly the knob overrides applied to `variants[i]` (empty
    for the center), which is what the reductions use to find each
    knob's traversal.
    """
    kind: str                              # "oat" | "pair" | "lhs"
    center: SimParams
    knobs: tuple[str, ...]
    variants: tuple[SimParams, ...]
    assignments: tuple[Mapping[str, float], ...]

    @property
    def width(self) -> int:
        return len(self.variants)

    def indices_for(self, knob: str) -> list[int]:
        """Variant indices on `knob`'s traversal (center excluded)."""
        return [i for i, a in enumerate(self.assignments) if knob in a]

    def fingerprint(self) -> str:
        """Content hash of the params block (all variants, in order)."""
        return params_fingerprint(self.variants)[:16]


def center_params(center: SimParams | None = None) -> SimParams:
    """Default design center: the calibrated point."""
    return center if center is not None else load_calibrated()


def oat_design(center: SimParams | None = None,
               knobs: Sequence[str] | None = None,
               points: int = 5, span: float = 2.0) -> Design:
    """One-at-a-time design: per-field 1-D traversals.

    `points` evenly-spaced values per knob across `knob_bounds`, all
    other knobs held at the center — `1 + len(knobs) * points`
    variants total.
    """
    center = center_params(center)
    knobs = tuple(knobs if knobs is not None else all_knobs())
    variants: list[SimParams] = [center]
    assigns: list[dict[str, float]] = [{}]
    for k in knobs:
        lo, hi = knob_bounds(center, k, span)
        for v in np.linspace(lo, hi, points):
            variants.append(dataclasses.replace(center, **{k: float(v)}))
            assigns.append({k: float(v)})
    return Design("oat", center, knobs, tuple(variants), tuple(assigns))


def pair_design(center: SimParams | None = None,
                pair: tuple[str, str] = ("mem_latency", "issue_gap_base"),
                points: int = 5, span: float = 2.0) -> Design:
    """Pairwise 2-D grid: `points x points` joint settings of two knobs."""
    center = center_params(center)
    f1, f2 = pair
    g1 = np.linspace(*knob_bounds(center, f1, span), points)
    g2 = np.linspace(*knob_bounds(center, f2, span), points)
    variants: list[SimParams] = [center]
    assigns: list[dict[str, float]] = [{}]
    for v1 in g1:
        for v2 in g2:
            over = {f1: float(v1), f2: float(v2)}
            variants.append(dataclasses.replace(center, **over))
            assigns.append(over)
    return Design("pair", center, (f1, f2), tuple(variants),
                  tuple(assigns))


def lhs_candidates(space: Sequence[tuple[str, float, float]], n: int,
                   rng) -> list[dict[str, float]]:
    """`n` Latin-hypercube samples over a `(name, lo, hi)` space.

    Each dimension is split into `n` equal strata with exactly one
    sample per stratum (independently permuted per dimension), so small
    populations still cover every knob's full range — this is the
    sampler `repro.core.calibration.calibrate` seeds its random-search
    populations with.  `rng` is a `random.Random` (stdlib), matching
    calibration's seeded search.
    """
    cols: dict[str, list[float]] = {}
    for name, lo, hi in space:
        strata = list(range(n))
        rng.shuffle(strata)
        cols[name] = [lo + (s + rng.random()) * (hi - lo) / n
                      for s in strata]
    return [{name: cols[name][i] for name, _, _ in space}
            for i in range(n)]


def lhs_design(center: SimParams | None = None,
               knobs: Sequence[str] | None = None,
               n: int = 64, span: float = 1.25, seed: int = 0) -> Design:
    """Latin-hypercube joint design: `n` stratified samples of all
    `knobs` at once, jittered in a local multiplicative `span` band
    around the center (robustness of the headline numbers to joint
    calibration error, not a re-exploration of the search space)."""
    import random
    center = center_params(center)
    knobs = tuple(knobs if knobs is not None else all_knobs())
    space = [(k, *knob_bounds(center, k, span, local=True))
             for k in knobs]
    variants: list[SimParams] = [center]
    assigns: list[dict[str, float]] = [{}]
    for over in lhs_candidates(space, n, random.Random(seed)):
        variants.append(dataclasses.replace(center, **over))
        assigns.append(over)
    return Design("lhs", center, knobs, tuple(variants), tuple(assigns))


# -- execution ------------------------------------------------------------

# Backend probes: canonical implementations moved to `repro.core.api`
# with the simulate() redesign; re-exported here for existing callers.
have_jax = api.have_jax
jax_accelerator = api.jax_accelerator


def resolve_backend(backend: str, width: int) -> str:
    """Resolve ``auto`` to a concrete engine by grid width and host.

    Thin wrapper over `repro.core.api.resolve_plan`, which holds the
    measured numpy/jax/assoc crossover points (docs/backends.md); kept
    because sweep callers only need the backend half of the plan."""
    return api.resolve_plan(backend=backend, width=width).backend


def run_grid(traces: Mapping[str, KernelTrace],
             params_list: Sequence[SimParams],
             opts: Sequence[OptConfig] = (OptConfig.baseline(),
                                          OptConfig.full()),
             *, mc: MachineConfig = MachineConfig(),
             backend: str = "auto", method: str = "auto",
             attribution: bool = True,
             cache: SweepCache | None = None, use_cache: bool = True,
             p_chunk: int | None = DEFAULT_P_CHUNK,
             bucket: str = "auto", shard: str = "auto",
             sim: BatchAraSimulator | None = None
             ) -> dict[tuple[str, str, int], SimResult]:
    """Evaluate `(trace x opt x params)` cells, batch-running only
    cache misses; returns `{(trace_key, opt.label, param_index):
    SimResult}`.

    The wide-params analogue of `benchmarks.gridlib.Grid.cells`: cells
    are keyed content-addressed on the params block (`sweep_cache
    .cell_key` hashes the full `SimParams`).  With `attribution`,
    results carry the stall decomposition plus the phase-split columns
    (`SimResult.phases`), exactly as fig6's grid pass stores them.

    Caching vs. backends: only numpy-computed cells are persisted (the
    cache's bit-exactness contract — jax results are float64-allclose,
    not bit-exact, and must never be served to scalar consumers), so
    ``auto`` is resolved against each *miss* batch's width, not the
    design's: a warm or mostly-warm re-run stays on cached numpy cells
    and any small remainder runs (and persists) through numpy, while a
    cold wide grid on an accelerator host goes through the compiled
    jax scan — served to the caller but re-simulated on the next cold
    run.  `method` picks the jax instruction-axis algorithm
    (``scan``/``assoc``/``auto``, see `repro.core.api.resolve_plan`);
    assoc-computed cells are never persisted either.

    ``bucket``/``shard`` are the execution-planner axes (shape
    bucketing of mixed-length miss batches, P-axis device sharding of
    wide designs via `repro.launch.mesh`); the default ``auto`` defers
    to the measured crossovers in `resolve_plan` and neither axis
    affects results or cache keys (`sweep_cache.cell_key` hashes
    inputs, not execution strategy).
    """
    opts = list(opts)
    params_list = list(params_list)
    cache = cache if cache is not None else SweepCache()
    simulator = sim if sim is not None else BatchAraSimulator(mc)
    obs_metrics.counter("sensitivity.cells").inc(
        len(traces) * len(opts) * len(params_list))

    out: dict[tuple[str, str, int], SimResult] = {}
    keys: dict[tuple[str, str, int], str] = {}
    by_sig: dict[tuple[tuple[int, ...], tuple[int, ...]], list[str]] = {}
    with obs_spans.span("cache.lookup", n_traces=len(traces),
                        n_opts=len(opts),
                        n_params=len(params_list)) as lk:
        for tname, tr in traces.items():
            fp = trace_fingerprint(tr)     # hash the stream once
            missing: set[tuple[int, int]] = set()
            for pi, p in enumerate(params_list):
                for oi, opt in enumerate(opts):
                    ck = cell_key(tr, opt, p, mc, trace_fp=fp)
                    keys[(tname, opt.label, pi)] = ck
                    res = (cache.get_result(ck, tr.name,
                                            attribution=attribution,
                                            require_phases=attribution)
                           if use_cache else None)
                    if res is None:
                        missing.add((oi, pi))
                    else:
                        out[(tname, opt.label, pi)] = res
            if missing:
                # Run the bounding (opts x params) product of the missing
                # cells: designs re-run all-or-nothing in practice, so the
                # product rarely exceeds the miss set.
                sig = (tuple(sorted({oi for oi, _ in missing})),
                       tuple(sorted({pi for _, pi in missing})))
                by_sig.setdefault(sig, []).append(tname)
        lk.set(hit_cells=len(out))

    for (ois, pis), tnames in by_sig.items():
        run_opts = [opts[oi] for oi in ois]
        run_params = [params_list[pi] for pi in pis]
        run_traces = [traces[t] for t in tnames]
        stacked = stack_traces(run_traces)
        plan = api.resolve_plan(backend=backend, method=method,
                                width=len(ois) * len(pis),
                                n_instrs=int(stacked.kind.shape[1]))
        # Only numpy scan cells are bit-exact against the scalar
        # simulator, so only those are persisted (cache contract).
        persist = use_cache and plan.backend == "numpy" \
            and plan.method == "scan"
        batch = api.simulate(stacked, run_opts, run_params,
                             mc=mc, backend=plan.backend,
                             method=plan.method,
                             attribution=attribution,
                             p_chunk=p_chunk, bucket=bucket,
                             shard=shard, sim=simulator)
        pg = (phase_decompose_grid(run_traces, batch, mc=mc,
                                   params=run_params)
              if attribution else None)
        for bi, tname in enumerate(tnames):
            for ci, oi in enumerate(ois):
                for cj, pi in enumerate(pis):
                    res = SimResult(
                        kernel=traces[tname].name,
                        cycles=float(batch.cycles[bi, ci, cj]),
                        flops=int(batch.flops[bi]),
                        bytes=int(batch.bytes[bi]), timings=[],
                        busy_fpu=float(batch.busy_fpu[bi, ci, cj]),
                        busy_bus=float(batch.busy_bus[bi, ci, cj]),
                        ideal=(float(batch.ideal[bi, ci, cj])
                               if batch.ideal is not None else 0.0),
                        stalls=(batch.stalls[bi, ci, cj].copy()
                                if batch.stalls is not None else None),
                        phases=(pg.columns(bi, ci, cj)
                                if pg is not None else None))
                    out[(tname, opts[oi].label, pi)] = res
                    if persist:
                        cache.put_result(keys[(tname, opts[oi].label, pi)],
                                         res)
    return out


@dataclasses.dataclass(frozen=True)
class SweepTensors:
    """Dense `(B, O, P)` tensors assembled from `run_grid` cells."""
    names: tuple[str, ...]                 # (B,) trace keys
    opt_labels: tuple[str, ...]            # (O,)
    cycles: np.ndarray                     # (B, O, P)
    ideal: np.ndarray | None               # (B, O, P)
    stalls: np.ndarray | None              # (B, O, P, 9)
    ii_eff: np.ndarray | None              # (B, O, P) phase column


def tensors_from_cells(cells: Mapping[tuple[str, str, int], SimResult],
                       names: Sequence[str],
                       opt_labels: Sequence[str],
                       n_params: int) -> SweepTensors:
    """Re-assemble `run_grid`'s per-cell dict into dense grid tensors
    (mixing cache hits and freshly-computed cells is fine — both carry
    the same numbers, bit-exact on the numpy backend)."""
    names = tuple(names)
    opt_labels = tuple(opt_labels)
    B, O, P = len(names), len(opt_labels), n_params
    cycles = np.zeros((B, O, P))
    first = cells[(names[0], opt_labels[0], 0)]
    attrib = first.stalls is not None
    ideal = np.zeros((B, O, P)) if attrib else None
    stalls = np.zeros((B, O, P, len(STALL_CATEGORIES))) if attrib else None
    ii_eff = (np.zeros((B, O, P))
              if attrib and first.phases is not None else None)
    for bi, tname in enumerate(names):
        for oi, ol in enumerate(opt_labels):
            for pi in range(P):
                res = cells[(tname, ol, pi)]
                cycles[bi, oi, pi] = res.cycles
                if attrib:
                    ideal[bi, oi, pi] = res.ideal
                    stalls[bi, oi, pi] = res.stalls
                    if ii_eff is not None and res.phases is not None:
                        ii_eff[bi, oi, pi] = res.phases["ii_eff"]
    return SweepTensors(names, opt_labels, cycles, ideal, stalls, ii_eff)


def sweep_design(traces: Mapping[str, KernelTrace], design: Design,
                 opts: Sequence[OptConfig] = (OptConfig.baseline(),
                                              OptConfig.full()),
                 **kwargs) -> SweepTensors:
    """`run_grid` a design and assemble the dense tensors."""
    opts = list(opts)
    cells = run_grid(traces, design.variants, opts, **kwargs)
    return tensors_from_cells(cells, list(traces),
                              [o.label for o in opts], design.width)


# -- reductions -----------------------------------------------------------

def _elasticity(vals: np.ndarray, cyc: np.ndarray,
                center_v: float) -> float:
    """d ln(output) / d ln(knob) over a 1-D traversal (endpoint secant).

    Exactly 0.0 for a knob with zero influence (the endpoint outputs
    are then bit-identical, so the numerator is exactly zero).  Knobs
    whose traversal touches zero fall back to a relative secant
    normalized by the center value (log-log is undefined there).
    """
    lo_i, hi_i = int(np.argmin(vals)), int(np.argmax(vals))
    dc = cyc[hi_i] - cyc[lo_i]
    if dc == 0.0 or vals[hi_i] == vals[lo_i]:
        return 0.0
    if vals[lo_i] > 0.0 and cyc[lo_i] > 0.0 and cyc[hi_i] > 0.0:
        return float(np.log(cyc[hi_i] / cyc[lo_i])
                     / np.log(vals[hi_i] / vals[lo_i]))
    scale = center_v if center_v > 0.0 else vals[hi_i] - vals[lo_i]
    mid = 0.5 * (cyc[hi_i] + cyc[lo_i])
    return float((dc / mid) / ((vals[hi_i] - vals[lo_i]) / scale))


def gap_closed(t: SweepTensors, base_col: int = 0,
               full_col: int = -1, eps: float = 1e-9) -> np.ndarray:
    """`(B, P)` fraction of baseline *stall* cycles the full
    configuration removes, per params variant (the sensitivity analogue
    of `analysis.attribution.gap_closed_by_path`, collapsed over
    paths).  Needs attribution tensors."""
    if t.ideal is None:
        raise ValueError("gap_closed needs attribution tensors "
                         "(sweep_design(..., attribution=True))")
    stall_base = t.cycles[:, base_col, :] - t.ideal[:, base_col, :]
    closed = t.cycles[:, base_col, :] - t.cycles[:, full_col, :]
    return closed / np.maximum(stall_base, eps)


def knob_rows(design: Design, t: SweepTensors, base_col: int = 0,
              full_col: int = -1) -> list[dict]:
    """Per-`(kernel, knob)` sensitivity rows for an OAT design.

    Columns: knob metadata (critical path, center/lo/hi values), center
    cycles and speedup, per-knob elasticities of baseline cycles,
    full-opt cycles and speedup, tornado swings and per-kernel rank
    (descending speedup swing, deterministic name tie-break so the
    ordering is invariant under design/param reordering), gap-closed
    ratio at the traversal endpoints, the steady-state `ii_eff` swing,
    and the stall category the traversal moves most.
    """
    if design.kind != "oat":
        raise ValueError(f"knob_rows needs an 'oat' design, got "
                         f"{design.kind!r}")
    rows: list[dict] = []
    gc = gap_closed(t, base_col, full_col) if t.ideal is not None else None
    for bi, kernel in enumerate(t.names):
        cyc_b = t.cycles[bi, base_col]
        cyc_f = t.cycles[bi, full_col]
        speedup = cyc_b / np.maximum(cyc_f, 1e-9)
        kernel_rows: list[dict] = []
        for knob in design.knobs:
            idx = [0] + design.indices_for(knob)   # center + traversal
            vals = np.array([design.assignments[i].get(
                knob, getattr(design.center, knob)) for i in idx])
            center_v = float(getattr(design.center, knob))
            lo_i, hi_i = idx[int(np.argmin(vals))], idx[int(np.argmax(vals))]
            row = {
                "kernel": kernel, "knob": knob,
                "path": KNOB_PATHS.get(knob, "unknown"),
                "center": center_v,
                "lo": float(vals.min()), "hi": float(vals.max()),
                "cycles_base": float(cyc_b[0]),
                "speedup": float(speedup[0]),
                "elast_base": _elasticity(vals, cyc_b[idx], center_v),
                "elast_full": _elasticity(vals, cyc_f[idx], center_v),
                "elast_speedup": _elasticity(vals, speedup[idx],
                                             center_v),
                "swing_base": float(cyc_b[idx].max() - cyc_b[idx].min()),
                "swing_speedup": float(speedup[idx].max()
                                       - speedup[idx].min()),
            }
            if gc is not None:
                row["gap_closed_lo"] = float(gc[bi, lo_i])
                row["gap_closed_hi"] = float(gc[bi, hi_i])
            if t.ii_eff is not None:
                ii = t.ii_eff[bi, base_col, idx]
                row["dii_eff_base"] = float(ii.max() - ii.min())
            if t.stalls is not None:
                delta = (t.stalls[bi, base_col, hi_i]
                         - t.stalls[bi, base_col, lo_i])
                row["top_moved"] = ("none" if not np.abs(delta).any()
                                    else STALL_CATEGORIES[
                                        int(np.argmax(np.abs(delta)))])
            kernel_rows.append(row)
        # Tornado rank: 1 = largest speedup swing; ties break on the
        # knob name so the ranking never depends on traversal order.
        ranked = sorted(kernel_rows,
                        key=lambda r: (-r["swing_speedup"], r["knob"]))
        for rank, row in enumerate(ranked, 1):
            row["tornado_rank"] = rank
        rows.extend(kernel_rows)
    return rows


def pair_rows(design: Design, t: SweepTensors, base_col: int = 0,
              full_col: int = -1) -> list[dict]:
    """Per-`(kernel, variant)` surface rows for a pairwise design:
    joint knob values, cycles, speedup, and the gap-closed ratio — a
    `(points x points)` surface per kernel."""
    if design.kind != "pair":
        raise ValueError(f"pair_rows needs a 'pair' design, got "
                         f"{design.kind!r}")
    f1, f2 = design.knobs
    gc = gap_closed(t, base_col, full_col) if t.ideal is not None else None
    rows = []
    for bi, kernel in enumerate(t.names):
        for pi in range(1, design.width):       # skip the center point
            a = design.assignments[pi]
            row = {
                "kernel": kernel, f1: a[f1], f2: a[f2],
                "cycles_base": float(t.cycles[bi, base_col, pi]),
                "cycles_full": float(t.cycles[bi, full_col, pi]),
                "speedup": float(t.cycles[bi, base_col, pi]
                                 / max(t.cycles[bi, full_col, pi], 1e-9)),
            }
            if gc is not None:
                row["gap_closed"] = float(gc[bi, pi])
            rows.append(row)
    return rows


def lhs_rows(design: Design, t: SweepTensors, base_col: int = 0,
             full_col: int = -1) -> list[dict]:
    """Per-kernel robustness bands over a Latin-hypercube design: how
    far the speedup and gap-closed ratio move when *all* knobs jitter
    jointly around the calibrated point."""
    if design.kind != "lhs":
        raise ValueError(f"lhs_rows needs an 'lhs' design, got "
                         f"{design.kind!r}")
    gc = gap_closed(t, base_col, full_col) if t.ideal is not None else None
    rows = []
    joint = slice(1, design.width)              # exclude the center
    for bi, kernel in enumerate(t.names):
        sp = (t.cycles[bi, base_col, joint]
              / np.maximum(t.cycles[bi, full_col, joint], 1e-9))
        sp_c = (t.cycles[bi, base_col, 0]
                / max(t.cycles[bi, full_col, 0], 1e-9))
        row = {"kernel": kernel, "n": design.width - 1,
               "speedup_center": float(sp_c),
               "speedup_min": float(sp.min()),
               "speedup_mean": float(sp.mean()),
               "speedup_max": float(sp.max())}
        if gc is not None:
            row["gap_closed_min"] = float(gc[bi, joint].min())
            row["gap_closed_max"] = float(gc[bi, joint].max())
        rows.append(row)
    return rows


# -- Sobol / variance decomposition ---------------------------------------

def sobol_design(center: SimParams | None = None,
                 knobs: Sequence[str] | None = None,
                 n: int = 16, seed: int = 0, span: float = 2.0,
                 space: Sequence[tuple[str, float, float]] | None = None
                 ) -> Design:
    """Saltelli sampling design for Sobol variance decomposition.

    Builds two independent Latin-hypercube matrices ``A`` and ``B``
    (`lhs_candidates`, seeded), plus one ``AB_i`` matrix per knob —
    ``A`` with column *i* replaced from ``B`` — for ``n * (k + 2)``
    variants total (the classic first/total-order estimator layout).
    ``variants[0]`` stays the unmodified center, matching every other
    design; the sample blocks follow in ``A, B, AB_0..AB_{k-1}`` order
    and `sobol_indices` re-derives the block structure from the width.

    `space` pins explicit ``(name, lo, hi)`` bounds (the searcher
    passes `launch.costmodel.SEARCH_SPACE` dims); otherwise bounds come
    from `knob_bounds` around the center.
    """
    import random
    center = center_params(center)
    if space is None:
        knobs = tuple(knobs if knobs is not None else all_knobs())
        space = [(k, *knob_bounds(center, k, span)) for k in knobs]
    else:
        space = [(str(k), float(lo), float(hi)) for k, lo, hi in space]
        knobs = tuple(k for k, _, _ in space)
    rng = random.Random(seed)
    a_rows = lhs_candidates(space, n, rng)
    b_rows = lhs_candidates(space, n, rng)
    sample_rows = list(a_rows) + list(b_rows)
    for k in knobs:
        sample_rows += [dict(a, **{k: b[k]})
                        for a, b in zip(a_rows, b_rows)]
    variants: list[SimParams] = [center]
    assigns: list[dict[str, float]] = [{}]
    for over in sample_rows:
        variants.append(dataclasses.replace(center, **over))
        assigns.append(dict(over))
    return Design("sobol", center, knobs, tuple(variants), tuple(assigns))


def _sobol_blocks(design: Design) -> int:
    """Per-block sample count `n` of a Saltelli design."""
    if design.kind != "sobol":
        raise ValueError(f"need a 'sobol' design, got {design.kind!r}")
    k = len(design.knobs)
    n, rem = divmod(design.width - 1, k + 2)
    if n < 2 or rem:
        raise ValueError(f"width {design.width} is not 1 + n*(k+2) "
                         f"for k={k} knobs")
    return n


def sobol_indices(design: Design, f: np.ndarray) -> dict[str, dict]:
    """First-order and total-order Sobol indices of one output.

    `f` is the output evaluated at every design variant (aligned with
    ``design.variants``; the center at index 0 is ignored).  Returns
    ``{knob: {"Si", "STi", "interaction"}}`` with the Saltelli
    first-order estimator ``Si = mean(fB * (fAB_i - fA)) / V`` and the
    Jansen total-order estimator ``STi = mean((fA - fAB_i)^2) / 2V``;
    ``interaction = max(STi - Si, 0)`` is the knob's
    involved-in-interactions mass the searcher uses to pick co-move
    pairs.

    A knob with provably zero influence (e.g. any opt-side knob when
    only the baseline corner is evaluated) yields **exactly** 0.0 for
    both indices: the numpy backend is bit-exact, so ``fAB_i == fA``
    elementwise and both numerators are exact zeros — a property the
    tests pin.  A flat output (``V == 0``) yields all-zero indices.
    """
    f = np.asarray(f, dtype=np.float64)
    n = _sobol_blocks(design)
    fA = f[1:1 + n]
    fB = f[1 + n:1 + 2 * n]
    V = float(np.var(np.concatenate([fA, fB])))
    out: dict[str, dict] = {}
    for i, knob in enumerate(design.knobs):
        lo = 1 + (2 + i) * n
        fABi = f[lo:lo + n]
        if V == 0.0:
            si = sti = 0.0
        else:
            si = float(np.mean(fB * (fABi - fA)) / V)
            sti = float(np.mean((fA - fABi) ** 2) / (2.0 * V))
        out[knob] = {"Si": si, "STi": sti,
                     "interaction": max(sti - si, 0.0)}
    return out


def sobol_rows(design: Design, t: SweepTensors, base_col: int = 0,
               full_col: int = -1) -> list[dict]:
    """Per-`(kernel, knob)` Sobol rows plus a ``geomean`` pseudo-kernel.

    Outputs decomposed: baseline cycles (``si_base``/``sti_base``) and
    the full-vs-base speedup (``si_speedup``/``sti_speedup``); the
    ``geomean`` rows decompose the geomean speedup across kernels —
    the quantity the design searcher optimizes, so its ``interaction``
    column is what ranks co-move pairs.
    """
    rows: list[dict] = []
    speedups = t.cycles[:, base_col, :] / np.maximum(
        t.cycles[:, full_col, :], 1e-9)
    for bi, kernel in enumerate(t.names):
        by_base = sobol_indices(design, t.cycles[bi, base_col])
        by_sp = sobol_indices(design, speedups[bi])
        for knob in design.knobs:
            rows.append({
                "kernel": kernel, "knob": knob,
                "path": KNOB_PATHS.get(knob, "unknown"),
                "si_base": by_base[knob]["Si"],
                "sti_base": by_base[knob]["STi"],
                "si_speedup": by_sp[knob]["Si"],
                "sti_speedup": by_sp[knob]["STi"],
                "interaction": by_sp[knob]["interaction"],
            })
    log_sp = np.log(np.maximum(speedups, 1e-30))
    by_geo = sobol_indices(design, np.exp(log_sp.mean(axis=0)))
    for knob in design.knobs:
        rows.append({
            "kernel": "geomean", "knob": knob,
            "path": KNOB_PATHS.get(knob, "unknown"),
            "si_base": 0.0, "sti_base": 0.0,
            "si_speedup": by_geo[knob]["Si"],
            "sti_speedup": by_geo[knob]["STi"],
            "interaction": by_geo[knob]["interaction"],
        })
    return rows


def co_move_pairs(indices: Mapping[str, Mapping[str, float]],
                  top: int = 3) -> list[tuple[str, str]]:
    """Knob pairs worth mutating jointly, from Sobol interactions.

    A knob's ``interaction`` mass (total-order minus first-order) says
    it participates in *some* interaction; the strongest candidates for
    the partner are the other high-interaction knobs, and mechanisms on
    the same critical path interact through shared stall terms far more
    often than across paths — so pairs are ranked by the product of the
    two knobs' interaction masses with same-`KNOB_PATHS`-path pairs
    first, name-ordered for determinism.  Pairs with zero joint mass
    are never proposed.
    """
    strengths = {k: max(float(v.get("interaction", 0.0)), 0.0)
                 for k, v in indices.items()}
    names = sorted(strengths)
    scored = []
    for i, k1 in enumerate(names):
        for k2 in names[i + 1:]:
            joint = strengths[k1] * strengths[k2]
            if joint <= 0.0:
                continue
            same = KNOB_PATHS.get(k1) == KNOB_PATHS.get(k2)
            scored.append((not same, -joint, k1, k2))
    scored.sort()
    return [(k1, k2) for _, _, k1, k2 in scored[:top]]


def path_stall_delta(t: SweepTensors, pi_from: int, pi_to: int,
                     opt_col: int = 0) -> dict[str, np.ndarray]:
    """`(B,)` per-critical-path stall deltas between two variants —
    used by the locality property test (a knob's traversal should move
    its own critical path whenever it moves cycles at all)."""
    if t.stalls is None:
        raise ValueError("path_stall_delta needs attribution tensors")
    delta = t.stalls[:, opt_col, pi_to] - t.stalls[:, opt_col, pi_from]
    return {path: delta[:, list(idx)].sum(axis=-1)
            for path, idx in PATH_INDICES.items()}


__all__ = [
    "KNOB_PATHS", "JAX_WIDTH_THRESHOLD", "DEFAULT_P_CHUNK", "Design",
    "all_knobs", "knob_bounds", "center_params", "oat_design",
    "pair_design", "lhs_design", "lhs_candidates", "resolve_backend",
    "have_jax", "run_grid", "sweep_design", "SweepTensors",
    "tensors_from_cells", "gap_closed", "knob_rows", "pair_rows",
    "lhs_rows", "path_stall_delta", "sobol_design", "sobol_indices",
    "sobol_rows", "co_move_pairs",
]
