"""End-to-end training driver.

CPU-scale real runs (examples use this) and the production-mesh path used
on real hardware.  On this container, run e.g.:

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2.5-3b --preset smoke --steps 100

Presets:
  smoke — reduced config, runs on 1 CPU (CI / demo).
  full  — the assigned config on the production mesh (real TPU pods; on
          CPU it will lower but be impractically slow to execute).
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import pathlib

import jax

from repro.configs import ARCHS, reduced
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM
from repro.models import init_model
from repro.train import optimizer as opt
from repro.train.loop import LoopConfig, run
from repro.train.step import StepConfig, init_state, make_train_step

REPO = pathlib.Path(__file__).resolve().parents[3]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=0,
                    help="override layer count (smoke preset)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log", default="")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.preset == "smoke":
        cfg = reduced(cfg)
        if args.layers:
            cfg = dataclasses.replace(cfg, n_layers=args.layers)

    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} "
          f"batch={args.batch} seq={args.seq}")

    sched = opt.cosine_schedule(args.lr, warmup=max(args.steps // 20, 1),
                                total=args.steps)
    step_cfg = StepConfig(microbatches=args.microbatches,
                          adamw=opt.AdamWConfig(lr=args.lr),
                          schedule=sched)
    train_step = jax.jit(make_train_step(cfg, step_cfg), donate_argnums=(0,))
    state = init_state(params, seed=args.seed)

    data = SyntheticLM(cfg, batch=args.batch, seq_len=args.seq,
                       seed=args.seed)
    ckpt_dir = args.ckpt_dir or str(REPO / "experiments" / "train" /
                                    cfg.name)
    ckpt = CheckpointManager(ckpt_dir, keep=2)
    res = run(train_step, state, data, ckpt,
              LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         log_every=10),
              log_path=args.log or None)
    first, last = res.history[0], res.history[-1]
    print(f"step {first['step']}: loss={first['loss']:.4f}  ->  "
          f"step {last['step']}: loss={last['loss']:.4f}")
    print(f"mean step time {sum(h['time_s'] for h in res.history) / len(res.history):.3f}s; "
          f"stragglers={res.straggler_steps}; resumed_from={res.resumed_from}")


if __name__ == "__main__":
    main()
