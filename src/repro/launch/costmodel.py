import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

DOC = """Scan-corrected cost extrapolation for §Roofline.

XLA's cost_analysis counts while/scan bodies ONCE regardless of trip count
(verified empirically), so the production compile (scan-over-layers,
microbatch scan, chunked-attention scan) underreports FLOPs/bytes/
collective bytes.  This module recovers true totals by lowering *unrolled*
reduced-depth variants and solving the linear structure:

    cost(L, c) = const + L * (layer_const + alpha * c)

where L = layer count and c = inner chunk size (attention KV chunk or SSD
chunk; the body of a chunk-scan costs ~alpha*c and executes S/c times, so
the true per-layer cost is layer_const + alpha * S).  Three measurements —
(L1, c1), (2*L1, c1), (L1, c2) — identify all terms.  Decode cells have no
chunk scan: two measurements suffice.

The analysis variants run with remat off and microbatches=1; the production
compile (dryrun.py) retains remat+scan and is the memory-fit proof.
"""

import argparse
import json
import pathlib
from typing import Any

from repro.configs import ARCHS, SHAPES, skip_reason
from repro.core.roofline import RooflineTerms

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / \
    "dryrun"

METRICS = ("flops", "hbm_bytes", "coll_total", "coll_ar", "coll_ag",
           "coll_rs", "coll_a2a", "coll_cp")


def _measure(arch: str, shape_name: str, multi_pod: bool,
             n_layers: int, chunk_field: str | None, chunk: int | None,
             extra_overrides: dict | None = None) -> dict[str, float]:
    from repro.launch.dryrun import lower_cell
    overrides: dict[str, Any] = {}
    if extra_overrides:
        overrides.update(extra_overrides)
    # Analysis knobs (and the chunk-variation measurement) override any
    # experiment-level settings of the same fields.
    overrides.update({"n_layers": n_layers, "scan_layers": False,
                      "remat": False, "microbatches": 1})
    if chunk_field and chunk:
        overrides[chunk_field] = chunk
    rec = lower_cell(arch, shape_name, multi_pod, overrides)
    if rec["status"] != "ok":
        raise RuntimeError(f"analysis lowering failed: {rec}")
    by_type = rec["collectives"]["bytes_by_type"]
    return {
        "flops": rec["cost"]["flops_per_device"],
        "hbm_bytes": rec["cost"]["hbm_bytes_per_device"],
        "coll_total": rec["collectives"]["total_bytes"],
        "coll_ar": by_type.get("all-reduce", 0.0),
        "coll_ag": by_type.get("all-gather", 0.0),
        "coll_rs": by_type.get("reduce-scatter", 0.0),
        "coll_a2a": by_type.get("all-to-all", 0.0),
        "coll_cp": by_type.get("collective-permute", 0.0),
    }


def _chunk_field(cfg, shape_name: str) -> tuple[str | None, int, int]:
    """Which inner chunk scan (if any) needs extrapolation for this cell.
    `cfg` must already carry any experiment overrides so the variation
    happens around the configured chunk size."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return None, 0, 0
    if "ssd" in cfg.pattern:
        c1 = cfg.ssm_chunk
        return "ssm_chunk", c1, min(2 * c1, shape.seq_len)
    # Attention archs: the chunked softmax scan triggers when S > chunk.
    if shape.seq_len > cfg.attn_chunk:
        c1 = cfg.attn_chunk
        return "attn_chunk", c1, min(2 * c1, shape.seq_len)
    return None, 0, 0


def analyze(arch: str, shape_name: str, multi_pod: bool = False,
            extra_overrides: dict | None = None) -> dict:
    import dataclasses
    cfg = ARCHS[arch]
    if extra_overrides:
        cfg_over = {k: v for k, v in extra_overrides.items()
                    if k != "microbatches"}
        cfg = dataclasses.replace(cfg, **cfg_over)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"status": "skipped", "reason": reason}

    plen = len(cfg.pattern)
    lead = cfg.first_dense_layers
    l1 = lead + plen
    l2 = lead + 2 * plen
    cfield, c1, c2 = _chunk_field(cfg, shape_name)
    seq = shape.seq_len

    m_l1 = _measure(arch, shape_name, multi_pod, l1, cfield, c1 or None,
                    extra_overrides)
    m_l2 = _measure(arch, shape_name, multi_pod, l2, cfield, c1 or None,
                    extra_overrides)
    per_layer = {k: (m_l2[k] - m_l1[k]) / plen for k in METRICS}
    const = {k: m_l1[k] - plen * per_layer[k] for k in METRICS}

    if cfield == "ssm_chunk" and 4 * c1 <= seq:
        # SSD's intra-chunk body has a *quadratic* chunk term (the (T,T)
        # decay-masked score matrices): body(c) = gamma*c + beta*c^2, so
        # true per-layer chunk cost = (S/c)*body(c) = gamma*S + beta*S*c.
        # Three measurements identify gamma and beta.
        m_c2 = _measure(arch, shape_name, multi_pod, l1, cfield, 2 * c1,
                        extra_overrides)
        m_c4 = _measure(arch, shape_name, multi_pod, l1, cfield, 4 * c1,
                        extra_overrides)
        for k in METRICS:
            d1 = m_c2[k] - m_l1[k]
            d2 = m_c4[k] - m_c2[k]
            beta = (d2 - 2 * d1) / (6 * plen * c1 * c1)
            gamma = d1 / (plen * c1) - 3 * beta * c1
            per_layer[k] = per_layer[k] + gamma * (seq - c1) + \
                beta * (seq * c1 - c1 * c1)
    elif cfield and c2 > c1:
        m_c2 = _measure(arch, shape_name, multi_pod, l1, cfield, c2,
                        extra_overrides)
        # Linear body (attention: the query block is fixed, the kv-chunk
        # body scales ~c): alpha per layer per unit chunk; true per-layer
        # adds alpha*(S - c1).
        alpha = {k: (m_c2[k] - m_l1[k]) / (plen * (c2 - c1))
                 for k in METRICS}
        per_layer = {k: per_layer[k] + alpha[k] * (seq - c1)
                     for k in METRICS}

    n_scan_layers = cfg.n_layers - lead
    total = {k: const[k] + n_scan_layers * per_layer[k] for k in METRICS}
    # Training remat recomputes the forward inside the backward: +1 fwd.
    remat_factor = 4.0 / 3.0 if (shape.kind == "train" and cfg.remat) else 1.0
    total_remat = {k: total[k] * (remat_factor if k == "flops" else 1.0)
                   for k in METRICS}
    return {
        "status": "ok",
        "per_layer": per_layer,
        "const": const,
        "total": total,
        "remat_flops_factor": remat_factor,
        "total_remat": total_remat,
    }


def roofline_from_analysis(analysis: dict, model_flops_per_device: float
                           ) -> dict:
    t = analysis["total_remat"]
    terms = RooflineTerms(flops=t["flops"], hbm_bytes=t["hbm_bytes"],
                          collective_bytes=t["coll_total"])
    out = terms.to_dict()
    out["useful_flops_ratio"] = (model_flops_per_device / t["flops"]
                                 if t["flops"] else 0.0)
    out["roofline_fraction"] = terms.roofline_fraction(
        model_flops_per_device)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single-pod",
                    choices=["single-pod", "multi-pod"])
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", default="")
    args = ap.parse_args()
    overrides = json.loads(args.override) if args.override else None
    res = analyze(args.arch, args.shape, args.mesh == "multi-pod",
                  overrides)
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    tag = f"__{args.tag}" if args.tag else ""
    name = f"{args.arch}__{args.shape}__{args.mesh}{tag}.analysis.json"
    (outdir / name).write_text(json.dumps(res, indent=2))
    print(json.dumps({"status": res["status"]}))


if __name__ == "__main__":
    main()
