"""Hardware cost model for the design-space search (speedup vs. cost).

The paper's Table II prices the *whole* Ara-Opt bundle: 2.64 mm2 /
141.89 mW baseline grows to 2.78 mm2 / 214.05 mW with all three
optimization classes at the strengths the paper implements.  The
design-space searcher (`repro.launch.design_search`) explores designs
that enable any subset of the M/C/O classes at *varying* strengths, so
it needs a cost surface over that widened space, not two published
points.  This module provides one, anchored to Table II:

* the baseline corner costs exactly the published baseline —
  disabled-class hardware is absent, so its knobs are free;
* the full corner at the paper's default strengths costs exactly the
  published Ara-Opt numbers;
* each enabled class contributes a fixed share of the published
  increment (`CLASS_SHARE` — operand-delivery hardware dominates: deep
  dual-source queues and forwarding muxes are SRAM+wiring, the
  decoupled memory front end is buffers+prefetcher, the issue-policy
  change is almost free control logic), scaled by how far its strength
  knobs are pushed past the paper's point (`class_strength`):
  monotone in every knob, 1.0 at the paper's defaults, softened so the
  cost of an aggressive knob grows sub-linearly near the bounds
  instead of diverging.

`SEARCH_SPACE` is the widened design space itself: the opt-side
strength knob of every mechanism, its bounds, the class whose hardware
implements it, and which direction is "stronger" (more hardware).  The
baseline-side knobs are *not* searched — they describe the workload's
host machine, not the design under evaluation — and stay pinned to the
calibrated point (`ara_calibrated.json`).

The table in docs/search.md mirrors `SEARCH_SPACE` and CI fails on
divergence (tools/check_docs.py, same contract as the SimParams knob
table) — which is why this module must stay importable with numpy as
its only third-party dependency (the docs job installs nothing else).
"""
from __future__ import annotations

import dataclasses

from repro.core.isa import OptConfig
from repro.core.paper import TABLE2
from repro.core.simulator import SimParams

__all__ = [
    "SpaceDim", "SEARCH_SPACE", "SPACE_BY_NAME", "CLASS_KNOBS",
    "CLASS_SHARE", "AREA_MM2", "POWER_MW", "aggressiveness",
    "class_strength", "design_area", "design_power", "design_cost",
]


@dataclasses.dataclass(frozen=True)
class SpaceDim:
    """One searchable strength knob of the widened design space."""
    name: str            # SimParams field
    lo: float            # lower bound (inclusive)
    hi: float            # upper bound (inclusive)
    cls: str             # opt class whose hardware implements it: M|C|O
    stronger: str        # direction of more hardware: "down" | "up"

    @property
    def default(self) -> float:
        """The paper-point strength (the SimParams field default)."""
        return float(getattr(SimParams(), self.name))

    def clip(self, value: float) -> float:
        return min(self.hi, max(self.lo, float(value)))


#: The widened design space: every opt-side strength knob, bounded.
#: ``stronger="down"`` knobs are latencies/overheads a bigger structure
#: shrinks (prefetch buffer, decoupled front end, forwarding network);
#: ``stronger="up"`` knobs are capacities a bigger structure grows
#: (operand/result queue run-ahead).  Bounds deliberately include
#: settings *weaker* than the paper's point — the searcher may trade a
#: mechanism almost away to afford strengthening another.
SEARCH_SPACE: tuple[SpaceDim, ...] = (
    # M — memory path: prefetcher + decoupled address front end.
    SpaceDim("prefetch_hit", 1.0, 16.0, "M", "down"),
    SpaceDim("tx_ovh_opt", 0.02, 1.0, "M", "down"),
    SpaceDim("idx_ovh_opt", 0.2, 4.0, "M", "down"),
    SpaceDim("rw_turnaround_opt", 0.25, 10.0, "M", "down"),
    SpaceDim("store_commit_opt", 0.0, 24.0, "M", "down"),
    # C — dependence & issue: release-aware issue policy.
    SpaceDim("issue_gap_opt", 0.5, 3.0, "C", "down"),
    # O — operand delivery: forwarding network + deep dual-source queues.
    SpaceDim("d_fwd", 0.5, 12.0, "O", "down"),
    SpaceDim("conflict_opt", 0.01, 0.14, "O", "down"),
    SpaceDim("queue_adv_opt", 24.0, 512.0, "O", "up"),
)

SPACE_BY_NAME: dict[str, SpaceDim] = {d.name: d for d in SEARCH_SPACE}

#: Opt class -> its strength knobs, in SEARCH_SPACE order.
CLASS_KNOBS: dict[str, tuple[str, ...]] = {
    cls: tuple(d.name for d in SEARCH_SPACE if d.cls == cls)
    for cls in ("M", "C", "O")
}

#: Share of the published baseline->Ara-Opt increment each class buys.
#: O dominates (deep dual-source operand/result queues are SRAM; the
#: forwarding network is lane-crossing wiring), M is buffers + a
#: prefetcher, C is control logic.  Shares sum to 1 so the full corner
#: at default strengths reproduces Table II exactly.
CLASS_SHARE: dict[str, float] = {"M": 0.35, "C": 0.15, "O": 0.50}

AREA_MM2: tuple[float, float] = TABLE2["area_mm2"]      # (base, opt)
POWER_MW: tuple[float, float] = TABLE2["power_mw"]      # (base, opt)


def aggressiveness(dim: SpaceDim, value: float) -> float:
    """How much hardware `value` implies relative to the paper's point.

    1.0 at the SimParams default, monotonically increasing toward the
    strong end of the knob's range, decreasing toward the weak end.
    Softened by a quarter-range constant so zero-valued strong settings
    (e.g. ``store_commit_opt = 0``) stay finite and the surface is
    smooth across the whole bounded range.
    """
    v = dim.clip(value)
    ref = dim.default
    s = (dim.hi - dim.lo) / 4.0
    if dim.stronger == "down":
        return (ref + s) / (v + s)
    return (v + s) / (ref + s)


def class_strength(cls: str, params: SimParams) -> float:
    """Mean aggressiveness of a class's knobs (1.0 at the paper point)."""
    knobs = CLASS_KNOBS[cls]
    return sum(aggressiveness(SPACE_BY_NAME[k], getattr(params, k))
               for k in knobs) / len(knobs)


def _cost(opt: OptConfig, params: SimParams,
          base: float, full: float) -> float:
    increment = full - base
    total = base
    for cls, enabled in (("M", opt.memory), ("C", opt.control),
                         ("O", opt.operand)):
        if enabled:
            total += (increment * CLASS_SHARE[cls]
                      * class_strength(cls, params))
    return total


def design_area(opt: OptConfig, params: SimParams) -> float:
    """Estimated area (mm2) of a design point.

    Exactly the published baseline with all classes off (regardless of
    `params` — absent hardware has no knobs), exactly the published
    Ara-Opt area for the full config at default strengths, and monotone
    in every strength knob.
    """
    return _cost(opt, params, *AREA_MM2)


def design_power(opt: OptConfig, params: SimParams) -> float:
    """Estimated power (mW) of a design point (same anchoring as area)."""
    return _cost(opt, params, *POWER_MW)


def design_cost(opt: OptConfig, params: SimParams) -> dict[str, float]:
    """The cost columns the searcher's Pareto axis reads.

    ``cost`` is the scalar the frontier minimizes — area, because Table
    II's own efficiency story is area efficiency (GFLOPS/mm2) and area
    is the axis a silicon budget actually constrains; power rides along
    for reporting.
    """
    area = design_area(opt, params)
    return {"area_mm2": area, "power_mw": design_power(opt, params),
            "cost": area}


def main() -> None:  # pragma: no cover - CLI
    import argparse
    import json
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--corners", action="store_true",
                    help="print the 8 ablation corners' costs at default "
                         "strengths")
    args = ap.parse_args()
    corners = [OptConfig.baseline(), *(
        OptConfig(m, c, o) for m in (False, True) for c in (False, True)
        for o in (False, True) if (m, c, o) != (False, False, False))]
    params = SimParams()
    rows = {opt.label: design_cost(opt, params) for opt in corners}
    print(json.dumps(rows if args.corners else
                     {"baseline": rows["base"], "full": rows["M+C+O"]},
                     indent=2))


if __name__ == "__main__":
    main()
