"""Content-addressed result cache for ablation-sweep cells.

Every benchmark (fig3-fig5, table1/table2) and the calibration loss walk
the same `(trace, OptConfig, SimParams)` cells; this cache keys each cell
on a sha256 over the *content* that determines its result — the full
instruction stream, the machine config, the opt flags, and the timing
parameters — so any consumer that asks for the same cell gets the stored
numbers back instead of re-simulating.  Keys are content hashes, not
names: regenerating a trace with different sizes (or editing the
simulator's parameters) changes the key and transparently misses.

Values hold only the scalar outputs (cycles, busy counters, roofline
accounting, and — when the producer ran with attribution — the kernel
ideal/stall decomposition plus its prologue/steady/tail phase split),
not per-instruction timings, so cells stay a few hundred bytes each.

Garbage collection: the store grows one file per distinct cell forever
unless bounded.  `prune(max_entries=N)` keeps the N most-recently-touched
cells; constructing `SweepCache(max_entries=N)` enforces that bound
automatically as `put` inserts.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
from typing import Iterable, Sequence

import numpy as np

from repro.core.isa import KernelTrace, MachineConfig, OptConfig
from repro.core.simulator import SimParams, SimResult
from repro.obs import metrics as obs_metrics

_REPO = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_ROOT = _REPO / "experiments" / "sweep_cache"

#: Bump on cache-layout changes.  Simulator *logic* is covered separately:
#: _SIM_SOURCE_DIGEST folds the timing-model source into every key, so an
#: edited model self-invalidates old cells instead of serving stale numbers.
SCHEMA_VERSION = 1


def _sim_source_digest() -> str:
    import repro.core.batch_sim as _bs
    import repro.core.simulator as _sim
    h = hashlib.sha256()
    for mod in (_sim, _bs):
        h.update(pathlib.Path(mod.__file__).read_bytes())
    return h.hexdigest()


_SIM_SOURCE_DIGEST = _sim_source_digest()


def trace_fingerprint(trace: KernelTrace) -> str:
    """Content hash of a kernel trace (instruction stream + accounting)."""
    h = hashlib.sha256()
    h.update(f"{trace.total_flops}|{trace.total_bytes}".encode())
    for ins in trace.instrs:
        h.update(
            f"{ins.name}|{ins.kind.value}|{ins.vl}|{ins.sew}|{ins.dst}|"
            f"{','.join(ins.srcs)}|{ins.stride.value}|{ins.flops}|"
            f"{ins.stream}|{ins.first_strip}".encode())
    return h.hexdigest()


def params_fingerprint(params: Sequence[SimParams]) -> str:
    """Content hash of a whole params block (an ordered sequence of
    `SimParams` variants).

    Cell keys already hash each cell's own params; this names the
    *block* — sensitivity designs use it as their identity
    (`repro.launch.sensitivity.Design.fingerprint`) so artifacts and
    logs can say "this CSV came from exactly these variants" without
    enumerating them."""
    h = hashlib.sha256()
    for p in params:
        h.update(json.dumps(dataclasses.asdict(p),
                            sort_keys=True).encode())
    return h.hexdigest()


def design_fingerprint(opt: OptConfig, params: SimParams) -> str:
    """Content hash of one *design point* (opt flags + timing params).

    The design-space searcher (`repro.launch.design_search`) keys its
    evaluated-archive on this, so a candidate proposed twice (mutation
    and crossover routinely re-derive the same point) is never
    re-simulated.  Trace-independent by construction — the same design
    scored on a different evaluation set keeps its identity."""
    payload = {"opt": [opt.memory, opt.control, opt.operand],
               "params": dataclasses.asdict(params)}
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def cell_key(trace: KernelTrace, opt: OptConfig,
             params: SimParams = SimParams(),
             mc: MachineConfig = MachineConfig(),
             trace_fp: str | None = None) -> str:
    """Content-addressed key for one `(trace, opt, params, machine)` cell.

    `trace_fp` lets callers sweeping many opts per trace hash the
    instruction stream once (`trace_fingerprint`) instead of per cell.

    Execution-planner axes (backend, method, ``bucket``, ``shard``,
    ``p_chunk``...) are deliberately NOT part of the payload: they pick
    *how* a cell is computed, never *what* it evaluates to, so a cell
    simulated bucketed fills the same entry an unbucketed rerun would
    read (tests/test_bucketing.py::test_cache_keys_ignore_plan_axes).
    """
    payload = {
        "schema": SCHEMA_VERSION,
        "sim": _SIM_SOURCE_DIGEST,
        "trace": trace_fp or trace_fingerprint(trace),
        "opt": [opt.memory, opt.control, opt.operand],
        "params": dataclasses.asdict(params),
        "mc": dataclasses.asdict(mc),
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


class SweepCache:
    """Filesystem-backed cache of sweep cells, one JSON file per key.

    `max_entries` (optional) bounds the store: once `put` pushes the cell
    count past the bound, the least-recently-touched cells are garbage-
    collected down to a 90% watermark (amortizing the GC scan while a
    sweep fills the store).  Every read bumps a cell's mtime, so hot
    cells survive eviction regardless of which instance runs the GC.

    Accounting: `hits`/`misses`/`evictions` count this instance's
    lookups and GC removals (`stats()` bundles them); the same events
    feed the process-wide `repro.obs.metrics` registry under
    ``sweep_cache.*`` so runlogs report cache behavior across instances.
    """

    def __init__(self, root: str | os.PathLike | None = None,
                 max_entries: int | None = None):
        self.root = pathlib.Path(root) if root is not None else DEFAULT_ROOT
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        if max_entries is not None and max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self._count: int | None = None     # lazily-initialized file count
        self._puts_since_sync = 0

    def _path(self, key: str) -> pathlib.Path:
        return self.root / key[:2] / f"{key}.json"

    def _entries(self) -> list[pathlib.Path]:
        if not self.root.exists():
            return []
        return list(self.root.glob("*/*.json"))

    def __len__(self) -> int:
        return len(self._entries())

    def _read(self, key: str) -> dict | None:
        """Uncounted read (callers classify hit/miss themselves)."""
        p = self._path(key)
        try:
            value = json.loads(p.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return None
        # LRU touch unconditionally: GC may run from a *different*
        # SweepCache instance (or an operator's prune call), and eviction
        # must still see read-hot cells as recently used.
        try:
            os.utime(p)
        except OSError:                    # pragma: no cover - racy unlink
            pass
        return value

    def _count_lookup(self, hit: bool) -> None:
        if hit:
            self.hits += 1
            obs_metrics.counter("sweep_cache.hits").inc()
        else:
            self.misses += 1
            obs_metrics.counter("sweep_cache.misses").inc()

    def get(self, key: str) -> dict | None:
        value = self._read(key)
        self._count_lookup(value is not None)
        return value

    def put(self, key: str, value: dict) -> None:
        p = self._path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        existed = p.exists()
        tmp = p.with_suffix(".tmp")
        blob = json.dumps(value, sort_keys=True)
        tmp.write_text(blob)
        os.replace(tmp, p)
        obs_metrics.counter("sweep_cache.put_bytes").inc(len(blob))
        if self.max_entries is not None:
            # Other instances/processes may insert into the same root, so
            # the local count is re-synced from disk periodically instead
            # of trusted forever.
            self._puts_since_sync += 1
            if self._count is None or self._puts_since_sync >= 64:
                self._count = len(self)
                self._puts_since_sync = 0
            elif not existed:
                self._count += 1
            if self._count > self.max_entries:
                # Collect down to a low watermark (90%) so a filling sweep
                # amortizes the O(entries) scan instead of re-globbing the
                # whole store on every subsequent insert.
                self.prune(max_entries=max(self.max_entries * 9 // 10, 1))

    def get_result(self, key: str, kernel: str,
                   attribution: bool = False,
                   require_phases: bool = False) -> SimResult | None:
        """Restore a cached cell.  With `attribution`, a cell stored
        without its stall decomposition counts as a miss so the caller
        re-simulates with accounting on; `require_phases` additionally
        demands the phase-split columns (grid attribution passes store
        them alongside the stall vector)."""
        v = self._read(key)
        usable = v is not None and not (
            (attribution and "stalls" not in v)
            or (require_phases and "phases" not in v))
        self._count_lookup(usable)
        if not usable:
            return None
        stalls = (np.asarray(v["stalls"], np.float64)
                  if "stalls" in v else None)
        return SimResult(kernel=kernel, cycles=v["cycles"],
                         flops=int(v["flops"]), bytes=int(v["bytes"]),
                         timings=[], busy_fpu=v["busy_fpu"],
                         busy_bus=v["busy_bus"],
                         ideal=v.get("ideal", 0.0), stalls=stalls,
                         phases=v.get("phases"))

    def put_result(self, key: str, res: SimResult) -> None:
        value = {"cycles": res.cycles, "flops": res.flops,
                 "bytes": res.bytes, "busy_fpu": res.busy_fpu,
                 "busy_bus": res.busy_bus}
        if res.stalls is not None:
            value["ideal"] = float(res.ideal)
            value["stalls"] = [float(x) for x in res.stalls]
        if res.phases is not None:
            value["phases"] = {k: float(x) for k, x in res.phases.items()}
        self.put(key, value)

    def prune(self, keep_keys: Iterable[str] | None = None,
              max_entries: int | None = None) -> int:
        """Garbage-collect cells; returns the number removed.

        With `max_entries`, keep the N most-recently-touched cells —
        `keep_keys` (if also given) are additionally protected from
        eviction.  With only `keep_keys`, drop every other cell.  With
        neither, drop everything (the full-flush legacy behavior).
        """
        entries = self._entries()
        keep = set(keep_keys or ())
        doomed: list[pathlib.Path]
        if max_entries is not None:
            entries.sort(key=_mtime_or_gone, reverse=True)
            doomed = [p for p in entries[max_entries:]
                      if p.stem not in keep]
        else:
            doomed = [p for p in entries if p.stem not in keep]
        removed = 0
        for p in doomed:
            p.unlink(missing_ok=True)
            removed += 1
        self.evictions += removed
        if removed:
            obs_metrics.counter("sweep_cache.evictions").inc(removed)
        if self._count is not None:
            self._count = max(self._count - removed, 0)
        return removed

    def stats(self) -> dict:
        """This instance's lookup/eviction accounting (cumulative)."""
        lookups = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": (self.hits / lookups) if lookups else 0.0}


def _mtime_or_gone(p: pathlib.Path) -> float:
    """Sort key robust to cells unlinked by a concurrent GC: a vanished
    entry sorts oldest, and its own unlink is already missing_ok."""
    try:
        return p.stat().st_mtime
    except OSError:
        return float("-inf")
