"""Content-addressed result cache for ablation-sweep cells.

Every benchmark (fig3-fig5, table1/table2) and the calibration loss walk
the same `(trace, OptConfig, SimParams)` cells; this cache keys each cell
on a sha256 over the *content* that determines its result — the full
instruction stream, the machine config, the opt flags, and the timing
parameters — so any consumer that asks for the same cell gets the stored
numbers back instead of re-simulating.  Keys are content hashes, not
names: regenerating a trace with different sizes (or editing the
simulator's parameters) changes the key and transparently misses.

Values hold only the scalar outputs (cycles, busy counters, roofline
accounting), not per-instruction timings, so cells stay a few hundred
bytes each.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
from typing import Iterable

from repro.core.isa import KernelTrace, MachineConfig, OptConfig
from repro.core.simulator import SimParams, SimResult

_REPO = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_ROOT = _REPO / "experiments" / "sweep_cache"

#: Bump on cache-layout changes.  Simulator *logic* is covered separately:
#: _SIM_SOURCE_DIGEST folds the timing-model source into every key, so an
#: edited model self-invalidates old cells instead of serving stale numbers.
SCHEMA_VERSION = 1


def _sim_source_digest() -> str:
    import repro.core.batch_sim as _bs
    import repro.core.simulator as _sim
    h = hashlib.sha256()
    for mod in (_sim, _bs):
        h.update(pathlib.Path(mod.__file__).read_bytes())
    return h.hexdigest()


_SIM_SOURCE_DIGEST = _sim_source_digest()


def trace_fingerprint(trace: KernelTrace) -> str:
    """Content hash of a kernel trace (instruction stream + accounting)."""
    h = hashlib.sha256()
    h.update(f"{trace.total_flops}|{trace.total_bytes}".encode())
    for ins in trace.instrs:
        h.update(
            f"{ins.name}|{ins.kind.value}|{ins.vl}|{ins.sew}|{ins.dst}|"
            f"{','.join(ins.srcs)}|{ins.stride.value}|{ins.flops}|"
            f"{ins.stream}|{ins.first_strip}".encode())
    return h.hexdigest()


def cell_key(trace: KernelTrace, opt: OptConfig,
             params: SimParams = SimParams(),
             mc: MachineConfig = MachineConfig(),
             trace_fp: str | None = None) -> str:
    """Content-addressed key for one `(trace, opt, params, machine)` cell.

    `trace_fp` lets callers sweeping many opts per trace hash the
    instruction stream once (`trace_fingerprint`) instead of per cell.
    """
    payload = {
        "schema": SCHEMA_VERSION,
        "sim": _SIM_SOURCE_DIGEST,
        "trace": trace_fp or trace_fingerprint(trace),
        "opt": [opt.memory, opt.control, opt.operand],
        "params": dataclasses.asdict(params),
        "mc": dataclasses.asdict(mc),
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


class SweepCache:
    """Filesystem-backed cache of sweep cells, one JSON file per key."""

    def __init__(self, root: str | os.PathLike | None = None):
        self.root = pathlib.Path(root) if root is not None else DEFAULT_ROOT
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> pathlib.Path:
        return self.root / key[:2] / f"{key}.json"

    def get(self, key: str) -> dict | None:
        p = self._path(key)
        try:
            value = json.loads(p.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            self.misses += 1
            return None
        self.hits += 1
        return value

    def put(self, key: str, value: dict) -> None:
        p = self._path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps(value, sort_keys=True))
        os.replace(tmp, p)

    def get_result(self, key: str, kernel: str) -> SimResult | None:
        v = self.get(key)
        if v is None:
            return None
        return SimResult(kernel=kernel, cycles=v["cycles"],
                         flops=int(v["flops"]), bytes=int(v["bytes"]),
                         timings=[], busy_fpu=v["busy_fpu"],
                         busy_bus=v["busy_bus"])

    def put_result(self, key: str, res: SimResult) -> None:
        self.put(key, {"cycles": res.cycles, "flops": res.flops,
                       "bytes": res.bytes, "busy_fpu": res.busy_fpu,
                       "busy_bus": res.busy_bus})

    def prune(self, keep_keys: Iterable[str] | None = None) -> int:
        """Drop cells not in `keep_keys` (all cells when None); returns
        the number of removed entries."""
        keep = set(keep_keys or ())
        removed = 0
        if not self.root.exists():
            return 0
        for p in self.root.glob("*/*.json"):
            if p.stem not in keep:
                p.unlink(missing_ok=True)
                removed += 1
        return removed
