"""Distribution: sharding rules, collectives, compression, context parallel."""
from repro.distributed.sharding import (ashard, named_shardings, param_specs,
                                        resolve_spec, use_mesh)

__all__ = ["ashard", "named_shardings", "param_specs", "resolve_spec",
           "use_mesh"]
