"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Mesh axes:
  "pod"   — DCN axis between pods: pure data parallelism (only gradient
            all-reduce crosses it).
  "data"  — ICI axis: batch data parallelism + FSDP/ZeRO parameter and
            optimizer-state sharding (the `d_model` dim of weights).
  "model" — ICI axis: tensor parallelism (heads / ff / vocab) and expert
            parallelism.

Models annotate activations with *logical* axis names via `ashard`; the
launcher installs a mesh + rule set with `use_mesh`.  Without an active
mesh every annotation is a no-op, so the same model code runs in unit tests
(1 device), smoke tests, and the 512-device dry-run.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical activation/param axis -> mesh axis (None = replicated).
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,             # context parallelism overrides per call site
    "seq_cp": "data",        # sequence-sharded KV cache (long-context decode)
    "act_embed": None,
    "heads": "model",
    "kv_heads": "model",     # dropped per-arch when kv_heads % model != 0
    "head_dim": None,
    "embed": "data",         # FSDP: d_model dim of weight matrices
    "ff": "model",           # tensor parallelism
    "vocab": "model",
    "expert": "model",       # expert parallelism
    "kv_lora": None,
    "conv": None,
    "state": None,
}


class _Active(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: dict[str, Any] | None = None


_ACTIVE = _Active()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict[str, Any] | None = None):
    """Install a mesh + logical rules for `ashard` / spec resolution."""
    prev = (_ACTIVE.mesh, _ACTIVE.rules)
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # Drop mesh axes the mesh doesn't actually have (single-pod mesh has no
    # "pod" axis).
    def _filter(v):
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in mesh.axis_names)
            return kept if kept else None
        return v if v in mesh.axis_names else None
    merged = {k: _filter(v) for k, v in merged.items()}
    _ACTIVE.mesh, _ACTIVE.rules = mesh, merged
    try:
        yield
    finally:
        _ACTIVE.mesh, _ACTIVE.rules = prev


def active_mesh() -> Mesh | None:
    return _ACTIVE.mesh


def resolve_spec(logical: tuple[str | None, ...]) -> P:
    rules = _ACTIVE.rules or {}
    axes = []
    used: set[str] = set()
    for name in logical:
        mesh_axis = rules.get(name) if name else None
        # A mesh axis may appear at most once in a spec.
        if isinstance(mesh_axis, tuple):
            mesh_axis = tuple(a for a in mesh_axis if a not in used) or None
            if mesh_axis:
                used.update(mesh_axis)
        elif mesh_axis is not None:
            if mesh_axis in used:
                mesh_axis = None
            else:
                used.add(mesh_axis)
        axes.append(mesh_axis)
    return P(*axes)


def ashard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain an activation's sharding by logical axis names (no-op when
    no mesh is active or under scan tracing of non-addressable shapes)."""
    mesh = _ACTIVE.mesh
    if mesh is None:
        return x
    spec = resolve_spec(tuple(logical))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding by pytree path.
# ---------------------------------------------------------------------------

# Ordered (regex, logical axes per dim, by-ndim) table.  First match wins.
# The logical tuple is right-aligned to the trailing dims of the leaf so
# stacked (scanned) params with leading layer dims work unchanged.
_PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"embed_table", ("vocab", "embed")),
    (r"lm_head", ("embed", "vocab")),
    (r"(wq_b|wq\b|q_proj)", ("embed", "heads", "head_dim")),
    (r"(wk\b|k_proj|wv\b|v_proj)", ("embed", "kv_heads", "head_dim")),
    (r"(wo\b|o_proj)", ("heads", "head_dim", "embed")),
    (r"wkv_b", ("kv_lora", "heads", "head_dim")),
    (r"(wq_a|wkv_a)", ("embed", "kv_lora")),
    (r"experts.*(w_in|w_gate)", ("expert", "embed", "ff")),
    (r"experts.*w_out", ("expert", "ff", "embed")),
    (r"(w_in|w_gate|gate_proj|up_proj)", ("embed", "ff")),
    (r"(w_out|down_proj)", ("ff", "embed")),
    (r"router", ("embed", "expert")),
    (r"(in_proj|x_proj)", ("embed", "ff")),
    (r"out_proj", ("ff", "embed")),
    (r"conv1d", (None, "ff")),
    (r"(norm|scale|bias|alpha|dt_bias|a_log)", (None,)),
]


def logical_axes_for(path: str, ndim: int) -> tuple[str | None, ...]:
    """Infer logical axes for a parameter from its tree path."""
    for pattern, logical in _PARAM_RULES:
        if re.search(pattern, path):
            if ndim >= len(logical):
                return (None,) * (ndim - len(logical)) + tuple(logical)
            return tuple(logical[-ndim:]) if ndim else ()
    return (None,) * ndim


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def axis_size(mesh: Mesh, ax) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= sizes[a]
        return n
    return sizes[ax]


def safe_spec(shape: tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop axes that don't divide their dim: explicit pjit shardings
    require divisibility (kv_heads=2 / heads=36 / vocab=49155 / experts=40
    over a 16-way axis fall back to replication; the padding-waste
    alternative is discussed in EXPERIMENTS.md §Roofline)."""
    full = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    fixed = [ax if (ax is None or (dim % axis_size(mesh, ax) == 0
                                   and dim >= axis_size(mesh, ax))) else None
             for dim, ax in zip(shape, full)]
    return P(*fixed)


def param_specs(params_shape: Any) -> Any:
    """PartitionSpec tree for a (possibly abstract) param tree, resolved
    against the active rules."""
    mesh = _ACTIVE.mesh

    def leaf_spec(path, leaf):
        if mesh is None:
            return P()
        logical = logical_axes_for(_path_str(path), len(leaf.shape))
        return safe_spec(leaf.shape, resolve_spec(logical), mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def named_shardings(tree_of_specs: Any, mesh: Mesh | None = None) -> Any:
    mesh = mesh or _ACTIVE.mesh
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
