"""Context-parallel (sequence-sharded) decode attention for long_500k.

With global_batch=1 and a 500k-token KV cache, batch parallelism is useless;
instead the KV cache is sharded along the *sequence* dimension over the
"data" axis and each chip computes a partial-softmax triple (m, l, o) over
its local KV shard.  The combine is the same tail-drain algebra as
kernels/decode_attention.combine_partials, expressed with psum — the
distributed instance of the paper's multi-lane + tail-combine decomposition.

This is explicit shard_map (not GSPMD-inferred) so the collective schedule
is exactly three small psums over (B, H)-sized tensors instead of a
sequence all-gather: collective bytes drop from O(S·H·D) to O(H·D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


def _local_partials(q, k, v, first_pos, kv_len, scale):
    """q: (B,H,D); k/v: (B,S_loc,KV,D) local shard starting at first_pos."""
    b, s_loc, kvh, d = k.shape
    h = q.shape[1]
    rep = h // kvh
    kf = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vf = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    pos = first_pos + jnp.arange(s_loc)
    valid = pos[None, None, :] < kv_len[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B, H)
    msafe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.where(valid, jnp.exp(s - msafe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)                                  # (B, H)
    o = jnp.einsum("bhs,bshd->bhd", p, vf.astype(jnp.float32))
    return m, l, o


def cp_decode_attention(q, k, v, kv_len, *, mesh: Mesh, axis: str = "data",
                        head_axis: str | None = "model",
                        scale: float | None = None) -> jax.Array:
    """Sequence-sharded decode attention.

    q: (B, H, D); k/v: (B, S, H, D) with S sharded over `axis` (context
    parallelism) and, when H divides the `head_axis` size, heads sharded
    over `head_axis` (tensor parallelism — heads are independent, so the
    partial-softmax combine still only reduces over `axis`).  kv_len: (B,).
    Returns (B, H, D) sharded like q.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    s_total = k.shape[1]
    n_shards = mesh.shape[axis]
    s_loc = s_total // n_shards
    h = q.shape[1]
    use_heads = (head_axis is not None and head_axis in mesh.axis_names
                 and h % mesh.shape[head_axis] == 0)
    haxis = head_axis if use_heads else None

    def local(q, k, v, kv_len):
        idx = jax.lax.axis_index(axis)
        first = idx * s_loc
        m, l, o = _local_partials(q, k, v, first, kv_len, scale)
        # Tail combine across sequence shards only (psum algebra ==
        # kernels.decode_attention.combine_partials).
        m_g = jax.lax.pmax(m, axis)
        w = jnp.exp(jnp.where(m <= NEG_INF / 2, NEG_INF, m) - m_g)
        w = jnp.where(m <= NEG_INF / 2, 0.0, w)
        l_g = jax.lax.psum(l * w, axis)
        o_g = jax.lax.psum(o * w[..., None], axis)
        return (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(None, haxis, None), P(None, axis, haxis, None),
                  P(None, axis, haxis, None), P()),
        out_specs=P(None, haxis, None),
        check_rep=False,
    )(q, k, v, kv_len)
