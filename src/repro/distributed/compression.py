"""Gradient compression for the cross-pod (DCN) all-reduce.

Int8 block-quantized all-reduce with error feedback: each pod reduces its
local (ICI) gradients at full precision, quantizes to int8 with per-block
fp32 scales, all-reduces the int8 payload (accumulated in int32) across the
"pod" axis, and dequantizes.  The quantization residual is carried to the
next step (error feedback), which restores O(full-precision) convergence.

DCN bandwidth is the scarcest resource at multi-pod scale — this trades a
~4x payload reduction against a bounded, feedback-corrected error, directly
shrinking the §Roofline collective term of the pod axis.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (any shape) -> (int8 blocks, fp32 scales)."""
    blocks, _ = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    out = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return out[:n].reshape(shape).astype(dtype)


def quantization_error(x: jax.Array) -> jax.Array:
    q, s = quantize(x)
    return x - dequantize(q, s, x.shape, x.dtype)


def compressed_psum(x: jax.Array, axis_name: str,
                    error: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Inside shard_map over `axis_name`: int8-payload mean-reduce with
    error feedback.  Returns (mean-reduced value, new local error).

    Implemented as all-gather of the int8 payload + per-block fp32 scales
    followed by an exact local dequant-reduce (each member's blocks are
    decoded with its own scale), so the only loss is each member's own
    quantization residual — which error feedback carries to the next step.
    Wire payload: ~1.02 bytes/element vs 4 (fp32): ~4x DCN traffic cut.
    """
    n = jax.lax.psum(1, axis_name)
    xc = x + (error if error is not None else 0.0)
    q, scale = quantize(xc)
    q_all = jax.lax.all_gather(q, axis_name)           # (n, blocks, BLOCK)
    s_all = jax.lax.all_gather(scale, axis_name)       # (n, blocks)
    recon = jnp.sum(q_all.astype(jnp.float32) * s_all[..., None], axis=0)
    numel = 1
    for s in x.shape:
        numel *= s
    out = recon.reshape(-1)[:numel].reshape(x.shape).astype(x.dtype) / n
    # Local residual (what our contribution lost): feedback for next step.
    new_error = xc - dequantize(q, scale, x.shape, x.dtype)
    return out, new_error


def tree_quantize(tree: Any) -> Any:
    return jax.tree.map(lambda x: quantize(x), tree)


def compressed_bytes(tree: Any) -> tuple[int, int]:
    """(raw fp32 bytes, compressed payload bytes) for a gradient pytree."""
    raw = comp = 0
    for leaf in jax.tree.leaves(tree):
        n = leaf.size
        raw += n * 4
        nblocks = -(-n // BLOCK)
        comp += n * 1 + nblocks * 4
    return raw, comp
