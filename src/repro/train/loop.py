"""Fault-tolerant training loop.

Production behaviors implemented (and exercised by tests/test_train_loop.py):
  * checkpoint/restart: periodic async checkpoints carry model+optimizer
    state, data-pipeline position, and RNG; `run()` auto-resumes from the
    latest checkpoint, so a crash at any step replays identically;
  * watchdog: a step exceeding `step_timeout_s` raises StepTimeout (on a
    real cluster this triggers the restart path; tests inject it);
  * straggler mitigation: per-step wall times feed an EWMA; steps slower
    than `straggler_factor` x EWMA are counted and logged — the signal a
    cluster scheduler uses to evict/replace slow hosts;
  * failure injection: `crash_at_step` simulates a hard failure for tests.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.train.step import TrainState


class StepTimeout(RuntimeError):
    pass


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    step_timeout_s: float = 600.0
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1
    crash_at_step: int | None = None        # failure injection (tests)


@dataclasses.dataclass
class LoopResult:
    state: Any
    history: list[dict]
    resumed_from: int | None
    straggler_steps: int


def run(train_step: Callable, state: TrainState, data: SyntheticLM,
        ckpt: CheckpointManager, cfg: LoopConfig,
        log_path: str | None = None, prefetch_depth: int = 2) -> LoopResult:
    """Run (or resume) training.  `train_step(state, batch) -> (state,
    metrics)` should already be jit'd with donation."""
    resumed_from = None
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state, extra = ckpt.restore(latest, state)
        data.restore(extra["data"])
        start_step = int(extra["loop_step"])
        resumed_from = latest

    source = Prefetcher(data, depth=prefetch_depth)
    history: list[dict] = []
    ewma = None
    stragglers = 0
    logf = open(log_path, "a") if log_path else None
    try:
        for step in range(start_step, cfg.total_steps):
            if cfg.crash_at_step is not None and step == cfg.crash_at_step:
                raise InjectedFailure(f"injected failure at step {step}")
            t0 = time.monotonic()
            batch = next(source)
            state, metrics = train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0

            if dt > cfg.step_timeout_s:
                raise StepTimeout(f"step {step} took {dt:.1f}s")
            if ewma is None:
                ewma = dt
            elif dt > cfg.straggler_factor * ewma:
                stragglers += 1
            ewma = (1 - cfg.ewma_alpha) * (ewma or dt) + cfg.ewma_alpha * dt

            rec = {"step": step, "time_s": dt,
                   **{k: float(np.asarray(v)) for k, v in metrics.items()}}
            history.append(rec)
            if logf and step % cfg.log_every == 0:
                logf.write(json.dumps(rec) + "\n")
                logf.flush()
            if (step + 1) % cfg.ckpt_every == 0:
                # The authoritative data position is batches *consumed*
                # (one per step) — NOT the prefetcher's read-ahead cursor,
                # which has already pulled `depth` future batches.
                ckpt.save(step + 1, state,
                          extra={"loop_step": step + 1,
                                 "data": {**data.state(),
                                          "step": step + 1}})
    finally:
        source.close()
        if logf:
            logf.close()
    ckpt.save(cfg.total_steps, state,
              extra={"loop_step": cfg.total_steps,
                     "data": {**data.state(), "step": cfg.total_steps}})
    ckpt.wait()
    return LoopResult(state=state, history=history,
                      resumed_from=resumed_from,
                      straggler_steps=stragglers)
