"""Train-step factory: microbatched gradient accumulation, remat, donation,
and the paper's C-optimization analogues at the step level.

* Microbatch accumulation is a `lax.scan` — XLA overlaps microbatch i+1's
  forward with microbatch i's gradient reduction (early dependence release
  at step granularity).
* The whole TrainState is donated: parameter buffers are released to the
  optimizer's output as soon as read (WAR release at operand-read, not
  step completion).
* Optional int8+error-feedback compression hook for the cross-pod gradient
  all-reduce (distributed/compression.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import loss_fn
from repro.train import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.AdamWState
    rng: jax.Array


def init_state(params, seed: int = 0) -> TrainState:
    return TrainState(params=params, opt=opt.init(params),
                      rng=jax.random.PRNGKey(seed))


@dataclasses.dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    schedule: Callable[[jax.Array], jax.Array] | None = None


def _split_microbatches(batch: dict, n: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg: ModelConfig, step_cfg: StepConfig):
    """Returns train_step(state, batch) -> (state, metrics).  Jit with
    donate_argnums=(0,) at the call site (launch/train.py does)."""
    sched = step_cfg.schedule or (lambda s: step_cfg.adamw.lr)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, cfg)

    def train_step(state: TrainState, batch: dict):
        n_mb = step_cfg.microbatches
        if n_mb > 1:
            mbs = _split_microbatches(batch, n_mb)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, metrics), g = grads_of(state.params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, loss_acc + loss), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, loss_sum), metrics = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, gsum)
            loss = loss_sum / n_mb
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grads_of(state.params, batch)

        lr = sched(state.opt.step)
        new_params, new_opt, opt_metrics = opt.update(
            grads, state.opt, state.params, step_cfg.adamw, lr)
        metrics = {**metrics, **opt_metrics, "loss": loss, "lr": lr}
        new_state = TrainState(params=new_params, opt=new_opt,
                               rng=jax.random.fold_in(state.rng, 0))
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch, cfg)
        return metrics
    return eval_step
