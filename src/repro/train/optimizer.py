"""AdamW with ZeRO-sharded state (no optax in-container; built in-tree).

Optimizer moments are fp32 and *inherit the parameter sharding* — with
FSDP-sharded params over "data" this is ZeRO-3: every device owns exactly
its shard of params, grads, and moments.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array               # ()
    m: Any                        # fp32 tree like params
    v: Any                        # fp32 tree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        norm


def update(grads: Any, state: AdamWState, params: Any, cfg: AdamWConfig,
           lr: jax.Array | float) -> tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), \
        {"grad_norm": gnorm}


# --- LR schedules -------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def linear_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, base_lr * (1 - 0.9 * t))
    return lr
