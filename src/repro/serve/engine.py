"""Serving engine: prefill + batched decode.

The decode loop applies the paper's C-optimization at the serving layer:
the next step's dispatch never waits on host-side postprocessing of the
previous step (async dispatch — dependences released at the earliest
semantically safe point), and the KV cache write is an in-place donated
buffer update (no write-back/reread of the cache between steps).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, logits_fn
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans
from repro.serve.cache import build_decode_cache


class Engine:
    """Single-model batched serving."""

    def __init__(self, params, cfg: ModelConfig, s_max: int = 2048,
                 cache_dtype=jnp.float32):
        self.params = params
        self.cfg = cfg
        self.s_max = s_max
        self.cache_dtype = cache_dtype
        self._decode = jax.jit(
            functools.partial(decode_step, cfg=cfg), donate_argnums=(1,))
        self._prefill = jax.jit(
            functools.partial(logits_fn, cfg=cfg, mode="prefill"))

    def prefill(self, tokens: jax.Array, extra: dict | None = None):
        """tokens: (B, S_p).  Returns (last_logits (B, V), cache, pos)."""
        with obs_spans.span("serve.prefill", batch=int(tokens.shape[0]),
                            prompt_len=int(tokens.shape[1])):
            batch = {"tokens": tokens, **(extra or {})}
            logits, prefill_caches = self._prefill(self.params, batch)
            cache = build_decode_cache(self.cfg, prefill_caches,
                                       tokens.shape[0], self.s_max,
                                       self.cache_dtype)
            pos = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
            return logits[:, -1], cache, pos

    def step(self, cache, tokens: jax.Array, pos: jax.Array):
        """One decode step for the whole batch (tokens: (B,), pos: (B,))."""
        with obs_spans.span("serve.decode_step"):
            logits, cache = self._decode(self.params, cache, tokens, pos)
        return logits, cache, pos + 1

    def generate(self, prompt: jax.Array, max_new: int = 32,
                 temperature: float = 0.0, key=None,
                 extra: dict | None = None) -> jax.Array:
        """Greedy / temperature sampling.  prompt: (B, S_p)."""
        obs_metrics.counter("serve.requests").inc()
        with obs_spans.span("serve.generate",
                            batch=int(prompt.shape[0]), max_new=max_new):
            logits, cache, pos = self.prefill(prompt, extra)
            outs = []
            tok = self._sample(logits, temperature, key, 0)
            for i in range(max_new):
                outs.append(tok)
                logits, cache, pos = self.step(cache, tok, pos)
                if key is not None:
                    key = jax.random.fold_in(key, i)
                tok = self._sample(logits, temperature, key, i + 1)
        obs_metrics.counter("serve.tokens").inc(
            int(prompt.shape[0]) * max_new)
        return jnp.stack(outs, axis=1)

    @staticmethod
    def metrics_snapshot() -> list[dict]:
        """Registry snapshot for a future HTTP metrics endpoint (ROADMAP
        item 4): the serving layer exposes this verbatim as JSON."""
        return obs_metrics.REGISTRY.snapshot()

    @staticmethod
    def _sample(logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            jax.random.fold_in(key, i), logits / temperature).astype(
            jnp.int32)
