"""Decode-cache construction: convert prefill caches into fixed-size decode
buffers (linear for global attention, ring for sliding windows, state
tensors for SSD/RG-LRU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import init_cache, stack_layout


def _place_linear(buf, seq):
    """buf: (B, S_max, ...); seq: (B, S_p, ...) -> write at [0, S_p)."""
    sp = seq.shape[1]
    return buf.at[:, :sp].set(seq.astype(buf.dtype))


def _place_ring(buf, seq, window: int):
    """Ring buffer: position p lives at slot p % window."""
    sp = seq.shape[1]
    keep = min(sp, window)
    tail = seq[:, sp - keep:]
    pos = jnp.arange(sp - keep, sp) % window
    return buf.at[:, pos].set(tail.astype(buf.dtype))


def _convert_one(kind: str, cfg: ModelConfig, prefill_cache, buf):
    if kind == "attn":
        return {"k": _place_linear(buf["k"], prefill_cache["k"]),
                "v": _place_linear(buf["v"], prefill_cache["v"])}
    if kind == "local":
        w = cfg.sliding_window
        return {"k": _place_ring(buf["k"], prefill_cache["k"], w),
                "v": _place_ring(buf["v"], prefill_cache["v"], w)}
    if kind == "mla":
        return {"ckv": _place_linear(buf["ckv"], prefill_cache["ckv"]),
                "krope": _place_linear(buf["krope"], prefill_cache["krope"])}
    if kind in ("ssd", "rglru"):
        return jax.tree.map(lambda b, p: p.astype(b.dtype), buf,
                            prefill_cache)
    raise ValueError(kind)


def build_decode_cache(cfg: ModelConfig, prefill_caches, batch: int,
                       s_max: int, dtype=jnp.bfloat16):
    """Map the stack-structured prefill caches onto zeroed decode buffers."""
    buffers = init_cache(cfg, batch, s_max, dtype)
    lead, n_rep, scan_kinds, tail = stack_layout(cfg)

    out = {"lead": {}, "scan": None, "tail": {}}
    for i, (kind, _) in enumerate(lead):
        out["lead"][str(i)] = _convert_one(
            kind, cfg, prefill_caches["lead"][str(i)],
            buffers["lead"][str(i)])
    if n_rep:
        out["scan"] = {}
        for p, (kind, _) in enumerate(scan_kinds):
            out["scan"][str(p)] = jax.vmap(
                lambda pc, b, kind=kind: _convert_one(kind, cfg, pc, b)
            )(prefill_caches["scan"][str(p)], buffers["scan"][str(p)])
    for i, (kind, _) in enumerate(tail):
        out["tail"][str(i)] = _convert_one(
            kind, cfg, prefill_caches["tail"][str(i)],
            buffers["tail"][str(i)])
    return out
