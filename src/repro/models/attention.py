"""Attention mixers: GQA/MQA (global + sliding-window) and DeepSeek MLA,
with prefill/decode KV-cache paths.

Two core implementations, selected by ``cfg.attn_impl``:

* ``naive``   — materializes the score matrix (the baseline operand path:
  S round-trips HBM, like the paper's VRF write-back/reread).
* ``chunked`` — online-softmax over KV chunks via ``lax.scan`` (flash-style
  chaining; XLA keeps running (m, l, acc) statistics live, bounding memory).
  This is the jnp twin of kernels/flash_attention.py and is shardable under
  GSPMD, which the Pallas kernel (TPU runtime only) is not on this host.

``cfg.use_pallas=True`` routes to the Pallas kernels on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ashard
from repro.kernels import ops as kops
from repro.models.layers import (apply_norm, apply_rope, cdtype, init_norm,
                                 pdtype, _normal)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    dt = pdtype(cfg)
    p = {
        "wq": _normal(ks[0], (d, h, hd), dt),
        "wk": _normal(ks[1], (d, kv, hd), dt),
        "wv": _normal(ks[2], (d, kv, hd), dt),
        "wo": _normal(ks[3], (h, hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dt)
        p["bk"] = jnp.zeros((kv, hd), dt)
        p["bv"] = jnp.zeros((kv, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_norm(ks[4], cfg, hd)
        p["k_norm"] = init_norm(ks[5], cfg, hd)
    return p


def init_mla(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    dt = pdtype(cfg)
    return {
        "wq_a": _normal(ks[0], (d, cfg.q_lora_rank), dt),
        "q_norm": init_norm(ks[1], cfg, cfg.q_lora_rank),
        "wq_b": _normal(ks[2], (cfg.q_lora_rank, h, qk), dt),
        "wkv_a": _normal(ks[3], (d, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
                         dt),
        "kv_norm": init_norm(ks[4], cfg, cfg.kv_lora_rank),
        "wkv_b": _normal(ks[5], (cfg.kv_lora_rank, h,
                                 cfg.qk_nope_head_dim + cfg.v_head_dim), dt),
        "wo": _normal(ks[6], (h, cfg.v_head_dim, d), dt),
    }


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

def _gqa_scores_mask(sq, skv, offset, causal, window):
    """(sq, skv) additive mask: causal and/or sliding window."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= qpos >= kpos
    if window is not None and window > 0:
        ok &= qpos - kpos < window
    return jnp.where(ok, 0.0, NEG_INF)


def attend_naive(q, k, v, *, causal, window, scale, softcap, offset=0):
    """q: (B, Sq, H, Dk); k: (B, Skv, KV, Dk); v: (B, Skv, KV, Dv)."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    dv = v.shape[3]
    rep = h // kvh
    qg = q.reshape(b, sq, kvh, rep, dh)
    s = jnp.einsum("bqkrd,bskd->bkrqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = s + _gqa_scores_mask(sq, k.shape[1], offset, causal, window)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrqs,bskd->bqkrd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dv).astype(q.dtype)


def attend_chunked(q, k, v, *, causal, window, scale, softcap, offset=0,
                   chunk=1024):
    """Online-softmax over KV chunks (flash-style chaining in jnp)."""
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    dv = v.shape[3]
    rep = h // kvh
    nchunk = -(-skv // chunk)
    pad = nchunk * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunk, chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunk, chunk, kvh, dv).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(b, sq, kvh, rep, dh).astype(jnp.float32)
    qpos = jnp.arange(sq) + offset

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, idx = inp                            # (B, C, KV, D), idx
        s = jnp.einsum("bqkrd,bskd->bkrqs", qg, kb.astype(jnp.float32))
        s = s * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = idx * chunk + jnp.arange(chunk)
        ok = kpos[None, :] < skv
        if causal:
            ok &= qpos[:, None] >= kpos[None, :]
        if window is not None and window > 0:
            ok &= (qpos[:, None] - kpos[None, :]) < window
        s = s + jnp.where(ok, 0.0, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        msafe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - msafe[..., None])
        alpha = jnp.exp(jnp.where(m <= NEG_INF / 2, NEG_INF, m) - msafe)
        alpha = jnp.where(m <= NEG_INF / 2, 0.0, alpha)
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = alpha[..., None] * acc + jnp.einsum(
            "bkrqs,bskd->bkrqd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, rep, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nchunk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def attend(q, k, v, cfg: ModelConfig, *, causal, window, scale,
           softcap=0.0, offset=0):
    if cfg.use_pallas and window is None and offset in (0, k.shape[1] - q.shape[1]):
        return kops.flash_attention(q, k, v, causal=causal, scale=scale,
                                    logit_softcap=softcap)
    if cfg.attn_impl == "naive" or k.shape[1] <= cfg.attn_chunk:
        return attend_naive(q, k, v, causal=causal, window=window,
                            scale=scale, softcap=softcap, offset=offset)
    return attend_chunked(q, k, v, causal=causal, window=window, scale=scale,
                          softcap=softcap, offset=offset, chunk=cfg.attn_chunk)


# ---------------------------------------------------------------------------
# GQA mixer (global or sliding-window)
# ---------------------------------------------------------------------------

def _project_qkv(p, x, cfg: ModelConfig, positions, theta):
    dt = cdtype(cfg)
    q = jnp.einsum("...d,dhk->...hk", x, p["wq"].astype(dt))
    k = jnp.einsum("...d,dhk->...hk", x, p["wk"].astype(dt))
    v = jnp.einsum("...d,dhk->...hk", x, p["wv"].astype(dt))
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), \
            v + p["bv"].astype(dt)
    if "q_norm" in p:
        q = apply_norm(p["q_norm"], q, cfg)
        k = apply_norm(p["k_norm"], k, cfg)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def gqa_forward(p, x, cfg: ModelConfig, *, window: int | None,
                theta: float, positions=None):
    """Full-sequence forward (training / prefill).  x: (B, S, d)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions, theta)
    q = ashard(q, "batch", "seq", "heads", None)
    k = ashard(k, "batch", "seq", "kv_heads", None)
    v = ashard(v, "batch", "seq", "kv_heads", None)
    scale = cfg.head_dim ** -0.5
    o = attend(q, k, v, cfg, causal=cfg.causal, window=window, scale=scale,
               softcap=cfg.logit_softcap)
    o = ashard(o, "batch", "seq", "heads", None)
    out = jnp.einsum("...hk,hkd->...d", o, p["wo"].astype(cdtype(cfg)))
    return out, (k, v)


def gqa_decode(p, x, cache, cfg: ModelConfig, *, window: int | None,
               theta: float, pos):
    """Single-token decode.  x: (B, 1, d); cache: dict(k, v) ring or linear
    buffers (B, S_max, KV, D); pos: (B,) current write position."""
    b = x.shape[0]
    positions = pos[:, None]
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, theta)
    k_cache, v_cache = cache["k"], cache["v"]
    s_max = k_cache.shape[1]
    if window is not None and window > 0 and s_max == window:
        slot = (pos % window)[:, None]                 # ring buffer
    else:
        slot = pos[:, None]
    bidx = jnp.arange(b)[:, None]
    k_cache = k_cache.at[bidx, slot].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, slot].set(v_new.astype(v_cache.dtype))

    scale = cfg.head_dim ** -0.5
    kv_len = jnp.minimum(pos + 1, s_max)
    # Ring buffers hold the most recent `window` positions — every live slot
    # is attendable, so validity masking by kv_len suffices.
    q1 = q[:, 0]                                       # (B, H, D)
    if cfg.use_cp_decode and window is None:
        # Context-parallel decode: KV stays sequence-sharded; three small
        # psums replace GSPMD's full-cache all-gather (§Perf hillclimb).
        from repro.distributed.context_parallel import cp_decode_attention
        from repro.distributed.sharding import active_mesh
        mesh = active_mesh()
        if mesh is not None and "data" in mesh.axis_names:
            rep = cfg.n_rep
            kf = jnp.repeat(k_cache, rep, axis=2) if rep > 1 else k_cache
            vf = jnp.repeat(v_cache, rep, axis=2) if rep > 1 else v_cache
            o = cp_decode_attention(q1, kf, vf, kv_len, mesh=mesh,
                                    axis="data", scale=scale)
            o = o.astype(cdtype(cfg))[:, None]
            out = jnp.einsum("...hk,hkd->...d", o,
                             p["wo"].astype(cdtype(cfg)))
            return out, {"k": k_cache, "v": v_cache}
    rep = cfg.n_rep
    kf = jnp.repeat(k_cache, rep, axis=2) if rep > 1 else k_cache
    vf = jnp.repeat(v_cache, rep, axis=2) if rep > 1 else v_cache
    logits = jnp.einsum("bhd,bshd->bhs", q1.astype(jnp.float32),
                        kf.astype(jnp.float32)) * scale
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    valid = jnp.arange(s_max)[None, None, :] < kv_len[:, None, None]
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", probs, vf.astype(jnp.float32))
    o = o.astype(cdtype(cfg))[:, None]                 # (B, 1, H, D)
    out = jnp.einsum("...hk,hkd->...d", o, p["wo"].astype(cdtype(cfg)))
    return out, {"k": k_cache, "v": v_cache}


def init_gqa_cache(cfg: ModelConfig, batch: int, s_max: int,
                   window: int | None, dtype=jnp.bfloat16):
    s = min(s_max, window) if (window and window > 0) else s_max
    shape = (batch, s, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# MLA mixer (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_forward(p, x, cfg: ModelConfig, positions=None):
    """Training/prefill MLA.  Returns (out, latent_cache)."""
    b, s, _ = x.shape
    dt = cdtype(cfg)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    nope, rope_d, vd = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                        cfg.v_head_dim)

    cq = apply_norm(p["q_norm"], jnp.einsum(
        "...d,dr->...r", x, p["wq_a"].astype(dt)), cfg)
    q = jnp.einsum("...r,rhk->...hk", cq, p["wq_b"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("...d,dr->...r", x, p["wkv_a"].astype(dt))
    c_kv, k_rope = kv_a[..., :cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    c_kv = apply_norm(p["kv_norm"], c_kv, cfg)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)

    kv = jnp.einsum("...r,rhk->...hk", c_kv, p["wkv_b"].astype(dt))
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:-1], rope_d))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (nope + rope_d) ** -0.5
    o = attend(q_full, k, v, cfg, causal=cfg.causal, window=None,
               scale=scale, softcap=0.0)
    out = jnp.einsum("...hk,hkd->...d", o, p["wo"].astype(dt))
    return out, (c_kv, k_rope[..., 0, :])


def mla_decode(p, x, cache, cfg: ModelConfig, *, pos):
    """Absorbed-projection MLA decode: attention runs in the latent space so
    the cache stays (S, kv_lora + rope) — the paper-style small 'operand
    queue' (no per-step K/V reconstruction).  x: (B, 1, d)."""
    b = x.shape[0]
    dt = cdtype(cfg)
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    positions = pos[:, None]

    cq = apply_norm(p["q_norm"], jnp.einsum(
        "...d,dr->...r", x, p["wq_a"].astype(dt)), cfg)
    q = jnp.einsum("...r,rhk->...hk", cq, p["wq_b"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)[:, 0]   # (B,H,r)

    kv_a = jnp.einsum("...d,dr->...r", x, p["wkv_a"].astype(dt))
    c_new, kr_new = kv_a[..., :cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    c_new = apply_norm(p["kv_norm"], c_new, cfg)
    kr_new = apply_rope(kr_new[..., None, :], positions, cfg.rope_theta)

    ckv, krope = cache["ckv"], cache["krope"]
    s_max = ckv.shape[1]
    bidx = jnp.arange(b)[:, None]
    slot = pos[:, None]
    ckv = ckv.at[bidx, slot].set(c_new.astype(ckv.dtype))
    krope = krope.at[bidx, slot].set(kr_new[:, :, 0].astype(krope.dtype))

    # Absorb W_kv_b into the query / output sides.
    wkv_b = p["wkv_b"].astype(dt)                      # (r, H, nope+v)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)[:, 0]   # (B, H, r)

    s_nope = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                        ckv.astype(jnp.float32))
    s_rope = jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                        krope.astype(jnp.float32))
    scale = (nope + rope_d) ** -0.5
    logits = (s_nope + s_rope) * scale
    kv_len = jnp.minimum(pos + 1, s_max)
    valid = jnp.arange(s_max)[None, None, :] < kv_len[:, None, None]
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", probs, ckv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhk->bhk", o_lat.astype(dt), w_uv)     # (B, H, v)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(dt))[:, None]
    return out, {"ckv": ckv, "krope": krope}


def init_mla_cache(cfg: ModelConfig, batch: int, s_max: int,
                   dtype=jnp.bfloat16):
    return {"ckv": jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, s_max, cfg.qk_rope_head_dim), dtype)}
