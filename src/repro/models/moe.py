"""Mixture-of-Experts FFN: top-k router + capacity-based sorted dispatch.

Expert-parallel design: expert weight tensors carry a leading `expert`
logical axis (sharded over the mesh "model" axis).  Dispatch gathers each
expert's tokens into an (E, C, d) buffer — the all-to-all this induces under
GSPMD is the EP collective accounted in §Roofline.

The dispatch is the gather/scatter analogue of the paper's descriptor-driven
memory front end: expert assignments are "address descriptors", and sorting
tokens by expert converts scattered access into the streaming pattern the
hardware (MXU batched GEMM) wants.

Token overflow beyond capacity C = ceil(T*k/E * capacity_factor) is dropped
(GShard-style), with the router's combine weights renormalized over
surviving assignments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ashard
from repro.models.layers import _normal, activation, cdtype, pdtype


def init_moe(key, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    dt = pdtype(cfg)
    p = {
        "router": _normal(ks[0], (d, e), jnp.float32),
        "experts": {
            "w_gate": _normal(ks[1], (e, d, f), dt),
            "w_in": _normal(ks[2], (e, d, f), dt),
            "w_out": _normal(ks[3], (e, f, d), dt),
        },
    }
    if cfg.n_shared_experts:
        from repro.models.layers import init_ffn
        p["shared"] = init_ffn(ks[4], cfg,
                               d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.moe_top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)          # round up to 8 for TPU tiling


def apply_moe(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d).  Differentiable sorted-capacity dispatch."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    cap = _capacity(t, cfg)
    dt = cdtype(cfg)
    xf = x.reshape(t, d)

    # --- routing ---------------------------------------------------------
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- sorted dispatch ---------------------------------------------------
    flat_expert = expert_idx.reshape(-1)                # (T*k,)
    order = jnp.argsort(flat_expert)                    # stable
    sorted_expert = flat_expert[order]
    # Position of each assignment within its expert's group.
    ones = jnp.ones_like(sorted_expert)
    pos_in_expert = jnp.cumsum(ones) - 1
    seg_start = jnp.searchsorted(sorted_expert, jnp.arange(e))
    pos_in_expert = pos_in_expert - seg_start[sorted_expert]
    keep = pos_in_expert < cap
    slot = sorted_expert * cap + pos_in_expert          # (T*k,) in [0, E*C)
    slot = jnp.where(keep, slot, e * cap)               # overflow -> dropped

    token_of = order // k                               # source token index
    # Scatter token vectors into the (E*C + 1, d) dispatch buffer.
    buf = jnp.zeros((e * cap + 1, d), dt)
    buf = buf.at[slot].set(xf[token_of].astype(dt), mode="drop")
    xe = buf[: e * cap].reshape(e, cap, d)
    xe = ashard(xe, "expert", None, None)

    # --- expert computation (batched over the expert axis) ----------------
    act = activation(cfg.act)
    we = p["experts"]
    g = act(jnp.einsum("ecd,edf->ecf", xe, we["w_gate"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", xe, we["w_in"].astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", g * u, we["w_out"].astype(dt))
    ye = ashard(ye, "expert", None, None)

    # --- combine -----------------------------------------------------------
    yflat = ye.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], yflat[jnp.clip(slot, 0, e * cap - 1)],
                         0.0)                            # (T*k, d)
    weights = gate.reshape(-1)[order] * keep             # dropped -> 0
    out = jnp.zeros((t, d), dt).at[token_of].add(
        gathered * weights[:, None].astype(dt))
    out = out.reshape(b, s, d)

    if "shared" in p:
        from repro.models.layers import apply_ffn
        out = out + apply_ffn(p["shared"], x, cfg)
    return out


def router_stats(p, x, cfg: ModelConfig) -> dict:
    """Load-balance diagnostics (tests + serving metrics)."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    counts = jnp.zeros((cfg.n_experts,), jnp.int32).at[idx.reshape(-1)].add(1)
    return {"expert_counts": counts,
            "max_prob": probs.max(),
            "entropy": -(probs * jnp.log(probs + 1e-9)).sum(-1).mean()}
