"""Composable transformer stack: pattern-cycled blocks, scan-over-layers.

Layer patterns (cfg.pattern) are cycled across the depth — e.g. gemma3's
("local",)*5 + ("attn",) 5:1 pattern, griffin's ("rglru", "rglru", "local")
1:2, deepseek's all-("mla",).  The stack scans over *pattern repetitions*
(each scan step applies one full pattern) so the compiled HLO contains each
distinct block body exactly once — essential for 40-62 layer models at
512-device SPMD compile time.

Leading layers that differ (deepseek-v2's first dense-FFN layer) are
unrolled before the scan; remainder layers (depth % pattern length) are
unrolled after it.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ashard
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_ffn, apply_norm, cdtype,
                                 embed_tokens, init_embedding, init_ffn,
                                 init_lm_head, init_norm, lm_logits)


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def _theta_for(cfg: ModelConfig, kind: str) -> float:
    if kind == "attn" and cfg.rope_theta_global is not None:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _window_for(cfg: ModelConfig, kind: str) -> int | None:
    return cfg.sliding_window if kind == "local" else None


def init_block(key, cfg: ModelConfig, kind: str, ffn_kind: str):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": init_norm(ks[0], cfg)}
    if kind in ("attn", "local"):
        p["mixer"] = attn.init_attention(ks[1], cfg)
    elif kind == "mla":
        p["mixer"] = attn.init_mla(ks[1], cfg)
    elif kind == "ssd":
        p["mixer"] = ssm_mod.init_ssd_block(ks[1], cfg)
    elif kind == "rglru":
        p["mixer"] = rglru_mod.init_rglru_block(ks[1], cfg)
    else:
        raise ValueError(kind)
    if ffn_kind != "none":
        p["norm2"] = init_norm(ks[2], cfg)
        if ffn_kind == "moe":
            p["ffn"] = moe_mod.init_moe(ks[3], cfg)
        else:
            p["ffn"] = init_ffn(ks[3], cfg)
    return p


def apply_block(p, x, cfg: ModelConfig, kind: str, ffn_kind: str, *,
                mode: str = "train", cache=None, pos=None, positions=None):
    """Returns (x, new_cache).  mode: train | prefill | decode."""
    h = apply_norm(p["norm1"], x, cfg)
    new_cache = None
    if kind in ("attn", "local"):
        window = _window_for(cfg, kind)
        theta = _theta_for(cfg, kind)
        if mode == "decode":
            out, new_cache = attn.gqa_decode(p["mixer"], h, cache, cfg,
                                             window=window, theta=theta,
                                             pos=pos)
        else:
            out, kv = attn.gqa_forward(p["mixer"], h, cfg, window=window,
                                       theta=theta, positions=positions)
            new_cache = {"k": kv[0], "v": kv[1]} if mode == "prefill" else None
    elif kind == "mla":
        if mode == "decode":
            out, new_cache = attn.mla_decode(p["mixer"], h, cache, cfg,
                                             pos=pos)
        else:
            out, lat = attn.mla_forward(p["mixer"], h, cfg,
                                        positions=positions)
            new_cache = ({"ckv": lat[0], "krope": lat[1]}
                         if mode == "prefill" else None)
    elif kind == "ssd":
        if mode == "decode":
            out, new_cache = ssm_mod.ssd_decode(p["mixer"], h, cache, cfg)
        else:
            out, c = ssm_mod.ssd_forward(p["mixer"], h, cfg)
            new_cache = c if mode == "prefill" else None
    elif kind == "rglru":
        if mode == "decode":
            out, new_cache = rglru_mod.rglru_decode(p["mixer"], h, cache, cfg)
        else:
            out, c = rglru_mod.rglru_forward(p["mixer"], h, cfg)
            new_cache = c if mode == "prefill" else None
    else:
        raise ValueError(kind)
    x = x + out
    if "ffn" in p:
        h = apply_norm(p["norm2"], x, cfg)
        if ffn_kind == "moe":
            x = x + moe_mod.apply_moe(p["ffn"], h, cfg)
        else:
            x = x + apply_ffn(p["ffn"], h, cfg)
    return ashard(x, "batch", "seq", "act_embed"), new_cache


# ---------------------------------------------------------------------------
# Stack layout
# ---------------------------------------------------------------------------

def stack_layout(cfg: ModelConfig):
    """(lead kinds, scan repetitions, tail kinds)."""
    plen = len(cfg.pattern)
    lead = [(cfg.mixer_at(i), cfg.ffn_at(i))
            for i in range(cfg.first_dense_layers)]
    rest = cfg.n_layers - len(lead)
    n_rep = rest // plen if cfg.scan_layers else 0
    tail_start = len(lead) + n_rep * plen
    tail = [(cfg.mixer_at(i), cfg.ffn_at(i))
            for i in range(tail_start, cfg.n_layers)]
    scan_kinds = [(cfg.mixer_at(len(lead) + j), cfg.ffn_at(len(lead) + j))
                  for j in range(plen)] if n_rep else []
    return lead, n_rep, scan_kinds, tail


def init_model(key, cfg: ModelConfig):
    lead, n_rep, scan_kinds, tail = stack_layout(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    if cfg.modality != "audio":
        params["embedding"] = init_embedding(keys[0], cfg)
    else:
        params["embedding"] = init_embedding(keys[0], cfg)  # output units
    params["lead"] = {
        str(i): init_block(jax.random.fold_in(keys[1], i), cfg, k, f)
        for i, (k, f) in enumerate(lead)}
    if n_rep:
        def init_rep(k):
            sub = jax.random.split(k, len(scan_kinds))
            return {str(pos): init_block(sub[pos], cfg, kind, f)
                    for pos, (kind, f) in enumerate(scan_kinds)}
        params["scan"] = jax.vmap(init_rep)(jax.random.split(keys[2], n_rep))
    params["tail"] = {
        str(i): init_block(jax.random.fold_in(keys[3], i), cfg, k, f)
        for i, (k, f) in enumerate(tail)}
    params["final_norm"] = init_norm(keys[4], cfg)
    params["head"] = init_lm_head(keys[5], cfg)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg: ModelConfig):
    if cfg.modality == "audio":
        x = batch["frames"].astype(cdtype(cfg))
    else:
        x = embed_tokens(params["embedding"], batch["tokens"], cfg)
        if cfg.modality == "vlm" and "img_embeds" in batch:
            n_img = batch["img_embeds"].shape[1]
            img = batch["img_embeds"].astype(x.dtype)
            x = jnp.concatenate([img, x[:, n_img:]], axis=1)
    return ashard(x, "batch", "seq", "act_embed")


def forward(params, batch, cfg: ModelConfig, *, mode: str = "train"):
    """Returns (hidden, caches) — caches is None unless mode == 'prefill'."""
    x = _embed_inputs(params, batch, cfg)
    lead, n_rep, scan_kinds, tail = stack_layout(cfg)
    collect = mode == "prefill"
    caches: dict[str, Any] = {"lead": {}, "scan": None, "tail": {}}

    for i, (kind, f) in enumerate(lead):
        x, c = apply_block(params["lead"][str(i)], x, cfg, kind, f, mode=mode)
        if collect:
            caches["lead"][str(i)] = c

    if n_rep:
        def body(carry, rep_params):
            h = carry
            cs = {}
            for pos, (kind, f) in enumerate(scan_kinds):
                h, c = apply_block(rep_params[str(pos)], h, cfg, kind, f,
                                   mode=mode)
                cs[str(pos)] = c
            return h, (cs if collect else 0)

        if cfg.remat and mode == "train":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, scan_caches = jax.lax.scan(body, x, params["scan"])
        if collect:
            caches["scan"] = scan_caches

    for i, (kind, f) in enumerate(tail):
        x, c = apply_block(params["tail"][str(i)], x, cfg, kind, f, mode=mode)
        if collect:
            caches["tail"][str(i)] = c

    x = apply_norm(params["final_norm"], x, cfg)
    return x, (caches if collect else None)


def logits_fn(params, batch, cfg: ModelConfig, *, mode: str = "train"):
    hidden, caches = forward(params, batch, cfg, mode=mode)
    return lm_logits(params, hidden, cfg), caches


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One decode step.  tokens: (B,) int32; pos: (B,) positions.
    Returns (logits (B, V), new_cache)."""
    batch = {"tokens": tokens[:, None]}
    x = embed_tokens(params["embedding"], batch["tokens"], cfg)
    x = ashard(x, "batch", None, "act_embed")
    lead, n_rep, scan_kinds, tail = stack_layout(cfg)
    new_cache: dict[str, Any] = {"lead": {}, "scan": None, "tail": {}}

    for i, (kind, f) in enumerate(lead):
        x, c = apply_block(params["lead"][str(i)], x, cfg, kind, f,
                           mode="decode", cache=cache["lead"][str(i)],
                           pos=pos)
        new_cache["lead"][str(i)] = c

    if n_rep:
        def body(carry, inp):
            h = carry
            rep_params, rep_cache = inp
            cs = {}
            for p_, (kind, f) in enumerate(scan_kinds):
                h, c = apply_block(rep_params[str(p_)], h, cfg, kind, f,
                                   mode="decode", cache=rep_cache[str(p_)],
                                   pos=pos)
                cs[str(p_)] = c
            return h, cs

        x, scan_caches = jax.lax.scan(body, x, (params["scan"],
                                                cache["scan"]))
        new_cache["scan"] = scan_caches

    for i, (kind, f) in enumerate(tail):
        x, c = apply_block(params["tail"][str(i)], x, cfg, kind, f,
                           mode="decode", cache=cache["tail"][str(i)],
                           pos=pos)
        new_cache["tail"][str(i)] = c

    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params, x, cfg)[:, 0]
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16):
    """Zeroed decode caches matching the stack layout."""
    lead, n_rep, scan_kinds, tail = stack_layout(cfg)

    def one(kind):
        if kind in ("attn",):
            return attn.init_gqa_cache(cfg, batch, s_max, None, dtype)
        if kind == "local":
            return attn.init_gqa_cache(cfg, batch, s_max,
                                       cfg.sliding_window, dtype)
        if kind == "mla":
            return attn.init_mla_cache(cfg, batch, s_max, dtype)
        if kind == "ssd":
            return ssm_mod.init_ssd_cache(cfg, batch, dtype)
        if kind == "rglru":
            return rglru_mod.init_rglru_cache(cfg, batch, dtype)
        raise ValueError(kind)

    cache: dict[str, Any] = {
        "lead": {str(i): one(k) for i, (k, _) in enumerate(lead)},
        "scan": None,
        "tail": {str(i): one(k) for i, (k, _) in enumerate(tail)},
    }
    if n_rep:
        def stack(c):
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_rep, *a.shape)).copy(), c)
        cache["scan"] = {str(p): stack(one(k))
                         for p, (k, _) in enumerate(scan_kinds)}
    return cache
