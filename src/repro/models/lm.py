"""LM losses and the train-step forward."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import logits_fn


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None,
                  z_loss: float = 1e-4) -> tuple[jax.Array, dict]:
    """Token-level CE in fp32 with optional z-loss regularizer."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss > 0:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(logits, -1) == targets) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}


def loss_fn(params, batch, cfg: ModelConfig):
    """batch: tokens/frames (+ img_embeds), targets, optional mask."""
    logits, _ = logits_fn(params, batch, cfg, mode="train")
    mask = batch.get("mask")
    if cfg.modality == "vlm" and mask is None:
        # No loss on the image prefix.
        b, s = batch["targets"].shape
        mask = (jnp.arange(s)[None, :] >= cfg.n_img_tokens).astype(
            jnp.float32) * jnp.ones((b, 1), jnp.float32)
    loss, metrics = cross_entropy(logits, batch["targets"], mask)
    return loss, metrics
