"""Shared layers: norms, RoPE, MLPs, embeddings (pure-pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ashard
from repro.kernels import ops as kops


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _normal(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# --- norms -------------------------------------------------------------------

def init_norm(key, cfg: ModelConfig, dim: int | None = None):
    dim = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((dim,), jnp.float32),
                "bias": jnp.zeros((dim,), jnp.float32)}
    return {"scale": jnp.zeros((dim,), jnp.float32)}      # (gemma)rmsnorm


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        norm = xf * jax.lax.rsqrt(var + eps)
        out = norm * (1.0 + p["scale"])    # zero-init scale == weight 1
    return out.astype(x.dtype)


# --- rotary ------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., H, D) with matching positions (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    angles = jnp.expand_dims(angles, axis=-2)          # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- activations -------------------------------------------------------------

def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


# --- MLP / GLU ---------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, d_ff: int | None = None):
    d, dff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = pdtype(cfg)
    if cfg.ffn == "mlp":                                 # plain 2-matrix MLP
        p = {"w_in": _normal(ks[0], (d, dff), dt),
             "w_out": _normal(ks[1], (dff, d), dt)}
        if cfg.mlp_bias:
            p["b_in"] = jnp.zeros((dff,), dt)
            p["b_out"] = jnp.zeros((d,), dt)
        return p
    return {"w_gate": _normal(ks[0], (d, dff), dt),
            "w_in": _normal(ks[1], (d, dff), dt),
            "w_out": _normal(ks[2], (dff, d), dt)}


def apply_ffn(p, x, cfg: ModelConfig):
    act = activation(cfg.act)
    dt = cdtype(cfg)
    x = x.astype(dt)
    if "w_gate" in p:                                   # GLU
        gate = act(jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dt)))
        up = jnp.einsum("...d,df->...f", x, p["w_in"].astype(dt))
        h = ashard(gate * up, "batch", "seq", "ff")
        return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(dt))
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(dt))
    if "b_in" in p:
        h = h + p["b_in"].astype(dt)
    h = ashard(act(h), "batch", "seq", "ff")
    out = jnp.einsum("...f,fd->...d", h, p["w_out"].astype(dt))
    if "b_out" in p:
        out = out + p["b_out"].astype(dt)
    return out


# --- embeddings --------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    return {"embed_table": _normal(key, (cfg.vocab_size, cfg.d_model),
                                   pdtype(cfg), scale=0.02)}


def embed_tokens(p, tokens, cfg: ModelConfig):
    x = jnp.take(p["embed_table"].astype(cdtype(cfg)), tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdtype(cfg))
    return x


def init_lm_head(key, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    return {"lm_head": _normal(key, (cfg.d_model, cfg.vocab_size),
                               pdtype(cfg))}


def lm_logits(params, x, cfg: ModelConfig):
    """Final projection in fp32 (CE numerics)."""
    xf = x.astype(jnp.float32)
    if cfg.tie_embeddings:
        w = params["embedding"]["embed_table"].astype(jnp.float32).T
    else:
        w = params["head"]["lm_head"].astype(jnp.float32)
    logits = jnp.einsum("...d,dv->...v", xf, w)
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return ashard(logits, "batch", "seq", "vocab")
