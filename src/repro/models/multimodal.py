"""Modality frontend stubs (per the brief: [audio]/[vlm] entries specify the
transformer BACKBONE only; the frontend supplies precomputed embeddings).

These produce the *input batches* — deterministic synthetic frame/patch
embeddings shaped exactly as the real frontends (HuBERT conv stem / CLIP
vision tower) would emit — so input_specs() and the data pipeline share one
source of truth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def audio_frames(key, batch: int, seq: int, cfg: ModelConfig):
    """Stub for the HuBERT 7-layer conv feature encoder output:
    (B, T_frames, d_model) frame embeddings at 50 Hz."""
    return 0.02 * jax.random.normal(key, (batch, seq, cfg.d_model),
                                    jnp.float32)


def vision_patches(key, batch: int, cfg: ModelConfig):
    """Stub for the CLIP-ViT patch tower output projected to d_model:
    (B, n_img_tokens, d_model)."""
    return 0.02 * jax.random.normal(key, (batch, cfg.n_img_tokens,
                                          cfg.d_model), jnp.float32)


def make_batch(key, cfg: ModelConfig, batch: int, seq: int) -> dict:
    """A full synthetic input batch for any modality."""
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
    targets = jnp.roll(tokens, -1, axis=1)
    out = {"targets": targets}
    if cfg.modality == "audio":
        out["frames"] = audio_frames(ks[1], batch, seq, cfg)
    else:
        out["tokens"] = tokens
        if cfg.modality == "vlm":
            out["img_embeds"] = vision_patches(ks[2], batch, cfg)
    return out
