"""Griffin / RecurrentGemma RG-LRU recurrent mixer.

The RG-LRU linear recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t *
x_t) is chaining in its purest form: each element group's state is the
chained operand of the next.  Training uses an associative scan (parallel
prologue/steady/tail — log-depth fill, then one group per step); decode
carries the (B, W) state, a cache smaller than any KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, cdtype, pdtype

_C = 8.0          # temperature on the recurrence gate (Griffin)
_MAX_A = -8.0     # a_param init so a ~ sigmoid in a stable range


def init_rglru_block(key, cfg: ModelConfig):
    d, w = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 6)
    dt = pdtype(cfg)
    return {
        "in_proj": _normal(ks[0], (d, w), dt),          # recurrence branch
        "gate_proj": _normal(ks[1], (d, w), dt),        # gelu gate branch
        "conv1d": _normal(ks[2], (cfg.conv_kernel, w), dt, scale=0.5),
        "conv_bias": jnp.zeros((w,), dt),
        "w_rgate": _normal(ks[3], (w, w), dt),          # r_t (recurrence)
        "w_igate": _normal(ks[4], (w, w), dt),          # i_t (input)
        "a_param": jnp.full((w,), _MAX_A, jnp.float32),
        "out_proj": _normal(ks[5], (w, d), dt),
    }


def _rglru_scan(x, r, i, a_param):
    """x/r/i: (B, L, W) float32.  Associative scan over (a, b) pairs."""
    log_a = _C * jax.nn.log_sigmoid(a_param) * jax.nn.sigmoid(r)
    a = jnp.exp(log_a)                                   # (B, L, W)
    gated = x * jax.nn.sigmoid(i)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
    return b_s, a_s          # h_t (with h_0 = 0), cumulative decay


def rglru_forward(p, xin, cfg: ModelConfig):
    """xin: (B, S, d) -> (out, cache)."""
    dt = cdtype(cfg)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xin,
                                  p["gate_proj"].astype(dt)))
    x = jnp.einsum("bsd,dw->bsw", xin, p["in_proj"].astype(dt))
    k = p["conv1d"].shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    l = x.shape[1]
    x = sum(xp[:, j:j + l] * p["conv1d"][j].astype(dt) for j in range(k))
    x = x + p["conv_bias"].astype(dt)
    conv_state = xp[:, -(k - 1):]

    xf = x.astype(jnp.float32)
    r = jnp.einsum("bsw,wv->bsv", xf, p["w_rgate"].astype(jnp.float32))
    i = jnp.einsum("bsw,wv->bsv", xf, p["w_igate"].astype(jnp.float32))
    h, _ = _rglru_scan(xf, r, i, p["a_param"])
    y = (h.astype(dt) * gate)
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"].astype(dt))
    cache = {"rnn": h[:, -1], "conv": conv_state}
    return out, cache


def rglru_decode(p, xin, cache, cfg: ModelConfig):
    """xin: (B, 1, d); cache {rnn: (B, W) f32, conv: (B, K-1, W)}."""
    dt = cdtype(cfg)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xin,
                                  p["gate_proj"].astype(dt)))
    x = jnp.einsum("bsd,dw->bsw", xin, p["in_proj"].astype(dt))
    k = p["conv1d"].shape[0]
    xp = jnp.concatenate([cache["conv"].astype(dt), x], axis=1)  # (B, K, W)
    x1 = sum(xp[:, j:j + 1] * p["conv1d"][j].astype(dt) for j in range(k))
    x1 = x1 + p["conv_bias"].astype(dt)
    conv_state = xp[:, 1:]

    xf = x1[:, 0].astype(jnp.float32)                   # (B, W)
    r = xf @ p["w_rgate"].astype(jnp.float32)
    i = xf @ p["w_igate"].astype(jnp.float32)
    log_a = _C * jax.nn.log_sigmoid(p["a_param"]) * jax.nn.sigmoid(r)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (xf * jax.nn.sigmoid(i))
    h = a * cache["rnn"] + b
    y = (h[:, None].astype(dt) * gate)
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"].astype(dt))
    return out, {"rnn": h, "conv": conv_state}


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    return {"rnn": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.rnn_width),
                              dtype)}
