"""Mamba-2 (SSD) mixer block — jnp chunked implementation + decode step.

The chunked algorithm is the paper's chaining model made literal: the
sequence is strip-mined into chunks (element groups); each chunk's interior
is dense MXU work (steady state) and a small (H, N, P) state chains across
chunks (the forwarded operand).  ``cfg.use_pallas=True`` routes the scan to
kernels/ssd.py on TPU; the jnp twin below is GSPMD-shardable and is what the
dry-run lowers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ashard
from repro.kernels import ops as kops
from repro.models.layers import _normal, apply_norm, cdtype, init_norm, pdtype


def init_ssd_block(key, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.d_inner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 6)
    dt = pdtype(cfg)
    return {
        # order: [z (gate) | x | B | C | dt]
        "in_proj": _normal(ks[0], (d, 2 * di + 2 * g * n + h), dt),
        "conv1d": _normal(ks[1], (cfg.conv_kernel, conv_dim), dt, scale=0.5),
        "conv_bias": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log)
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),   # softplus bias
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": init_norm(ks[3], cfg, di),
        "out_proj": _normal(ks[4], (di, d), dt),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B, L, C); w: (K, C) depthwise.  Returns (y, new_state).

    state: (B, K-1, C) trailing context for decode continuity."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # Depthwise causal conv as a sum of shifted scalings (K is tiny: 4).
    l = x.shape[1]
    y = sum(xp[:, i:i + l] * w[i] for i in range(k))
    y = y + b
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return y, new_state


def _ssd_chunked_jnp(x, dt, a, b, c, chunk: int):
    """Chunked SSD scan (same math as kernels/ssd.py, GSPMD-friendly).

    x: (B, L, H, P); dt: (B, L, H); a: (H,); b/c: (B, L, G, N).
    Returns (y: (B, L, H, P), h_final: (B, H, N, P))."""
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = l + pad
    nch = lp // chunk
    xe = ashard(x.reshape(bsz, nch, chunk, h, p),
                "batch", None, None, "heads", None)
    dte = ashard(dt.reshape(bsz, nch, chunk, h), "batch", None, None, "heads")
    be = ashard(jnp.repeat(b, rep, axis=2).reshape(bsz, nch, chunk, h, n),
                "batch", None, None, "heads", None)
    ce = ashard(jnp.repeat(c, rep, axis=2).reshape(bsz, nch, chunk, h, n),
                "batch", None, None, "heads", None)

    adt = a[None, None, None, :] * dte                  # (B, nc, T, H)
    cum = jnp.cumsum(adt, axis=2)
    total = cum[:, :, -1]                               # (B, nc, H)
    dtx = dte[..., None] * xe                           # (B, nc, T, H, P)

    # Intra-chunk (dense, causal-decay masked).
    lmask = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmask = jnp.where(tri[None, None, :, :, None], lmask, 0.0)
    scores = jnp.einsum("bgthn,bgshn->bgtsh", ce, be)
    y_intra = jnp.einsum("bgtsh,bgshp->bgthp", scores * lmask, dtx)

    # Chunk summaries -> cross-chunk scan of the (H, N, P) state.
    decay_end = jnp.exp(total[:, :, None, :] - cum)     # (B, nc, T, H)
    summary = jnp.einsum("bgthn,bgthp->bghnp", be * decay_end[..., None], dtx)

    def scan_fn(hprev, inp):
        summ, tot = inp                                 # (B,H,N,P), (B,H)
        hnew = jnp.exp(tot)[..., None, None] * hprev + summ
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    hT, hprevs = jax.lax.scan(
        scan_fn, h0,
        (summary.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         total.transpose(1, 0, 2).astype(jnp.float32)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)            # (B, nc, H, N, P)

    # Inter-chunk contribution.
    y_inter = jnp.einsum("bgthn,bghnp->bgthp",
                         ce * jnp.exp(cum)[..., None], hprevs)
    y = (y_intra + y_inter).reshape(bsz, lp, h, p)[:, :l]
    return y.astype(x.dtype), hT


def ssd_forward(p, xin, cfg: ModelConfig):
    """Full-sequence forward.  xin: (B, S, d) -> (out, cache)."""
    bsz, s, _ = xin.shape
    dt_ = cdtype(cfg)
    di, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    hp = cfg.ssm_headdim

    zxbcdt = jnp.einsum("bsd,de->bse", xin, p["in_proj"].astype(dt_))
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv1d"].astype(dt_),
                                   p["conv_bias"].astype(dt_))
    xbc = jax.nn.silu(xbc)
    x, b, c = jnp.split(xbc, [di, di + g * n], axis=-1)
    x = ashard(x.reshape(bsz, s, h, hp), "batch", "seq", "heads", None)
    b = b.reshape(bsz, s, g, n)
    c = c.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    if cfg.use_pallas:
        y, hT = kops.ssd_batched(x, dt, a, b, c, chunk=cfg.ssm_chunk)
        hT = jnp.asarray(hT)
    else:
        y, hT = _ssd_chunked_jnp(x, dt, a, b, c, cfg.ssm_chunk)
    y = y + p["d_skip"][None, None, :, None] * x        # D skip connection
    y = y.reshape(bsz, s, di)
    y = apply_norm(p["out_norm"], y, cfg) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y.astype(dt_), p["out_proj"].astype(dt_))
    cache = {"conv": conv_state, "ssm": hT.astype(jnp.float32)}
    return out, cache


def ssd_decode(p, xin, cache, cfg: ModelConfig):
    """Single-token decode.  xin: (B, 1, d); cache {conv: (B, K-1, C),
    ssm: (B, H, N, P)}."""
    bsz = xin.shape[0]
    dt_ = cdtype(cfg)
    di, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    hp = cfg.ssm_headdim

    zxbcdt = jnp.einsum("bsd,de->bse", xin, p["in_proj"].astype(dt_))
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv1d"].astype(dt_),
                                   p["conv_bias"].astype(dt_),
                                   state=cache["conv"])
    xbc = jax.nn.silu(xbc)
    x, b, c = jnp.split(xbc[:, 0], [di, di + g * n], axis=-1)
    x = x.reshape(bsz, h, hp)
    b = b.reshape(bsz, g, n)
    c = c.reshape(bsz, g, n)
    rep = h // g
    bh = jnp.repeat(b, rep, axis=1)                     # (B, H, N)
    ch = jnp.repeat(c, rep, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    hstate = cache["ssm"]                               # (B, H, N, P)
    decay = jnp.exp(a * dt)                             # (B, H)
    dbx = jnp.einsum("bhn,bhp,bh->bhnp", bh.astype(jnp.float32),
                     x.astype(jnp.float32), dt)
    hstate = decay[..., None, None] * hstate + dbx
    y = jnp.einsum("bhnp,bhn->bhp", hstate, ch.astype(jnp.float32))
    y = y + p["d_skip"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(bsz, 1, di).astype(dt_)
    y = apply_norm(p["out_norm"], y, cfg) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y.astype(dt_), p["out_proj"].astype(dt_))
    return out, {"conv": conv_state, "ssm": hstate}


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_state,
                          cfg.ssm_headdim), jnp.float32),
    }
