"""Composable model definitions for the assigned architectures."""
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_model, logits_fn)
from repro.models.lm import cross_entropy, loss_fn

__all__ = ["decode_step", "forward", "init_cache", "init_model",
           "logits_fn", "cross_entropy", "loss_fn"]
