"""Deviation-attribution engine (paper §II.C / §IV).

Decomposes simulated kernel executions against the ideal multi-lane
chaining model:

  * `repro.analysis.attribution` — phase decomposition (prologue / steady
    state / tail, `core.chaining` Eq. (1)-(5)) and per-critical-path stall
    accounting / gap-closed ratios;
  * `repro.analysis.timeline` — per-instruction Gantt export in Chrome
    ``trace_event`` JSON for any `(kernel, opt, params)` cell;
  * `repro.analysis.report` — per-kernel text/CSV stall breakdowns.

The underlying stall vectors come from `repro.core.simulator` (per
instruction) and `repro.core.batch_sim` (whole grids, numpy and jax
backends); `repro.core.stalls` defines the category vocabulary.
"""
from repro.analysis.attribution import (KernelAttribution,  # noqa: F401
                                        PhaseDecomposition, PhaseGrid,
                                        attribute_kernel, chain_spec_for,
                                        gap_closed_by_path, phase_decompose,
                                        phase_decompose_grid)
from repro.analysis.report import (breakdown_rows, format_report,  # noqa: F401
                                   render_stacked_bars, write_csv)
from repro.analysis.timeline import (export_chrome_trace,  # noqa: F401
                                     trace_events)
