"""Per-kernel stall-breakdown reports (text and CSV).

Turns attribution-carrying `SimResult`s into flat rows — cycles, ideal,
the nine stall categories, the three critical-path sums, and the top two
stall sources — plus an aligned text rendering for terminals.
"""
from __future__ import annotations

import pathlib
from typing import Mapping

from repro.core.simulator import SimResult
from repro.core.stalls import (CRITICAL_PATHS, STALL_CATEGORIES, as_row,
                               top_sources)


def breakdown_rows(results: Mapping[str, SimResult],
                   config: str | None = None) -> list[dict]:
    """One CSV-friendly row per kernel (insertion order preserved)."""
    rows = []
    for name, res in results.items():
        if res.stalls is None:
            raise ValueError(f"{name}: result carries no stall vector")
        row: dict = {"kernel": name}
        if config is not None:
            row["config"] = config
        row.update(as_row(res.ideal, res.stalls, res.cycles))
        row["stall_frac"] = (res.cycles - res.ideal) / max(res.cycles, 1e-9)
        top = top_sources(res.stalls, 2)
        row["top1"], row["top2"] = top[0][0], top[1][0]
        if res.phases:
            # Phase-split columns (grid attribution passes attach them):
            # prologue/steady/tail, dp/ii_eff/dt, t_ideal.
            row.update(res.phases)
        rows.append(row)
    return rows


def format_report(rows: list[dict], title: str = "stall breakdown") -> str:
    """Aligned text table: per-kernel critical-path shares + top sources."""
    lines = [f"# {title}",
             f"{'kernel':<8} {'config':<6} {'cycles':>10} {'ideal%':>7} "
             + "".join(f"{p:>11}" for p in CRITICAL_PATHS)
             + "  top stall sources"]
    for r in rows:
        cyc = r["cycles"]
        shares = "".join(
            f"{100.0 * r[p] / max(cyc, 1e-9):>10.1f}%" for p in CRITICAL_PATHS)
        lines.append(
            f"{r['kernel']:<8} {r.get('config', '-'):<6} {cyc:>10.0f} "
            f"{100.0 * r['ideal'] / max(cyc, 1e-9):>6.1f}% {shares}"
            f"  {r['top1']}, {r['top2']}")
    return "\n".join(lines)


def write_csv(rows: list[dict], path: str | pathlib.Path) -> pathlib.Path:
    """Persist breakdown rows as CSV; returns the path."""
    path = pathlib.Path(path)
    if not rows:
        path.write_text("")
        return path
    cols = list(rows[0].keys())
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(_fmt(r[c]) for c in cols))
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text("\n".join(lines) + "\n")
    return path


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


#: Stacked-bar segment colors: ideal grey, then one shade family per
#: critical path (mem_* blues, dep_* oranges, opr_* greens), ordered to
#: match ``["ideal", *STALL_CATEGORIES]``.
_BAR_COLORS = ("#d9d9d9",
               "#08519c", "#3182bd", "#6baed6", "#bdd7e7",
               "#e6550d", "#fdae6b",
               "#31a354", "#74c476", "#c7e9c0")


def have_matplotlib() -> bool:
    """True when the optional plotting dependency is importable."""
    try:
        import matplotlib  # noqa: F401
        return True
    except ImportError:
        return False


def render_stacked_bars(rows: list[dict], path: str | pathlib.Path,
                        normalize: bool = True,
                        title: str = "stall breakdown") -> pathlib.Path:
    """Render breakdown rows (fig6_attribution.csv shape) as stacked bars.

    One subplot per ``config`` value (row order preserved), x axis =
    kernels, each bar split into the ideal segment plus the nine stall
    categories shaded by critical path.  ``normalize`` plots fractions of
    measured cycles (so every bar tops out at 1.0); otherwise absolute
    cycles.  Needs matplotlib (the ``[plot]`` extra); raises
    ``RuntimeError`` when it is missing so callers can degrade cleanly
    via `have_matplotlib`.
    """
    if not have_matplotlib():
        raise RuntimeError(
            "render_stacked_bars needs matplotlib; install the [plot] "
            "extra (pip install -e .[plot])")
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    by_cfg: dict[str, list[dict]] = {}
    for r in rows:
        by_cfg.setdefault(str(r.get("config", "-")), []).append(r)
    ncfg = len(by_cfg)
    ncols = min(ncfg, 4)
    nrows = -(-ncfg // ncols)
    fig, axes = plt.subplots(nrows, ncols, sharey=normalize,
                             figsize=(3.2 * ncols + 1.6, 2.6 * nrows + 0.9),
                             squeeze=False)
    segments = ["ideal", *STALL_CATEGORIES]
    for ax in axes.flat[ncfg:]:
        ax.set_visible(False)
    for ax, (cfg, cfg_rows) in zip(axes.flat, by_cfg.items()):
        kernels = [r["kernel"] for r in cfg_rows]
        x = range(len(kernels))
        bottom = [0.0] * len(kernels)
        denom = [max(r["cycles"], 1e-9) if normalize else 1.0
                 for r in cfg_rows]
        for seg, color in zip(segments, _BAR_COLORS):
            vals = [r[seg] / d for r, d in zip(cfg_rows, denom)]
            ax.bar(x, vals, bottom=bottom, color=color, width=0.8,
                   label=seg)
            bottom = [b + v for b, v in zip(bottom, vals)]
        ax.set_title(cfg, fontsize=9)
        ax.set_xticks(list(x))
        ax.set_xticklabels(kernels, rotation=60, fontsize=7)
        ax.tick_params(axis="y", labelsize=7)
    axes.flat[0].set_ylabel("fraction of cycles" if normalize
                            else "cycles", fontsize=8)
    handles, labels = axes.flat[0].get_legend_handles_labels()
    fig.legend(handles, labels, loc="center right", fontsize=7,
               frameon=False)
    fig.suptitle(title, fontsize=11)
    fig.tight_layout(rect=(0, 0, 0.87, 0.96))
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return path


#: Critical-path accent colors for tornado bars (same families as the
#: stacked-bar shades: mem blues, dep oranges, opr greens; inherent and
#: unknown knobs grey).
_PATH_COLORS = {"mem_supply": "#3182bd", "dep_issue": "#e6550d",
                "operand": "#31a354", "inherent": "#969696"}


def render_tornado(rows: list[dict], path: str | pathlib.Path,
                   value: str = "swing_speedup", top: int = 8,
                   title: str = "sensitivity tornado") -> pathlib.Path:
    """Render fig7 knob rows (`launch.sensitivity.knob_rows` shape) as
    per-kernel tornado charts: horizontal bars, one per knob, widest
    (lowest `tornado_rank`) on top, colored by the knob's critical
    path.  `top` bounds the knobs shown per kernel."""
    if not have_matplotlib():
        raise RuntimeError(
            "render_tornado needs matplotlib; install the [plot] "
            "extra (pip install -e .[plot])")
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    by_kernel: dict[str, list[dict]] = {}
    for r in rows:
        by_kernel.setdefault(str(r["kernel"]), []).append(r)
    nk = len(by_kernel)
    ncols = min(nk, 4)
    nrows = -(-nk // ncols)
    fig, axes = plt.subplots(nrows, ncols,
                             figsize=(3.4 * ncols + 1.2, 2.4 * nrows + 0.8),
                             squeeze=False)
    for ax in axes.flat[nk:]:
        ax.set_visible(False)
    for ax, (kernel, krows) in zip(axes.flat, by_kernel.items()):
        ranked = sorted(krows, key=lambda r: r["tornado_rank"])[:top]
        ranked = ranked[::-1]              # widest bar on top
        y = range(len(ranked))
        vals = [r[value] for r in ranked]
        colors = [_PATH_COLORS.get(r.get("path", ""), "#969696")
                  for r in ranked]
        ax.barh(list(y), vals, color=colors, height=0.7)
        ax.set_yticks(list(y))
        ax.set_yticklabels([r["knob"] for r in ranked], fontsize=6)
        ax.tick_params(axis="x", labelsize=6)
        ax.set_title(kernel, fontsize=9)
    fig.suptitle(f"{title} ({value})", fontsize=11)
    fig.tight_layout(rect=(0, 0, 1, 0.95))
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return path


def render_param_heatmap(rows: list[dict], knobs: tuple[str, str],
                         path: str | pathlib.Path,
                         value: str = "gap_closed",
                         title: str = "pairwise sensitivity"
                         ) -> pathlib.Path:
    """Render fig7 pairwise rows (`launch.sensitivity.pair_rows` shape)
    as one heatmap per kernel: knob 1 on x, knob 2 on y, cell color =
    `value` (gap-closed ratio by default)."""
    if not have_matplotlib():
        raise RuntimeError(
            "render_param_heatmap needs matplotlib; install the [plot] "
            "extra (pip install -e .[plot])")
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt
    import numpy as np

    f1, f2 = knobs
    by_kernel: dict[str, list[dict]] = {}
    for r in rows:
        by_kernel.setdefault(str(r["kernel"]), []).append(r)
    nk = len(by_kernel)
    ncols = min(nk, 4)
    nrows = -(-nk // ncols)
    fig, axes = plt.subplots(nrows, ncols,
                             figsize=(3.0 * ncols + 1.4, 2.6 * nrows + 0.8),
                             squeeze=False)
    for ax in axes.flat[nk:]:
        ax.set_visible(False)
    im = None
    for ax, (kernel, krows) in zip(axes.flat, by_kernel.items()):
        xs = sorted({r[f1] for r in krows})
        ys = sorted({r[f2] for r in krows})
        grid = np.full((len(ys), len(xs)), np.nan)
        for r in krows:
            grid[ys.index(r[f2]), xs.index(r[f1])] = r[value]
        im = ax.imshow(grid, origin="lower", aspect="auto",
                       cmap="viridis")
        ax.set_xticks(range(len(xs)))
        ax.set_xticklabels([f"{x:.3g}" for x in xs], fontsize=6,
                           rotation=45)
        ax.set_yticks(range(len(ys)))
        ax.set_yticklabels([f"{y:.3g}" for y in ys], fontsize=6)
        ax.set_xlabel(f1, fontsize=7)
        ax.set_ylabel(f2, fontsize=7)
        ax.set_title(kernel, fontsize=9)
    if im is not None:
        fig.colorbar(im, ax=axes.ravel().tolist(), fraction=0.02,
                     label=value)
    fig.suptitle(f"{title} ({value})", fontsize=11)
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return path


def render_frontier(rows: list[dict], path: str | pathlib.Path,
                    title: str = "design-search Pareto frontier"
                    ) -> pathlib.Path:
    """Render fig9 frontier rows as a cost/score scatter.

    `rows` is the fig9_search CSV shape: every evaluated-or-frontier
    point carries ``cost``, ``score``, ``label`` and an ``on_frontier``
    flag.  Frontier points draw as a step line (the achievable
    trade-off curve) colored by dominant path; non-frontier evaluations
    (when present) scatter grey underneath, showing what the search
    rejected."""
    if not have_matplotlib():
        raise RuntimeError(
            "render_frontier needs matplotlib; install the [plot] "
            "extra (pip install -e .[plot])")
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    front = [r for r in rows if r.get("on_frontier", True)]
    rest = [r for r in rows if not r.get("on_frontier", True)]
    front.sort(key=lambda r: r["cost"])
    fig, ax = plt.subplots(figsize=(5.4, 3.6))
    if rest:
        ax.scatter([r["cost"] for r in rest], [r["score"] for r in rest],
                   s=12, color="#cccccc", zorder=1, label="evaluated")
    ax.step([r["cost"] for r in front], [r["score"] for r in front],
            where="post", color="#555555", lw=1, zorder=2)
    colors = [_PATH_COLORS.get(r.get("dominant_path", ""), "#969696")
              for r in front]
    ax.scatter([r["cost"] for r in front], [r["score"] for r in front],
               s=30, c=colors, zorder=3, label="frontier")
    for r in front:
        ax.annotate(str(r.get("label", "")), (r["cost"], r["score"]),
                    fontsize=5, xytext=(2, 2),
                    textcoords="offset points")
    ax.set_xlabel("cost (area mm$^2$)", fontsize=8)
    ax.set_ylabel("score (geomean speedup)", fontsize=8)
    ax.tick_params(labelsize=7)
    ax.legend(fontsize=6)
    ax.set_title(title, fontsize=10)
    fig.tight_layout()
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return path


def render_convergence(history: list[dict], path: str | pathlib.Path,
                       title: str = "design-search convergence"
                       ) -> pathlib.Path:
    """Render a search log (fig9_convergence CSV shape: per-generation
    ``gen``/``best_score``/``frontier_size``/``archive`` rows) as the
    best-score trajectory with the frontier size on a twin axis."""
    if not have_matplotlib():
        raise RuntimeError(
            "render_convergence needs matplotlib; install the [plot] "
            "extra (pip install -e .[plot])")
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    gens = [r["gen"] for r in history]
    fig, ax = plt.subplots(figsize=(5.0, 3.2))
    ax.plot(gens, [r["best_score"] for r in history], "o-",
            color="#08519c", label="best score")
    ax.set_xlabel("generation", fontsize=8)
    ax.set_ylabel("best feasible score", color="#08519c", fontsize=8)
    ax.tick_params(labelsize=7)
    ax2 = ax.twinx()
    ax2.plot(gens, [r["frontier_size"] for r in history], "s--",
             color="#31a354", label="frontier size")
    ax2.set_ylabel("frontier size", color="#31a354", fontsize=8)
    ax2.tick_params(labelsize=7)
    ax.set_title(title, fontsize=10)
    fig.tight_layout()
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return path


__all__ = ["breakdown_rows", "format_report", "write_csv",
           "have_matplotlib", "render_stacked_bars", "render_tornado",
           "render_param_heatmap", "render_frontier",
           "render_convergence", "STALL_CATEGORIES"]
