"""Per-kernel stall-breakdown reports (text and CSV).

Turns attribution-carrying `SimResult`s into flat rows — cycles, ideal,
the nine stall categories, the three critical-path sums, and the top two
stall sources — plus an aligned text rendering for terminals.
"""
from __future__ import annotations

import pathlib
from typing import Mapping

from repro.core.simulator import SimResult
from repro.core.stalls import (CRITICAL_PATHS, STALL_CATEGORIES, as_row,
                               top_sources)


def breakdown_rows(results: Mapping[str, SimResult],
                   config: str | None = None) -> list[dict]:
    """One CSV-friendly row per kernel (insertion order preserved)."""
    rows = []
    for name, res in results.items():
        if res.stalls is None:
            raise ValueError(f"{name}: result carries no stall vector")
        row: dict = {"kernel": name}
        if config is not None:
            row["config"] = config
        row.update(as_row(res.ideal, res.stalls, res.cycles))
        row["stall_frac"] = (res.cycles - res.ideal) / max(res.cycles, 1e-9)
        top = top_sources(res.stalls, 2)
        row["top1"], row["top2"] = top[0][0], top[1][0]
        rows.append(row)
    return rows


def format_report(rows: list[dict], title: str = "stall breakdown") -> str:
    """Aligned text table: per-kernel critical-path shares + top sources."""
    lines = [f"# {title}",
             f"{'kernel':<8} {'config':<6} {'cycles':>10} {'ideal%':>7} "
             + "".join(f"{p:>11}" for p in CRITICAL_PATHS)
             + "  top stall sources"]
    for r in rows:
        cyc = r["cycles"]
        shares = "".join(
            f"{100.0 * r[p] / max(cyc, 1e-9):>10.1f}%" for p in CRITICAL_PATHS)
        lines.append(
            f"{r['kernel']:<8} {r.get('config', '-'):<6} {cyc:>10.0f} "
            f"{100.0 * r['ideal'] / max(cyc, 1e-9):>6.1f}% {shares}"
            f"  {r['top1']}, {r['top2']}")
    return "\n".join(lines)


def write_csv(rows: list[dict], path: str | pathlib.Path) -> pathlib.Path:
    """Persist breakdown rows as CSV; returns the path."""
    path = pathlib.Path(path)
    if not rows:
        path.write_text("")
        return path
    cols = list(rows[0].keys())
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(_fmt(r[c]) for c in cols))
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text("\n".join(lines) + "\n")
    return path


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


__all__ = ["breakdown_rows", "format_report", "write_csv",
           "STALL_CATEGORIES"]
