"""Per-instruction Gantt export in Chrome ``trace_event`` JSON.

Any `(kernel, opt, params)` cell simulated by `AraSimulator.run` can be
dumped as a trace viewable in ``chrome://tracing`` / Perfetto: one "X"
(complete) event per vector instruction on the execution-resource track it
occupied, with the instruction's exact stall decomposition attached as
event ``args``.  One simulated cycle is rendered as one microsecond.
"""
from __future__ import annotations

import json
import pathlib

from repro.core.isa import KernelTrace, OpKind
from repro.core.simulator import SimResult
from repro.core.stalls import stall_dict

#: Track (Chrome tid) per resource class.
_TRACKS = {
    OpKind.LOAD: (1, "VLSU read"),
    OpKind.STORE: (2, "VLSU write"),
    OpKind.COMPUTE: (3, "FPU lanes"),
    OpKind.REDUCE: (3, "FPU lanes"),
    OpKind.SLIDE: (4, "SLDU"),
}


def trace_events(trace: KernelTrace, result: SimResult,
                 pid: int = 0) -> list[dict]:
    """Chrome ``trace_event`` list for one simulated cell.

    ``pid`` selects the Perfetto process row — pass distinct pids to
    merge several cells (or a cell plus host-side spans, see
    `repro.obs.export.export_merged_trace`) into one file.
    """
    if len(result.timings) != len(trace.instrs):
        raise ValueError(
            "result carries no per-instruction timings for this trace "
            "(cache-restored results cannot be exported; re-simulate with "
            "AraSimulator.run)")
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid,
        "args": {"name": f"{trace.name} [{result.kernel}]"},
    }]
    for tid, label in sorted(set(_TRACKS.values())):
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": label}})
    for idx, (ins, t) in enumerate(zip(trace.instrs, result.timings)):
        tid, _ = _TRACKS[ins.kind]
        args = {
            "instr": idx,
            "vl": ins.vl,
            "first_out": t.first_out,
            "read_done": t.read_done,
            "ideal": t.ideal,
        }
        if ins.stream:
            args["stream"] = ins.stream
        if t.stalls is not None:
            args.update({k: v for k, v in stall_dict(t.stalls).items()
                         if v > 0.0})
        events.append({
            "name": f"{ins.name} vl={ins.vl}",
            "cat": ins.kind.value,
            "ph": "X",
            "pid": pid,
            "tid": tid,
            "ts": t.start,                      # 1 cycle == 1 us
            "dur": max(t.complete - t.start, 0.0),
            "args": args,
        })
    return events


def export_chrome_trace(path: str | pathlib.Path, trace: KernelTrace,
                        result: SimResult) -> pathlib.Path:
    """Write one cell's Gantt as Chrome trace JSON; returns the path."""
    path = pathlib.Path(path)
    payload = {
        "traceEvents": trace_events(trace, result),
        "displayTimeUnit": "ms",
        "metadata": {
            "kernel": trace.name,
            "problem": trace.problem,
            "cycles": result.cycles,
            "ideal": result.ideal,
        },
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(payload, indent=1))
    return path
