"""Attribute simulated executions to the ideal chaining model's terms.

Two complementary decompositions of the same measured cycles:

  * **Phase decomposition** (`phase_decompose`): split a run into
    prologue / steady state / tail against a `core.chaining.ChainSpec`
    built structurally from the trace, and back out the paper's deviation
    triple ``(dp, II_eff, dt)`` (Eq. (4)/(5)) with
    `core.chaining.attribute`.  `phase_decompose_grid` is the batched
    counterpart: it reads the phase observables a
    `core.batch_sim.BatchResult` carries (earliest lane ``first_out``,
    finisher start) and backs out the triple for every
    `(kernel, opt, params)` cell in one vectorized pass — no scalar loop
    over cells.
  * **Critical-path accounting** (`attribute_kernel`,
    `gap_closed_by_path`): read the simulator's exact per-category stall
    vector (``ideal + sum(stalls) == cycles``) and aggregate it over the
    paper's three critical paths — memory-side supply, dependence & issue
    control, operand delivery.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core.chaining import ChainSpec, Deviation, attribute
from repro.core.isa import KernelTrace, MachineConfig, OpKind, OptConfig
from repro.core.simulator import AraSimulator, SimParams, SimResult
from repro.core.stalls import group_stalls, stall_dict, top_sources


@dataclasses.dataclass(frozen=True)
class PhaseDecomposition:
    """Measured phase times + deviation terms against the ideal spec."""
    spec: ChainSpec
    prologue_real: float
    steady_real: float
    tail_real: float
    deviation: Deviation

    @property
    def t_real(self) -> float:
        return self.prologue_real + self.steady_real + self.tail_real

    @property
    def t_ideal(self) -> float:
        return self.spec.t_ideal

    @property
    def loss(self) -> float:
        """Eq. (5): dT = dp + T_steady*(II_eff - 1) + dt."""
        return self.deviation.loss(self.spec)


@dataclasses.dataclass(frozen=True)
class KernelAttribution:
    """One cell's full attribution bundle."""
    kernel: str
    opt_label: str
    result: SimResult
    phases: PhaseDecomposition
    stalls: dict[str, float]           # per category (9)
    paths: dict[str, float]            # per critical path (3)

    @property
    def top2(self) -> list[tuple[str, float]]:
        return top_sources(self.result.stalls, 2)


def _chain_depth(trace: KernelTrace) -> int:
    """Longest RAW chain (number of dependent stages) through the trace."""
    depth: dict[str, int] = {}
    best = 1
    for ins in trace.instrs:
        d = 1 + max((depth.get(s, 0) for s in ins.srcs), default=0)
        best = max(best, d)
        if ins.dst is not None:
            depth[ins.dst] = d
    return best


def _tail_ideal(trace: KernelTrace, mc: MachineConfig,
                params: SimParams) -> float:
    """Ideal drain time of the final instruction (the chain's tail)."""
    if not trace.instrs:
        return 0.0
    last = trace.instrs[-1]
    epc = mc.elems_per_cycle
    if last.kind is OpKind.STORE:
        return last.bytes / mc.axi_bytes_per_cycle
    if last.kind is OpKind.LOAD:
        return params.prefetch_hit + mc.burst_bytes / mc.axi_bytes_per_cycle
    tail = mc.fu_latency + last.vl / epc
    if last.kind is OpKind.REDUCE:
        import math
        tail += math.ceil(math.log2(max(last.vl, 2))) * mc.fu_latency
    return tail


def chain_spec_for(trace: KernelTrace,
                   mc: MachineConfig = MachineConfig(),
                   params: SimParams = SimParams()) -> ChainSpec:
    """Ideal `ChainSpec` for a kernel trace (paper Eq. (1)-(3)).

    Startup delays are the forwarding floor per dependent stage of the
    longest RAW chain, fill time is the FU pipeline depth, and the steady
    state is the roofline floor — perfectly overlapped lanes and memory,
    whichever is slower.  `ChainSpec.steady_ideal` is `ceil(vl / lanes)`,
    so the floor is encoded as an effective element count on `epc` lanes.
    """
    epc = mc.elems_per_cycle
    lane_elems = sum(i.vl for i in trace.instrs
                     if i.kind not in (OpKind.LOAD, OpKind.STORE))
    mem_bytes = sum(i.bytes for i in trace.instrs)
    steady_floor = max(lane_elems / epc, mem_bytes / mc.axi_bytes_per_cycle)
    depth = _chain_depth(trace)
    return ChainSpec(
        startup_delays=(params.d_fwd,) * max(depth - 1, 0),
        fill_time=float(mc.fu_latency),
        tail_time=_tail_ideal(trace, mc, params),
        vl=max(int(round(steady_floor * epc)), 1),
        lanes=epc)


def phase_decompose(trace: KernelTrace, result: SimResult,
                    mc: MachineConfig = MachineConfig(),
                    params: SimParams = SimParams()) -> PhaseDecomposition:
    """Split measured cycles into prologue / steady / tail and back out
    the deviation triple ``(dp, II_eff, dt)`` (exact: the returned
    `Deviation.t_real(spec) == result.cycles`).

    Phase boundaries are read off the timings: the prologue ends when the
    chain first produces a lane result (earliest compute `first_out`), the
    tail begins when the finishing instruction starts.
    """
    spec = chain_spec_for(trace, mc, params)
    cycles = result.cycles
    if not result.timings:
        dev = attribute(spec, 0.0, 0.0, 0.0)
        return PhaseDecomposition(spec, 0.0, 0.0, 0.0, dev)
    lane_fo = [t.first_out for t, i in zip(result.timings, trace.instrs)
               if i.kind not in (OpKind.LOAD, OpKind.STORE)]
    prologue_real = min(lane_fo) if lane_fo else result.timings[0].first_out
    prologue_real = min(prologue_real, cycles)
    finisher = max(result.timings, key=lambda t: t.complete)
    tail_real = min(cycles - finisher.start, cycles - prologue_real)
    steady_real = cycles - prologue_real - tail_real
    dev = attribute(spec, cycles, prologue_real, tail_real)
    return PhaseDecomposition(spec, prologue_real, steady_real, tail_real,
                              dev)


@dataclasses.dataclass(frozen=True)
class PhaseGrid:
    """Vectorized phase decomposition of a whole `(B, O, P)` batch grid.

    Ideal-model terms (from `chain_spec_for`) depend only on the trace and
    params, so they carry `(B, P)` shape; measured phases and the deviation
    triple are per cell, `(B, O, P)`.  `cell(b, o, p)` reconstructs the
    scalar `PhaseDecomposition` for one cell.
    """
    names: tuple[str, ...]             # (B,) kernel names
    specs: tuple[tuple[ChainSpec, ...], ...]   # [B][P] ideal chain specs
    prologue_ideal: np.ndarray         # (B, P) Eq. (1) p_N
    steady_ideal: np.ndarray           # (B, P) Eq. (2) T_steady
    tail_ideal: np.ndarray             # (B, P) T_tail
    t_ideal: np.ndarray                # (B, P) Eq. (3)
    prologue_real: np.ndarray          # (B, O, P)
    steady_real: np.ndarray            # (B, O, P)
    tail_real: np.ndarray              # (B, O, P)
    dp: np.ndarray                     # (B, O, P) prologue deviation
    ii_eff: np.ndarray                 # (B, O, P) effective II
    dt: np.ndarray                     # (B, O, P) tail deviation

    @property
    def t_real(self) -> np.ndarray:
        """(B, O, P) measured cycles reconstructed from Eq. (4)."""
        return self.prologue_real + self.steady_real + self.tail_real

    @property
    def loss(self) -> np.ndarray:
        """(B, O, P) Eq. (5): dT = dp + T_steady*(II_eff - 1) + dt."""
        return (self.dp
                + self.steady_ideal[:, None, :] * (self.ii_eff - 1.0)
                + self.dt)

    def cell(self, b: int, o: int, p: int = 0) -> PhaseDecomposition:
        """Scalar `PhaseDecomposition` view of one grid cell."""
        dev = Deviation(dp=float(self.dp[b, o, p]),
                        ii_eff=float(self.ii_eff[b, o, p]),
                        dt=float(self.dt[b, o, p]))
        return PhaseDecomposition(
            spec=self.specs[b][p],
            prologue_real=float(self.prologue_real[b, o, p]),
            steady_real=float(self.steady_real[b, o, p]),
            tail_real=float(self.tail_real[b, o, p]),
            deviation=dev)

    def columns(self, b: int, o: int, p: int = 0) -> dict[str, float]:
        """One cell's phase split as flat CSV-friendly columns."""
        return {
            "prologue": float(self.prologue_real[b, o, p]),
            "steady": float(self.steady_real[b, o, p]),
            "tail": float(self.tail_real[b, o, p]),
            "dp": float(self.dp[b, o, p]),
            "ii_eff": float(self.ii_eff[b, o, p]),
            "dt": float(self.dt[b, o, p]),
            "t_ideal": float(self.t_ideal[b, p]),
        }


def phase_decompose_grid(traces: Sequence[KernelTrace], result,
                         mc: MachineConfig = MachineConfig(),
                         params: SimParams | Sequence[SimParams]
                         = SimParams()) -> PhaseGrid:
    """Batched `phase_decompose`: back out ``(dp, II_eff, dt)`` for every
    `(kernel, opt, params)` cell of a `core.batch_sim.BatchResult` in one
    vectorized pass.

    `traces` must be the sequence the grid was stacked from (same order as
    `result` axis 0) and `params` the params axis (axis 2).  The ideal
    `ChainSpec` terms are structural per `(trace, params)`; the measured
    phase boundaries come from the phase observables both batch backends
    carry (`lane_first_out`, `first_first_out`, `finish_start`).  Numbers
    match per-cell `phase_decompose` of the scalar simulator exactly on
    the numpy backend (float64 allclose on jax).
    """
    if result.lane_first_out is None or result.finish_start is None:
        raise ValueError("BatchResult carries no phase observables; "
                         "re-run BatchAraSimulator.run on this engine "
                         "version")
    if isinstance(params, SimParams):
        params = [params]
    params = list(params)
    traces = list(traces)
    B, O, P = result.cycles.shape
    if len(traces) != B or len(params) != P:
        raise ValueError(f"grid shape {(B, O, P)} does not match "
                         f"{len(traces)} traces x {len(params)} params")
    specs = tuple(tuple(chain_spec_for(tr, mc, p) for p in params)
                  for tr in traces)
    prologue_i = np.array([[s.prologue for s in row] for row in specs])
    steady_i = np.array([[float(s.steady_ideal) for s in row]
                         for row in specs])
    tail_i = np.array([[s.tail_time for s in row] for row in specs])

    cycles = result.cycles
    # Prologue ends at the earliest lane first_out; traces with no lane
    # instruction fall back to the first instruction's first_out (the
    # same rule as the scalar `phase_decompose`).
    lane_fo = result.lane_first_out
    prologue_real = np.where(np.isfinite(lane_fo), lane_fo,
                             result.first_first_out)
    prologue_real = np.minimum(prologue_real, cycles)
    tail_real = np.minimum(cycles - result.finish_start,
                           cycles - prologue_real)
    steady_real = cycles - prologue_real - tail_real
    dp = prologue_real - prologue_i[:, None, :]
    dt = tail_real - tail_i[:, None, :]
    ii_eff = steady_real / np.maximum(steady_i[:, None, :], 1e-12)
    return PhaseGrid(names=tuple(result.names), specs=specs,
                     prologue_ideal=prologue_i, steady_ideal=steady_i,
                     tail_ideal=tail_i,
                     t_ideal=prologue_i + steady_i + tail_i,
                     prologue_real=prologue_real, steady_real=steady_real,
                     tail_real=tail_real, dp=dp, ii_eff=ii_eff, dt=dt)


def attribute_kernel(trace: KernelTrace,
                     opt: OptConfig = OptConfig.baseline(),
                     params: SimParams = SimParams(),
                     mc: MachineConfig = MachineConfig(),
                     result: SimResult | None = None) -> KernelAttribution:
    """Full attribution of one `(trace, opt, params)` cell.

    Pass `result` to reuse an existing simulation (it must carry timings
    and stall vectors, i.e. come from `AraSimulator.run`, not the cache).
    """
    if result is None or result.stalls is None or not result.timings:
        result = AraSimulator(mc, params).run(trace, opt)
    phases = phase_decompose(trace, result, mc, params)
    return KernelAttribution(
        kernel=trace.name, opt_label=opt.label, result=result,
        phases=phases, stalls=stall_dict(result.stalls),
        paths=group_stalls(result.stalls))


def gap_closed_by_path(base: SimResult, opt: SimResult,
                       eps: float = 1e-9) -> dict[str, float]:
    """Fraction of each critical path's baseline stall that an optimized
    configuration eliminates (the attribution analogue of Fig. 4's
    gap-closed metric).  A path with no baseline stall reports 1.0."""
    if base.stalls is None or opt.stalls is None:
        raise ValueError("gap_closed_by_path needs attribution-carrying "
                         "SimResults (AraSimulator.run or attribution "
                         "batch cells)")
    gb = group_stalls(base.stalls)
    go = group_stalls(opt.stalls)
    out = {}
    for path, b in gb.items():
        out[path] = 1.0 if b <= eps else (b - go[path]) / b
    return out


def summarize(results: Mapping[str, SimResult]) -> dict[str, dict]:
    """Per-kernel critical-path sums + top-2 sources, for quick printing."""
    out = {}
    for name, res in results.items():
        if res.stalls is None:
            continue
        out[name] = {"paths": group_stalls(res.stalls),
                     "top2": top_sources(res.stalls, 2),
                     "ideal": res.ideal, "cycles": res.cycles}
    return out
