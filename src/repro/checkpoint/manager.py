"""Checkpointing: async, atomic, integrity-checked, mesh-reshardable.

Fault-tolerance contract:
  * async — training never blocks on persistence (the paper's early
    dependence release applied to the I/O path: the step only "reads" the
    state; the write happens in the background on a host copy);
  * atomic — a checkpoint directory appears only via os.replace of a fully
    written tmp dir, so a crash mid-write can never corrupt the latest
    checkpoint;
  * integrity — every array file carries a crc32 recorded in the manifest,
    verified on restore;
  * reshardable — leaves are restored via jax.make_array_from_callback
    against *target* shardings, so a checkpoint saved on one mesh restores
    onto any other (elastic scaling / shrink-to-recover).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np


def _with_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """np.load(mmap) drops ml_dtypes descriptors (bf16 loads as |V2):
    reinterpret raw bytes via the manifest-recorded dtype."""
    try:
        want = np.dtype(dtype_str)
    except TypeError:
        want = np.dtype(getattr(ml_dtypes, dtype_str))
    if arr.dtype == want:
        return arr
    if arr.dtype.kind == "V" and arr.dtype.itemsize == want.itemsize:
        return arr.view(want)
    return arr.astype(want)


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((name, leaf))
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3,
                 async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        # Host copy happens on the caller thread (cheap device->host on this
        # container; on TPU it's the only sync part), I/O in the background.
        items, _ = _flatten(tree)
        host_items = [(n, np.asarray(v)) for n, v in items]
        self.wait()
        if self.async_save:
            self._pending = threading.Thread(
                target=self._write, args=(step, host_items, extra or {}),
                daemon=True)
            self._pending.start()
        else:
            self._write(step, host_items, extra or {})

    def _write(self, step: int, host_items, extra: dict) -> None:
        tmp = self.dir / f".tmp_step_{step:010d}"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extra": extra, "arrays": {}}
        for name, arr in host_items:
            fname = f"{name}.npy"
            np.save(tmp / fname, arr)
            crc = zlib.crc32((tmp / fname).read_bytes())
            manifest["arrays"][name] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "crc32": crc}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("step_*"))
        for old in ckpts[: max(0, len(ckpts) - self.keep)]:
            shutil.rmtree(old, ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> int | None:
        # A crash right after save() can leave the async write in flight;
        # discovery must not race it (auto-resume would miss the newest —
        # or only — checkpoint), so join any pending writer first.
        self.wait()
        ckpts = sorted(self.dir.glob("step_*"))
        if not ckpts:
            return None
        return int(ckpts[-1].name.split("_")[1])

    def restore(self, step: int | None, target: Any,
                shardings: Any | None = None) -> tuple[Any, dict]:
        """Restore into the structure of `target` (a pytree of arrays or
        ShapeDtypeStructs).  `shardings`: optional matching tree of
        NamedShardings for cross-mesh resharded restore."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:010d}"
        manifest = json.loads((path / "manifest.json").read_text())

        items, treedef = _flatten(target)
        sharding_items = None
        if shardings is not None:
            sharding_items, _ = _flatten(shardings)

        leaves = []
        for i, (name, ref) in enumerate(items):
            meta = manifest["arrays"][name]
            fpath = path / meta["file"]
            crc = zlib.crc32(fpath.read_bytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption in {fpath}")
            arr = np.load(fpath, mmap_mode="r")
            assert list(arr.shape) == list(ref.shape), (name, arr.shape,
                                                        ref.shape)
            if sharding_items is not None:
                sh = sharding_items[i][1]
                leaf = jax.make_array_from_callback(
                    arr.shape, sh,
                    lambda idx, a=arr, d=meta["dtype"]: _with_dtype(
                        np.asarray(a), d)[idx])
            else:
                leaf = jnp.asarray(_with_dtype(np.asarray(arr),
                                               meta["dtype"]))
            leaves.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, leaves), \
            manifest["extra"]
