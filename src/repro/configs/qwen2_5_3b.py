"""Assigned architecture config (see registry.py for the sourced spec)."""
from repro.configs.registry import QWEN2_5_3B as CONFIG, reduced

SMOKE = reduced(CONFIG)
