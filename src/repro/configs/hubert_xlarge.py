"""Assigned architecture config (see registry.py for the sourced spec)."""
from repro.configs.registry import HUBERT_XLARGE as CONFIG, reduced

SMOKE = reduced(CONFIG)
