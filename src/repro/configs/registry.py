"""The 10 assigned architectures (exact configs from the brief) plus
reduced smoke-test variants.

Every entry records its public source in a comment; full configs are only
ever lowered abstractly (dry-run); reduced configs run on CPU.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

# --- dense GQA transformers --------------------------------------------------

# [hf:THUDM/glm-4-9b] 40L d=4096 32H kv=2 ff=13696 v=151552, RoPE, GQA
GLM4_9B = ModelConfig(
    name="glm4-9b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    head_dim=128, d_ff=13696, vocab_size=151552, pattern=("attn",),
    ffn="glu", act="silu", norm="rmsnorm", rope_theta=1e4, qkv_bias=True)

# [arXiv:2402.19173] 32L d=4608 36H kv=4 ff=18432 v=49152, GQA, RoPE
STARCODER2_7B = ModelConfig(
    name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36,
    n_kv_heads=4, head_dim=128, d_ff=18432, vocab_size=49152,
    pattern=("attn",), ffn="mlp", act="gelu", norm="layernorm",
    qkv_bias=True, mlp_bias=True, rope_theta=1e5)

# [hf:google/gemma-3-*] 62L d=5376 32H kv=16 ff=21504 v=262144, 5:1
# local:global, 128k context
GEMMA3_27B = ModelConfig(
    name="gemma3-27b", n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    head_dim=128, d_ff=21504, vocab_size=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    ffn="glu", act="gelu_tanh", norm="gemma", qk_norm=True,
    sliding_window=1024, rope_theta=1e4, rope_theta_global=1e6,
    embed_scale=True, tie_embeddings=True, final_logit_softcap=30.0)

# [hf:Qwen/Qwen2.5-*] 36L d=2048 16H kv=2 ff=11008 v=151936, GQA, QKV bias
QWEN2_5_3B = ModelConfig(
    name="qwen2.5-3b", n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    head_dim=128, d_ff=11008, vocab_size=151936, pattern=("attn",),
    ffn="glu", act="silu", norm="rmsnorm", qkv_bias=True,
    rope_theta=1e6, tie_embeddings=True)

# --- MoE ----------------------------------------------------------------------

# [arXiv:2405.04434] 60L d=5120 128H ff(expert)=1536 v=102400,
# MLA kv_lora=512, 2 shared + 160 routed top-6
DEEPSEEK_V2_236B = ModelConfig(
    name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
    n_kv_heads=128, head_dim=128, d_ff=12288, vocab_size=102400,
    pattern=("mla",), ffn="moe", act="silu", norm="rmsnorm",
    n_experts=160, moe_top_k=6, moe_d_ff=1536, n_shared_experts=2,
    first_dense_layers=1, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    rope_theta=1e4)

# [hf:ibm-granite/granite-3.0-*-base] 32L d=1536 24H kv=8 v=49155,
# MoE 40e top-8, expert ff=512 (brief note lists 32e; the structured spec
# says 40e — we follow the structured spec and record the discrepancy).
GRANITE_MOE_3B = ModelConfig(
    name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, head_dim=64, d_ff=512, vocab_size=49155,
    pattern=("attn",), ffn="moe", act="silu", norm="rmsnorm",
    n_experts=40, moe_top_k=8, moe_d_ff=512, rope_theta=1e4,
    tie_embeddings=True)

# --- hybrid / SSM -------------------------------------------------------------

# [arXiv:2402.19427] 26L d=2560 10H kv=1 ff=7680 v=256000, RG-LRU + local
# attention 1:2 (pattern rec,rec,local), window 2048
RECURRENTGEMMA_2B = ModelConfig(
    name="recurrentgemma-2b", n_layers=26, d_model=2560, n_heads=10,
    n_kv_heads=1, head_dim=256, d_ff=7680, vocab_size=256000,
    pattern=("rglru", "rglru", "local"), ffn="glu", act="gelu_tanh",
    norm="gemma", sliding_window=2048, rope_theta=1e4, embed_scale=True,
    tie_embeddings=True, lru_width=2560)

# [arXiv:2405.21060] 48L d=1536 attn-free v=50280, SSD, state=128
MAMBA2_780M = ModelConfig(
    name="mamba2-780m", n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    head_dim=0, d_ff=0, vocab_size=50280, pattern=("ssd",), ffn="none",
    norm="rmsnorm", ssm_state=128, ssm_headdim=64, ssm_ngroups=1,
    ssm_expand=2, conv_kernel=4, tie_embeddings=True)

# --- audio / vlm ---------------------------------------------------------------

# [arXiv:2106.07447] 48L d=1280 16H ff=5120 v=504, encoder-only
HUBERT_XLARGE = ModelConfig(
    name="hubert-xlarge", n_layers=48, d_model=1280, n_heads=16,
    n_kv_heads=16, head_dim=80, d_ff=5120, vocab_size=504,
    pattern=("attn",), ffn="mlp", act="gelu", norm="layernorm",
    encoder_only=True, causal=False, modality="audio")

# [hf:microsoft/Phi-3-vision-128k-instruct] 32L d=3072 32H kv=32 ff=8192
# v=32064, phi3-mini backbone + CLIP stub
PHI3_VISION_4_2B = ModelConfig(
    name="phi-3-vision-4.2b", n_layers=32, d_model=3072, n_heads=32,
    n_kv_heads=32, head_dim=96, d_ff=8192, vocab_size=32064,
    pattern=("attn",), ffn="glu", act="silu", norm="rmsnorm",
    rope_theta=1e4, modality="vlm", n_img_tokens=256)


ARCHS: dict[str, ModelConfig] = {c.name: c for c in [
    GLM4_9B, STARCODER2_7B, GEMMA3_27B, QWEN2_5_3B, DEEPSEEK_V2_236B,
    GRANITE_MOE_3B, RECURRENTGEMMA_2B, MAMBA2_780M, HUBERT_XLARGE,
    PHI3_VISION_4_2B,
]}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig, n_layers: int | None = None) -> ModelConfig:
    """Smoke-test variant: same family/pattern, tiny dims, fp32."""
    plen = len(cfg.pattern)
    layers = n_layers or max(2 * plen, 2 + cfg.first_dense_layers)
    heads = 4 if cfg.n_heads else 0
    kv = min(cfg.n_kv_heads, heads) if cfg.n_kv_heads else 0
    if kv and heads % kv:
        kv = 1
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=128,
        sliding_window=min(cfg.sliding_window, 16) or 16,
        n_experts=8 if cfg.n_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2),
        moe_d_ff=32 if cfg.moe_d_ff else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=16 if cfg.qk_nope_head_dim else 0,
        qk_rope_head_dim=8 if cfg.qk_rope_head_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        lru_width=64 if cfg.lru_width else 0,
        n_img_tokens=8 if cfg.n_img_tokens else 0,
        attn_chunk=64,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
