"""Assigned architecture config (see registry.py for the sourced spec)."""
from repro.configs.registry import STARCODER2_7B as CONFIG, reduced

SMOKE = reduced(CONFIG)
