"""Assigned architecture config (see registry.py for the sourced spec)."""
from repro.configs.registry import DEEPSEEK_V2_236B as CONFIG, reduced

SMOKE = reduced(CONFIG)
