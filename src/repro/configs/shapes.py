"""Assigned input shapes and the (arch x shape) cell matrix."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: Archs whose attention is sub-quadratic / hybrid (long_500k runs).
LONG_CONTEXT_OK = {"gemma3-27b", "recurrentgemma-2b", "mamba2-780m"}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """Why a cell is skipped, or None if it runs (DESIGN.md §Arch-applic.)."""
    if cfg.encoder_only and shape.kind == "decode":
        return "encoder-only arch has no decode step"
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return ("pure full-attention arch: 500k context is out of scope "
                "(needs sub-quadratic attention)")
    return None


def cells(cfg: ModelConfig) -> list[tuple[ShapeSpec, str | None]]:
    return [(s, skip_reason(cfg, s)) for s in SHAPES.values()]
