"""Assigned architecture config (see registry.py for the sourced spec)."""
from repro.configs.registry import PHI3_VISION_4_2B as CONFIG, reduced

SMOKE = reduced(CONFIG)
