"""Architecture configs, shapes, and the simulator hardware config."""
from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS, get, reduced
from repro.configs.shapes import SHAPES, ShapeSpec, cells, skip_reason

__all__ = ["ModelConfig", "ARCHS", "get", "reduced", "SHAPES", "ShapeSpec",
           "cells", "skip_reason"]
