"""Assigned architecture config (see registry.py for the sourced spec)."""
from repro.configs.registry import MAMBA2_780M as CONFIG, reduced

SMOKE = reduced(CONFIG)
