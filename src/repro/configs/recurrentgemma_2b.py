"""Assigned architecture config (see registry.py for the sourced spec)."""
from repro.configs.registry import RECURRENTGEMMA_2B as CONFIG, reduced

SMOKE = reduced(CONFIG)
