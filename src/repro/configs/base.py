"""Model configuration shared by all assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Block pattern: mixer type per layer, cycled (gemma3 5:1, griffin 1:2).
    pattern: tuple[str, ...] = ("attn",)   # attn | local | mla | ssd | rglru
    ffn: str = "glu"                       # glu | mlp | moe | none
    act: str = "silu"
    norm: str = "rmsnorm"                  # rmsnorm | layernorm | gemma
    qkv_bias: bool = False
    mlp_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 1e4
    rope_theta_global: float | None = None  # gemma3: 1M on global layers
    sliding_window: int = 4096              # "local" mixers
    tie_embeddings: bool = False
    embed_scale: bool = False               # gemma: embeds * sqrt(d)
    encoder_only: bool = False
    causal: bool = True

    # MoE.
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # MLA (DeepSeek-V2).
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # SSD (Mamba-2).
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssm_chunk: int = 128

    # RG-LRU (Griffin / RecurrentGemma).
    lru_width: int = 0

    # Modality (frontend stubs per the brief).
    modality: str = "text"                 # text | audio | vlm
    n_img_tokens: int = 0                  # vlm: fixed image-prefix length

    # Numerics / implementation.
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_impl: str = "chunked"             # chunked (flash-like) | naive
    attn_chunk: int = 1024
    use_pallas: bool = False               # route hot paths to Pallas kernels
    remat: bool = True
    scan_layers: bool = True
    # Distribution strategy knobs (§Perf hillclimb levers).
    sharding_mode: str = "tp"              # tp (Megatron) | fsdp (pure DP)
    use_cp_decode: bool = False            # shard_map context-parallel decode

    # ----- derived -----------------------------------------------------
    @property
    def n_rep(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def mixer_at(self, layer: int) -> str:
        return self.pattern[layer % len(self.pattern)]

    def ffn_at(self, layer: int) -> str:
        if self.ffn == "moe" and layer < self.first_dense_layers:
            return "glu"
        return self.ffn

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def rnn_width(self) -> int:
        return self.lru_width or self.d_model

    # ----- analytic param counts (roofline MODEL_FLOPS) ----------------
    def _mixer_params(self, kind: str) -> int:
        d = self.d_model
        if kind in ("attn", "local"):
            q = d * self.n_heads * self.head_dim
            kv = 2 * d * self.n_kv_heads * self.head_dim
            o = self.n_heads * self.head_dim * d
            return q + kv + o
        if kind == "mla":
            qa = d * self.q_lora_rank
            qb = self.q_lora_rank * self.n_heads * (
                self.qk_nope_head_dim + self.qk_rope_head_dim)
            kva = d * (self.kv_lora_rank + self.qk_rope_head_dim)
            kvb = self.kv_lora_rank * self.n_heads * (
                self.qk_nope_head_dim + self.v_head_dim)
            o = self.n_heads * self.v_head_dim * d
            return qa + qb + kva + kvb + o
        if kind == "ssd":
            di, g, n, h = (self.d_inner, self.ssm_ngroups, self.ssm_state,
                           self.ssm_nheads)
            in_p = d * (2 * di + 2 * g * n + h)
            conv = self.conv_kernel * (di + 2 * g * n)
            out = di * d
            return in_p + conv + out + 2 * h
        if kind == "rglru":
            w = self.rnn_width
            return 2 * d * w + self.conv_kernel * w + 2 * w * w + w * d
        raise ValueError(kind)

    def _ffn_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "none":
            return 0
        if kind == "mlp":
            return 2 * d * self.d_ff
        if kind == "glu":
            return 3 * d * self.d_ff
        if kind == "moe":
            expert = 3 * d * self.moe_d_ff
            shared = self.n_shared_experts * expert
            return self.n_experts * expert + shared + d * self.n_experts
        raise ValueError(kind)

    def param_count(self) -> int:
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings and not self.encoder_only:
            total += self.vocab_size * self.d_model
        for i in range(self.n_layers):
            total += self._mixer_params(self.mixer_at(i))
            total += self._ffn_params(self.ffn_at(i))
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings and not self.encoder_only:
            total += self.vocab_size * self.d_model
        for i in range(self.n_layers):
            total += self._mixer_params(self.mixer_at(i))
            kind = self.ffn_at(i)
            if kind == "moe":
                expert = 3 * self.d_model * self.moe_d_ff
                total += (self.moe_top_k + self.n_shared_experts) * expert
                total += self.d_model * self.n_experts
            else:
                total += self._ffn_params(kind)
        return total
