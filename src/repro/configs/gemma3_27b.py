"""Assigned architecture config (see registry.py for the sourced spec)."""
from repro.configs.registry import GEMMA3_27B as CONFIG, reduced

SMOKE = reduced(CONFIG)
