"""Assigned architecture config (see registry.py for the sourced spec)."""
from repro.configs.registry import GLM4_9B as CONFIG, reduced

SMOKE = reduced(CONFIG)
