"""Hand-optimized-assembly style instruction traces for the paper's kernels.

Each generator emits the strip-mined vector instruction stream a hand-tuned
RVV kernel would execute on Ara (paper §VI.A: scal, axpy, dotp, gemv, symv,
ger, gemm, trsm, syrk, spmv, dwt), with the register-reuse patterns that give
rise to the WAR/WAW hazards and memory-stream structure the paper attributes
bottlenecks to.

Register convention: LMUL=8 for 1-D streaming kernels (register groups v0,
v8, v16, v24 — no rotation possible, so strip loops reuse registers and carry
WAR hazards, as in Ara's hand-optimized kernels); LMUL=1..2 for matrix
kernels (accumulator-rich).

Default problem sizes follow Fig. 3: N=1024 for 1-D kernels, 32x128 gemv,
32x32 symv/trsm/syrk/spmv, 128x128 ger and gemm.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from repro.core.isa import (KernelTrace, MachineConfig, OpKind, Stride,
                            VInstr, strips, vlmax_for)

Trace = KernelTrace

# Integer codes for the struct-of-arrays trace form (core/batch_sim.py).
KIND_CODE = {OpKind.LOAD: 0, OpKind.STORE: 1, OpKind.COMPUTE: 2,
             OpKind.REDUCE: 3, OpKind.SLIDE: 4}
STRIDE_CODE = {Stride.UNIT: 0, Stride.STRIDED: 1, Stride.INDEXED: 2}
PAD = -1                               # padding value for kind/dst/srcs


@dataclasses.dataclass(frozen=True)
class StackedTraces:
    """`B` kernel traces padded to `(B, max_instrs)` struct-of-arrays form.

    Register names are interned per trace into dense indices so hazard
    state (last writer / reader release) becomes a `(batch, R)` array in
    the batched simulator instead of a per-name dict.  Padding cells have
    ``kind == PAD`` and must never touch simulator state.
    """
    names: tuple[str, ...]             # (B,) kernel names
    n_instrs: np.ndarray               # (B,) int32 valid prefix length
    kind: np.ndarray                   # (B, I) int8, KIND_CODE or PAD
    vl: np.ndarray                     # (B, I) int32
    sew: np.ndarray                    # (B, I) int32
    nbytes: np.ndarray                 # (B, I) int64 (memory ops else 0)
    stride: np.ndarray                 # (B, I) int8, STRIDE_CODE
    first_strip: np.ndarray            # (B, I) bool
    is_div: np.ndarray                 # (B, I) bool (non-pipelined divide)
    red_levels: np.ndarray             # (B, I) int32 ceil(log2(max(vl,2)))
    dst: np.ndarray                    # (B, I) int16 register index or PAD
    srcs: np.ndarray                   # (B, I, S) int16 register idx or PAD
    n_regs: np.ndarray                 # (B,) int32 distinct registers
    total_flops: np.ndarray            # (B,) int64
    total_bytes: np.ndarray            # (B,) int64

    @property
    def batch(self) -> int:
        return len(self.names)

    @property
    def max_instrs(self) -> int:
        return self.kind.shape[1]

    @property
    def max_srcs(self) -> int:
        return self.srcs.shape[2]

    @property
    def max_regs(self) -> int:
        return int(self.n_regs.max()) if len(self.n_regs) else 0

    def subset(self, rows: Sequence[int], max_instrs: int | None = None
               ) -> "StackedTraces":
        """Select trace rows and (optionally) truncate the instruction
        axis to `max_instrs` — the padded columns beyond every selected
        trace's valid prefix are pure `PAD` and carry no state, so a
        shorter instruction axis is semantically identical.  This is how
        `repro.core.bucketing` builds per-bucket stacks without
        re-stacking from the original `KernelTrace` objects.

        The source axis (`max_srcs`) is kept as-is: it is tiny, and a
        shared width lets every bucket reuse one compiled program family.
        """
        idx = np.asarray(list(rows), np.intp)
        cap = self.max_instrs if max_instrs is None else int(max_instrs)
        if len(idx) and cap < int(self.n_instrs[idx].max()):
            raise ValueError(
                f"max_instrs={cap} would truncate valid instructions "
                f"(longest selected trace: {int(self.n_instrs[idx].max())})")
        c = np.ascontiguousarray
        return StackedTraces(
            names=tuple(self.names[i] for i in idx),
            n_instrs=c(self.n_instrs[idx]),
            kind=c(self.kind[idx, :cap]), vl=c(self.vl[idx, :cap]),
            sew=c(self.sew[idx, :cap]), nbytes=c(self.nbytes[idx, :cap]),
            stride=c(self.stride[idx, :cap]),
            first_strip=c(self.first_strip[idx, :cap]),
            is_div=c(self.is_div[idx, :cap]),
            red_levels=c(self.red_levels[idx, :cap]),
            dst=c(self.dst[idx, :cap]), srcs=c(self.srcs[idx, :cap]),
            n_regs=c(self.n_regs[idx]),
            total_flops=c(self.total_flops[idx]),
            total_bytes=c(self.total_bytes[idx]))


def stack_traces(traces: Sequence[KernelTrace]) -> StackedTraces:
    """Pad/stack kernel traces into the batched struct-of-arrays form."""
    B = len(traces)
    I = max((len(t.instrs) for t in traces), default=0)
    S = max((len(i.srcs) for t in traces for i in t.instrs), default=1)
    S = max(S, 1)

    n_instrs = np.zeros(B, np.int32)
    kind = np.full((B, I), PAD, np.int8)
    vl = np.zeros((B, I), np.int32)
    sew = np.zeros((B, I), np.int32)
    nbytes = np.zeros((B, I), np.int64)
    stride = np.zeros((B, I), np.int8)
    first_strip = np.zeros((B, I), bool)
    is_div = np.zeros((B, I), bool)
    red_levels = np.zeros((B, I), np.int32)
    dst = np.full((B, I), PAD, np.int16)
    srcs = np.full((B, I, S), PAD, np.int16)
    n_regs = np.zeros(B, np.int32)
    total_flops = np.zeros(B, np.int64)
    total_bytes = np.zeros(B, np.int64)

    for b, tr in enumerate(traces):
        regs: dict[str, int] = {}

        def idx(name: str) -> int:
            return regs.setdefault(name, len(regs))

        n_instrs[b] = len(tr.instrs)
        total_flops[b] = tr.total_flops
        total_bytes[b] = tr.total_bytes
        for i, ins in enumerate(tr.instrs):
            kind[b, i] = KIND_CODE[ins.kind]
            vl[b, i] = ins.vl
            sew[b, i] = ins.sew
            nbytes[b, i] = ins.bytes
            stride[b, i] = STRIDE_CODE[ins.stride]
            first_strip[b, i] = ins.first_strip
            is_div[b, i] = ins.name.startswith("vfdiv")
            if ins.kind is OpKind.REDUCE:
                red_levels[b, i] = math.ceil(math.log2(max(ins.vl, 2)))
            if ins.dst is not None:
                dst[b, i] = idx(ins.dst)
            for s, name in enumerate(ins.srcs):
                srcs[b, i, s] = idx(name)
        n_regs[b] = len(regs)

    return StackedTraces(names=tuple(t.name for t in traces),
                         n_instrs=n_instrs, kind=kind, vl=vl, sew=sew,
                         nbytes=nbytes, stride=stride,
                         first_strip=first_strip, is_div=is_div,
                         red_levels=red_levels, dst=dst, srcs=srcs,
                         n_regs=n_regs, total_flops=total_flops,
                         total_bytes=total_bytes)


def _mk(name, kind, vl, *, dst=None, srcs=(), stride=Stride.UNIT, fpe=0,
        stream="", first=False, sew=4):
    return VInstr(name=name, kind=kind, vl=vl, sew=sew, dst=dst,
                  srcs=tuple(srcs), stride=stride, flops=fpe * vl,
                  stream=stream, first_strip=first)


# ---------------------------------------------------------------------------
# 1-D streaming kernels (LMUL=8)
# ---------------------------------------------------------------------------

def scal(n: int = 1024, mc: MachineConfig = MachineConfig()) -> Trace:
    """x = a*x, in place: vle v0 ; vfmul v0,v0,fa ; vse v0.

    At LMUL=8 the whole loop lives in one register group, so every strip
    carries WAR (next vle vs. this vse's read) and WAW (vle vs. vfmul)
    hazards — the paper's strongest dependence-release showcase
    (Table I: scal C = 1.36)."""
    vlmax = vlmax_for(4, mc.vlen_bits, 8)
    ins = []
    for i, vl in enumerate(strips(n, vlmax)):
        ins.append(_mk("vle32", OpKind.LOAD, vl, dst="v0", stream="x",
                       first=(i == 0)))
        ins.append(_mk("vfmul", OpKind.COMPUTE, vl, dst="v0", srcs=["v0"],
                       fpe=1))
        ins.append(_mk("vse32", OpKind.STORE, vl, srcs=["v0"], stream="xo"))
    return Trace("scal", tuple(ins), total_flops=n, total_bytes=8 * n,
                 problem=f"N={n}")


def axpy(n: int = 1024, mc: MachineConfig = MachineConfig()) -> Trace:
    """y = a*x + y with double-buffered x/y register pairs (the four LMUL=8
    groups allow 2-strip rotation, so WAR hazards mostly decouple and the
    remaining baseline loss is memory-side — Table I: axpy C = 1.05,
    M = 1.22)."""
    vlmax = vlmax_for(4, mc.vlen_bits, 8)
    ins = []
    for i, vl in enumerate(strips(n, vlmax)):
        vx = "v0" if i % 2 == 0 else "v16"
        vy = "v8" if i % 2 == 0 else "v24"
        ins.append(_mk("vle32", OpKind.LOAD, vl, dst=vx, stream="x",
                       first=(i == 0)))
        ins.append(_mk("vle32", OpKind.LOAD, vl, dst=vy, stream="y",
                       first=(i == 0)))
        ins.append(_mk("vfmacc", OpKind.COMPUTE, vl, dst=vy,
                       srcs=[vx, vy], fpe=2))
        ins.append(_mk("vse32", OpKind.STORE, vl, srcs=[vy], stream="yo"))
    return Trace("axpy", tuple(ins), total_flops=2 * n, total_bytes=12 * n,
                 problem=f"N={n}")


def dotp(n: int = 1024, mc: MachineConfig = MachineConfig()) -> Trace:
    """s = x.y : per strip vle,vle,vfmacc into v16 accumulator; final
    vfredsum.  The accumulator RAW chain + final reduction serialize the
    tail (paper: dotp gains are limited by accumulation dependences)."""
    vlmax = vlmax_for(4, mc.vlen_bits, 8)
    ins = []
    for i, vl in enumerate(strips(n, vlmax)):
        vx = "v0" if i % 2 == 0 else "v24"
        ins.append(_mk("vle32", OpKind.LOAD, vl, dst=vx, stream="x",
                       first=(i == 0)))
        ins.append(_mk("vle32", OpKind.LOAD, vl, dst="v8", stream="y",
                       first=(i == 0)))
        ins.append(_mk("vfmacc", OpKind.COMPUTE, vl, dst="v16",
                       srcs=[vx, "v8", "v16"], fpe=2))
    ins.append(_mk("vfredsum", OpKind.REDUCE, min(n, vlmax), dst="f0",
                   srcs=["v16"], fpe=1))
    return Trace("dotp", tuple(ins), total_flops=2 * n, total_bytes=8 * n,
                 problem=f"N={n}")


# ---------------------------------------------------------------------------
# BLAS-2 kernels
# ---------------------------------------------------------------------------

def gemv(m: int = 32, n: int = 128, mc: MachineConfig = MachineConfig()) -> Trace:
    """y = A x (m rows of length n): per row, strip dot-product + reduce.
    x is loaded once (kept in v24 across rows when it fits)."""
    vlmax = vlmax_for(4, mc.vlen_bits, 4)
    ins = []
    x_fits = n <= vlmax
    if x_fits:
        ins.append(_mk("vle32", OpKind.LOAD, n, dst="v24", stream="x",
                       first=True))
    for r in range(m):
        va = "v0" if r % 2 == 0 else "v8"
        vacc = "v16" if r % 2 == 0 else "v20"
        for i, vl in enumerate(strips(n, vlmax)):
            ins.append(_mk("vle32", OpKind.LOAD, vl, dst=va,
                           stream="A", first=(r == 0 and i == 0)))
            if not x_fits:
                ins.append(_mk("vle32", OpKind.LOAD, vl, dst="v12",
                               stream="x", first=(r == 0 and i == 0)))
            ins.append(_mk("vfmul" if i == 0 else "vfmacc", OpKind.COMPUTE,
                           vl, dst=vacc,
                           srcs=[va, "v24" if x_fits else "v12"] +
                                ([] if i == 0 else [vacc]),
                           fpe=2))
        ins.append(_mk("vfredsum", OpKind.REDUCE, min(n, vlmax), dst="f0",
                       srcs=[vacc], fpe=1))
    flops = 2 * m * n
    bytes_ = 4 * (m * n + n + 2 * m)          # A + x + y read/write
    return Trace("gemv", tuple(ins), flops, bytes_, problem=f"{m}x{n}")


def symv(n: int = 32, mc: MachineConfig = MachineConfig()) -> Trace:
    """y = A x, A symmetric (n x n): row-wise dot products over full rows
    (small n => short vectors, reduction-dominated)."""
    vlmax = vlmax_for(4, mc.vlen_bits, 4)
    ins = []
    ins.append(_mk("vle32", OpKind.LOAD, n, dst="v24", stream="x",
                   first=True))
    for r in range(n):
        va = "v0" if r % 2 == 0 else "v8"
        vacc = "v16" if r % 2 == 0 else "v20"
        ins.append(_mk("vle32", OpKind.LOAD, n, dst=va, stream="A",
                       first=(r == 0)))
        ins.append(_mk("vfmul", OpKind.COMPUTE, n, dst=vacc,
                       srcs=[va, "v24"], fpe=2))
        ins.append(_mk("vfredsum", OpKind.REDUCE, n, dst="f0",
                       srcs=[vacc], fpe=1))
    flops = 2 * n * n
    bytes_ = 4 * (n * n + n + 2 * n)
    return Trace("symv", tuple(ins), flops, bytes_, problem=f"{n}x{n}")


def ger(m: int = 128, n: int = 128, mc: MachineConfig = MachineConfig()) -> Trace:
    """A += x y^T : y kept resident (v24); per row: vle A-row, vfmacc with
    scalar x_i, vse A-row.  Streaming row updates with register reuse —
    the 2-D analogue of axpy (paper: ger behaves like regular streaming)."""
    vlmax = vlmax_for(4, mc.vlen_bits, 4)
    ins = [_mk("vle32", OpKind.LOAD, min(n, vlmax), dst="v24", stream="y",
               first=True)]
    for r in range(m):
        va = "v0" if r % 2 == 0 else "v8"       # row double-buffering
        for i, vl in enumerate(strips(n, vlmax)):
            ins.append(_mk("vle32", OpKind.LOAD, vl, dst=va, stream="A",
                           first=(r == 0 and i == 0)))
            ins.append(_mk("vfmacc", OpKind.COMPUTE, vl, dst=va,
                           srcs=[va, "v24"], fpe=2))
            ins.append(_mk("vse32", OpKind.STORE, vl, srcs=[va],
                           stream="Ao"))
    flops = 2 * m * n
    bytes_ = 4 * (2 * m * n + m + n)
    return Trace("ger", tuple(ins), flops, bytes_, problem=f"{m}x{n}")


# ---------------------------------------------------------------------------
# BLAS-3 kernels
# ---------------------------------------------------------------------------

def gemm(m: int = 128, n: int = 128, k: int = 128,
         mc: MachineConfig = MachineConfig(), rows_per_block: int = 8) -> Trace:
    """C = A B with an outer-product register-blocked schedule: for each
    column strip (LMUL=2) and block of `rows_per_block` C rows kept in
    accumulators, stream B rows and issue one vfmacc per C row
    (scalar a[i,k] broadcast by the scalar core, free under the Ideal
    Dispatcher).  B-row loads are reused across the rows of a block."""
    lmul = 2
    vlmax = vlmax_for(4, mc.vlen_bits, lmul)
    ins = []
    nblocks = math.ceil(m / rows_per_block)
    for jstrip, vl in enumerate(strips(n, vlmax)):
        for ib in range(nblocks):
            rows = min(rows_per_block, m - ib * rows_per_block)
            for kk in range(k):
                vb = "v28" if kk % 2 == 0 else "v30"   # B double-buffer
                ins.append(_mk("vle32", OpKind.LOAD, vl, dst=vb,
                               stream="B",
                               first=(jstrip == 0 and ib == 0 and kk == 0)))
                for r in range(rows):
                    acc = f"v{2 * r}"
                    ins.append(_mk("vfmacc", OpKind.COMPUTE, vl, dst=acc,
                                   srcs=[vb, acc] if kk else [vb],
                                   fpe=2))
            for r in range(rows):
                ins.append(_mk("vse32", OpKind.STORE, vl,
                               srcs=[f"v{2 * r}"], stream="Co"))
    flops = 2 * m * n * k
    # Memory traffic of this schedule: B streamed once per row-block,
    # C stored once, A via scalar broadcasts (k*m scalar loads).
    bytes_ = 4 * (nblocks * k * n + m * n + m * k)
    return Trace("gemm", tuple(ins), flops, bytes_, problem=f"{m}x{n}x{k}")


def syrk(n: int = 32, k: int = 32, mc: MachineConfig = MachineConfig(),
         rows_per_block: int = 8) -> Trace:
    """C = A A^T (lower triangle): gemm-style register-blocked schedule —
    blocks of C rows accumulate while A^T rows stream once per block."""
    vlmax = vlmax_for(4, mc.vlen_bits, 2)
    vl = min(n, vlmax)
    ins = []
    nblocks = math.ceil(n / rows_per_block)
    for ib in range(nblocks):
        rows = min(rows_per_block, n - ib * rows_per_block)
        for kk in range(k):
            vb = "v28" if kk % 2 == 0 else "v30"
            ins.append(_mk("vle32", OpKind.LOAD, vl, dst=vb,
                           stream="A", first=(ib == 0 and kk == 0)))
            for r in range(rows):
                acc = f"v{2 * r}"
                ins.append(_mk("vfmacc", OpKind.COMPUTE, vl, dst=acc,
                               srcs=[vb, acc] if kk else [vb], fpe=2))
        for r in range(rows):
            ins.append(_mk("vse32", OpKind.STORE, vl, srcs=[f"v{2 * r}"],
                           stream="Co"))
    flops = n * (n + 1) * k                 # 2 flops * n(n+1)/2 * k
    bytes_ = 4 * (nblocks * k * n + n * n + n * k)
    return Trace("syrk", tuple(ins), flops, bytes_, problem=f"{n}x{k}")


def trsm(n: int = 32, mc: MachineConfig = MachineConfig()) -> Trace:
    """Triangular solve with n RHS columns: forward substitution; row r
    depends on all previous rows — the loop-carried RAW chain limits
    recoverable overlap (paper: trsm gains ~1.2x)."""
    vlmax = vlmax_for(4, mc.vlen_bits, 2)
    vl = min(n, vlmax)
    ins = []
    for r in range(n):
        vb = "v8" if r % 2 == 0 else "v16"
        ins.append(_mk("vle32", OpKind.LOAD, vl, dst=vb, stream="B",
                       first=(r == 0)))
        # x_r = (b_r - sum_{j<r} a_rj x_j) / a_rr : model the update as a
        # chain of vfnmsac against the running solution block + a divide.
        # The division is long-latency/non-pipelined on Ara's FPU, which is
        # why trsm's recoverable overlap is small (paper: 1.20x).
        if r > 0:
            ins.append(_mk("vfnmsac", OpKind.COMPUTE, vl, dst=vb,
                           srcs=[vb, "v0"], fpe=2))
        ins.append(_mk("vfdiv", OpKind.COMPUTE, vl, dst="v0",
                       srcs=[vb], fpe=1))
        ins.append(_mk("vse32", OpKind.STORE, vl, srcs=["v0"], stream="Xo"))
    flops = n * n * 2
    bytes_ = 4 * (n * n // 2 + 2 * n * n // max(n, 1) * n)
    return Trace("trsm", tuple(ins), flops, max(bytes_, 4 * 3 * n * n // 2),
                 problem=f"{n}x{n}")


# ---------------------------------------------------------------------------
# Irregular / complex access kernels
# ---------------------------------------------------------------------------

def spmv(n: int = 32, density: float = 0.3,
         mc: MachineConfig = MachineConfig()) -> Trace:
    """CSR SpMV: per row, indexed gather of x, vfmacc, reduce.  Indexed
    accesses defeat next-VL prefetch (paper: spmv speedup ~1.2x from
    decoupling only)."""
    nnz_row = max(1, int(n * density))
    ins = []
    for r in range(n):
        e = r % 2 == 0
        vv, vi, vg, vacc = (("v8", "v12", "v0", "v16") if e else
                            ("v10", "v14", "v4", "v20"))
        ins.append(_mk("vle32", OpKind.LOAD, nnz_row, dst=vv,
                       stream="val", first=(r == 0)))
        ins.append(_mk("vle32", OpKind.LOAD, nnz_row, dst=vi,
                       stream="idx", first=(r == 0)))
        ins.append(_mk("vluxei32", OpKind.LOAD, nnz_row, dst=vg,
                       srcs=[vi], stride=Stride.INDEXED, stream="xg",
                       first=(r == 0)))
        ins.append(_mk("vfmul", OpKind.COMPUTE, nnz_row, dst=vacc,
                       srcs=[vg, vv], fpe=2))
        ins.append(_mk("vfredsum", OpKind.REDUCE, nnz_row, dst="f0",
                       srcs=[vacc], fpe=1))
    nnz = n * nnz_row
    flops = 2 * nnz
    bytes_ = 4 * (3 * nnz + 2 * n)
    return Trace("spmv", tuple(ins), flops, bytes_,
                 problem=f"{n}x{n},d={density}")


def dwt(n: int = 1024, mc: MachineConfig = MachineConfig()) -> Trace:
    """1-D Haar-style discrete wavelet transform: per level, strided loads
    of even/odd samples, butterfly compute, two stores; halving sizes give
    a mix of long and short vectors plus slide traffic."""
    vlmax = vlmax_for(4, mc.vlen_bits, 4)
    ins = []
    level = 0
    size = n
    while size >= 8:
        half = size // 2
        for i, vl in enumerate(strips(half, vlmax)):
            first = (i == 0)
            e = i % 2 == 0
            v0, v8, v16, v24 = (("v0", "v8", "v16", "v24") if e else
                                ("v4", "v12", "v20", "v28"))
            ins.append(_mk("vlse32", OpKind.LOAD, vl, dst=v0,
                           stride=Stride.STRIDED, stream=f"e{level}",
                           first=first))
            ins.append(_mk("vlse32", OpKind.LOAD, vl, dst=v8,
                           stride=Stride.STRIDED, stream=f"o{level}",
                           first=first))
            ins.append(_mk("vfadd", OpKind.COMPUTE, vl, dst=v16,
                           srcs=[v0, v8], fpe=1))
            ins.append(_mk("vfsub", OpKind.COMPUTE, vl, dst=v24,
                           srcs=[v0, v8], fpe=1))
            ins.append(_mk("vfmul", OpKind.COMPUTE, vl, dst=v16,
                           srcs=[v16], fpe=1))
            ins.append(_mk("vfmul", OpKind.COMPUTE, vl, dst=v24,
                           srcs=[v24], fpe=1))
            ins.append(_mk("vse32", OpKind.STORE, vl, srcs=[v16],
                           stream=f"a{level}"))
            ins.append(_mk("vse32", OpKind.STORE, vl, srcs=[v24],
                           stream=f"d{level}"))
        size = half
        level += 1
    total = sum(i.flops for i in ins)
    bytes_ = sum(i.bytes for i in ins)
    return Trace("dwt", tuple(ins), total, bytes_, problem=f"N={n}")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

KERNELS: dict[str, Callable[..., Trace]] = {
    "scal": scal, "axpy": axpy, "dotp": dotp, "gemv": gemv, "symv": symv,
    "ger": ger, "gemm": gemm, "trsm": trsm, "syrk": syrk, "spmv": spmv,
    "dwt": dwt,
}

#: Fig. 3 default problem sizes.
DEFAULT_TRACES: dict[str, Callable[[], Trace]] = {
    "scal": lambda: scal(1024), "axpy": lambda: axpy(1024),
    "dotp": lambda: dotp(1024), "gemv": lambda: gemv(32, 128),
    "symv": lambda: symv(32), "ger": lambda: ger(128, 128),
    "gemm": lambda: gemm(128, 128, 128), "trsm": lambda: trsm(32),
    "syrk": lambda: syrk(32, 32), "spmv": lambda: spmv(32),
    "dwt": lambda: dwt(1024),
}
