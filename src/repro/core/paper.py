"""Published numbers from the paper, used for calibration and validation.

Sources: Fig. 3 (speedups, problem sizes), Fig. 4 (normalized performance /
gap-closed), Table I (2^3 ablation), §VI.C (lane utilization, VRF conflict),
Table II (PPA).
"""

# Fig. 3: Ara-Opt speedup over baseline Ara (All configuration).
FIG3_SPEEDUP = {
    "scal": 2.41, "axpy": 1.60, "ger": 1.52, "gemm": 1.42,
    "symv": 1.22, "syrk": 1.24, "dwt": 1.22, "trsm": 1.20, "spmv": 1.18,
    "dotp": 1.05, "gemv": 1.06,
}
FIG3_GEOMEAN = 1.33

# Fig. 4: normalized-to-roofline performance, baseline -> Ara-Opt.
FIG4_NORMALIZED = {
    "scal": (0.40, 0.96),
    "axpy": (0.60, 0.95),
    "ger": (0.60, 0.91),
    "gemm": (0.58, 0.83),
}
FIG4_GAP_CLOSED = {"scal": 0.937, "axpy": 0.889, "ger": 0.783, "gemm": 0.593}
FIG4_GEOMEAN_NORM = (0.30, 0.40)
FIG4_GEOMEAN_GAP_CLOSED = 0.122

# Table I: orthogonal ablation (speedup over baseline).
TABLE1 = {
    #         M     C     O     M+C   M+O   C+O   All
    "scal": (1.24, 1.36, 1.14, 2.09, 1.47, 1.52, 2.41),
    "axpy": (1.22, 1.05, 1.03, 1.59, 1.12, 1.11, 1.60),
    "ger":  (1.13, 1.05, 1.03, 1.45, 1.03, 1.11, 1.52),
    "gemm": (1.26, 1.05, 1.10, 1.41, 1.29, 1.12, 1.42),
    "gemv": (1.07, 1.00, 1.07, 1.01, 1.07, 1.07, 1.06),
    "dotp": (1.00, 1.04, 1.04, 1.02, 1.04, 1.06, 1.05),
}
TABLE1_CONFIGS = ("M", "C", "O", "M+C", "M+O", "C+O", "All")
TABLE1_GEOMEAN = (1.15, 1.09, 1.07, 1.38, 1.16, 1.16, 1.45)

# §VI.C lane utilization baseline -> opt.
LANE_UTILIZATION = {
    "scal": (0.100, 0.241), "axpy": (0.099, 0.159),
    "ger": (0.100, 0.152), "gemm": (0.580, 0.827),
}
GEMM_VRF_CONFLICT = (0.14, 0.05)

# Table II PPA.
TABLE2 = {
    "freq_ghz": 1.0,
    "perf_gflops": (9.32, 13.28),
    "area_mm2": (2.64, 2.78),
    "power_mw": (141.89, 214.05),
    "energy_eff": (65.68, 62.04),
    "area_eff": (3.53, 4.78),
}
