"""Calibrate the simulator's baseline-side parameters against the paper.

The RTL microarchitecture's exact timings are not published, so we fit a
small set of *physical* parameters (memory latency, per-burst overhead, bus
turnaround, issue gap, WAR release overhead, write-back/re-read delay, queue
depths) to the paper's measurements:

  targets:  Fig. 3 full-configuration speedups (11 kernels, weight 1.0),
            Fig. 4 baseline normalized performance (4 kernels, weight 1.5),
            Table I single-class ablation columns for scal/axpy/gemm/dotp
            (weight 0.5 — structural, keeps M/C/O attribution honest).

Search: seeded random search followed by coordinate refinement.  The result
is written to ``src/repro/configs/ara_calibrated.json`` and loaded by
``repro.configs.ara``.  Fidelity is reported in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
import random

from repro.core import paper
from repro.core.isa import OptConfig, geomean
from repro.core.roofline import normalized
from repro.core.simulator import AraSimulator, SimParams
from repro.core.traces import DEFAULT_TRACES

# Parameter search space: (name, lo, hi).  tx_ovh is bounded low because
# back-to-back unit-stride loads stream efficiently even in baseline Ara
# (Table I: dotp M = 1.00); the dominant baseline memory losses are
# store-coupled (r/w interference + latency re-exposure behind stores).
SPACE = [
    ("mem_latency", 24.0, 140.0),
    ("tx_ovh_base", 0.02, 0.6),
    ("rw_turnaround_base", 2.0, 30.0),
    ("store_commit_base", 0.0, 120.0),
    ("issue_gap_base", 1.0, 8.0),
    ("war_release_ovh", 2.0, 40.0),
    ("d_chain_base", 3.0, 30.0),
    ("queue_adv_base", 4.0, 64.0),
    ("queue_adv_opt", 64.0, 256.0),
    ("idx_ovh_base", 0.5, 4.0),
]

# Hand-derived seed (napkin math over scal/axpy periods; see EXPERIMENTS.md
# §Paper-repro): random search refines from here.
SEED_CANDIDATE = {
    "mem_latency": 70.0, "tx_ovh_base": 0.1, "rw_turnaround_base": 10.0,
    "store_commit_base": 30.0, "issue_gap_base": 3.0,
    "war_release_ovh": 15.0, "d_chain_base": 15.0, "queue_adv_base": 12.0,
    "queue_adv_opt": 160.0, "idx_ovh_base": 2.0,
}

ABL_KERNELS = ("scal", "axpy", "gemm", "dotp")
ABL_SINGLES = {"M": OptConfig(True, False, False),
               "C": OptConfig(False, True, False),
               "O": OptConfig(False, False, True),
               "M+C": OptConfig(True, True, False)}
CAL_PATH = pathlib.Path(__file__).resolve().parents[1] / "configs" / \
    "ara_calibrated.json"


def _traces():
    return {k: fn() for k, fn in DEFAULT_TRACES.items()}


def evaluate(params: SimParams, traces=None) -> dict:
    """Simulate everything the loss needs; returns a metrics dict."""
    traces = traces or _traces()
    sim = AraSimulator(params=params)
    out = {"speedup": {}, "norm_base": {}, "norm_opt": {}, "ablation": {}}
    base_cycles = {}
    for name, tr in traces.items():
        b = sim.run(tr, OptConfig.baseline())
        o = sim.run(tr, OptConfig.full())
        base_cycles[name] = b.cycles
        out["speedup"][name] = b.cycles / o.cycles
        oi = tr.operational_intensity
        out["norm_base"][name] = normalized(b.gflops, oi)
        out["norm_opt"][name] = normalized(o.gflops, oi)
    for name in ABL_KERNELS:
        tr = traces[name]
        row = {}
        for label, cfg in ABL_SINGLES.items():
            row[label] = base_cycles[name] / sim.run(tr, cfg).cycles
        out["ablation"][name] = row
    out["geomean_speedup"] = geomean(list(out["speedup"].values()))
    out["geomean_norm_base"] = geomean(list(out["norm_base"].values()))
    out["geomean_norm_opt"] = geomean(list(out["norm_opt"].values()))
    return out


def loss(metrics: dict) -> float:
    err = 0.0
    for k, tgt in paper.FIG3_SPEEDUP.items():
        err += (math.log(metrics["speedup"][k] / tgt)) ** 2
    for k, (nb, no) in paper.FIG4_NORMALIZED.items():
        err += 1.5 * (metrics["norm_base"][k] - nb) ** 2
        err += 0.75 * (metrics["norm_opt"][k] - no) ** 2
    cols = dict(zip(paper.TABLE1_CONFIGS, range(7)))
    for k in ABL_KERNELS:
        for label in ("M", "C", "O", "M+C"):
            tgt = paper.TABLE1[k][cols[label]]
            err += 0.5 * (math.log(metrics["ablation"][k][label] / tgt)) ** 2
    return err


def _loss_of(vals: dict, traces) -> float:
    return loss(evaluate(SimParams(**vals), traces))


def calibrate(iters: int = 400, seed: int = 0, refine_rounds: int = 3,
              verbose: bool = True) -> tuple[SimParams, float]:
    rng = random.Random(seed)
    traces = _traces()
    defaults = dataclasses.asdict(SimParams())

    def sample() -> dict:
        vals = dict(defaults)
        for name, lo, hi in SPACE:
            vals[name] = rng.uniform(lo, hi)
        vals["idx_ovh_opt"] = 0.9 * vals["idx_ovh_base"]
        return vals

    best_vals = dict(defaults, **SEED_CANDIDATE)
    best_vals["idx_ovh_opt"] = 0.9 * best_vals["idx_ovh_base"]
    best = _loss_of(best_vals, traces)
    if verbose:
        print(f"[seed] loss={best:.4f}")
    for i in range(iters):
        vals = sample()
        l = _loss_of(vals, traces)
        if l < best:
            best, best_vals = l, vals
            if verbose:
                print(f"[{i:4d}] loss={best:.4f}")
    # Coordinate refinement.
    for _ in range(refine_rounds):
        for name, lo, hi in SPACE:
            cur = best_vals[name]
            for f in (0.5, 0.75, 0.9, 1.1, 1.33, 2.0):
                cand = dict(best_vals)
                cand[name] = min(hi, max(lo, cur * f))
                if name == "idx_ovh_base":
                    cand["idx_ovh_opt"] = 0.9 * cand[name]
                l = _loss_of(cand, traces)
                if l < best:
                    best, best_vals = l, cand
        if verbose:
            print(f"[refine] loss={best:.4f}")
    return SimParams(**best_vals), best


def save(params: SimParams, loss_value: float,
         path: pathlib.Path = CAL_PATH) -> None:
    payload = {"params": dataclasses.asdict(params), "loss": loss_value}
    path.write_text(json.dumps(payload, indent=2))


def load(path: pathlib.Path = CAL_PATH) -> SimParams:
    if path.exists():
        payload = json.loads(path.read_text())
        return SimParams(**payload["params"])
    return SimParams()


def main() -> None:  # pragma: no cover - CLI
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    params, best = calibrate(iters=args.iters, seed=args.seed)
    save(params, best)
    metrics = evaluate(params)
    print(json.dumps({"loss": best,
                      "speedup": metrics["speedup"],
                      "geomean": metrics["geomean_speedup"],
                      "norm_base": metrics["norm_base"]}, indent=2))
    print(f"saved -> {CAL_PATH}")


if __name__ == "__main__":
    main()
