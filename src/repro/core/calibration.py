"""Calibrate the simulator's baseline-side parameters against the paper.

The RTL microarchitecture's exact timings are not published, so we fit a
small set of *physical* parameters (memory latency, per-burst overhead, bus
turnaround, issue gap, WAR release overhead, write-back/re-read delay, queue
depths) to the paper's measurements:

  targets:  Fig. 3 full-configuration speedups (11 kernels, weight 1.0),
            Fig. 4 baseline normalized performance (4 kernels, weight 1.5),
            Table I single-class ablation columns for scal/axpy/gemm/dotp
            (weight 0.5 — structural, keeps M/C/O attribution honest).

Search: seeded random search followed by coordinate refinement.  Every
candidate population is scored by ONE batched evaluation of the
`(kernel x config x candidate)` grid through
`repro.core.batch_sim.BatchAraSimulator` — the simulator is never invoked
one scalar cell at a time.  The result is written to
``src/repro/configs/ara_calibrated.json`` and loaded by
``repro.configs.ara``.  Fidelity is reported in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
import random
from typing import Sequence

import numpy as np

from repro.core import api, paper
from repro.core.batch_sim import BatchAraSimulator
from repro.core.isa import OptConfig, geomean
from repro.core.roofline import normalized
from repro.core.simulator import SimParams
from repro.core.stalls import PATH_NAMES, STALL_CATEGORIES, path_sums
from repro.core.traces import DEFAULT_TRACES, stack_traces
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

# Parameter search space: (name, lo, hi).  tx_ovh is bounded low because
# back-to-back unit-stride loads stream efficiently even in baseline Ara
# (Table I: dotp M = 1.00); the dominant baseline memory losses are
# store-coupled (r/w interference + latency re-exposure behind stores).
SPACE = [
    ("mem_latency", 24.0, 140.0),
    ("tx_ovh_base", 0.02, 0.6),
    ("rw_turnaround_base", 2.0, 30.0),
    ("store_commit_base", 0.0, 120.0),
    ("issue_gap_base", 1.0, 8.0),
    ("war_release_ovh", 2.0, 40.0),
    ("d_chain_base", 3.0, 30.0),
    ("queue_adv_base", 4.0, 64.0),
    ("queue_adv_opt", 64.0, 256.0),
    ("idx_ovh_base", 0.5, 4.0),
]

# Hand-derived seed (napkin math over scal/axpy periods; see EXPERIMENTS.md
# §Paper-repro): random search refines from here.
SEED_CANDIDATE = {
    "mem_latency": 70.0, "tx_ovh_base": 0.1, "rw_turnaround_base": 10.0,
    "store_commit_base": 30.0, "issue_gap_base": 3.0,
    "war_release_ovh": 15.0, "d_chain_base": 15.0, "queue_adv_base": 12.0,
    "queue_adv_opt": 160.0, "idx_ovh_base": 2.0,
}

#: Allowed relative drift of a reproduced geomean speedup from the value
#: recorded in ara_calibrated.json — one constant for both arms of the
#: tripwire (tests/test_simulator_paper.py and examples/ara_paper_repro.py).
GEOMEAN_DRIFT_TOL = 0.05

ABL_KERNELS = ("scal", "axpy", "gemm", "dotp")
ABL_SINGLES = {"M": OptConfig(True, False, False),
               "C": OptConfig(False, True, False),
               "O": OptConfig(False, False, True),
               "M+C": OptConfig(True, True, False)}
CAL_PATH = pathlib.Path(__file__).resolve().parents[1] / "configs" / \
    "ara_calibrated.json"

# Config axis of the calibration grid: every column the loss reads.
_CONFIGS: tuple[OptConfig, ...] = (
    OptConfig.baseline(), OptConfig.full(), *ABL_SINGLES.values())
_ABL_COL = {label: 2 + i for i, label in enumerate(ABL_SINGLES)}


def _traces():
    return {k: fn() for k, fn in DEFAULT_TRACES.items()}


def grid_traces():
    """The calibration evaluation grid: the 11 paper kernels at their
    Fig. 3 problem sizes.  Public so the design-space searcher
    (`repro.launch.design_search`) can score candidate designs on
    exactly the grid the recorded ``geomean_speedup`` in
    `ara_calibrated.json` was measured on — the "scores >= Ara-Opt on
    the calibrated grid" acceptance gate compares like with like."""
    return _traces()


# One simulator for every scoring call: the jax backend caches its
# compiled program per instance, so sharing it lets the search's repeated
# same-shape populations reuse one compile instead of recompiling per
# batched evaluation.
_SIM = BatchAraSimulator()


def evaluate_many(params_list: Sequence[SimParams],
                  traces=None, backend: str = "numpy",
                  attribution: bool = False,
                  method: str = "scan",
                  assoc_chunk: int | None = None,
                  bucket: str = "auto",
                  shard: str = "auto") -> list[dict]:
    """Score many candidates with one batched `(kernel x config x
    candidate)` sweep; returns one metrics dict per candidate.

    `backend` selects the batched engine: ``numpy`` (bit-exact vs. the
    scalar simulator) or ``jax`` (one compiled `lax.scan` program; wins
    on accelerator hosts once the fixed-shape compile amortizes over the
    search's repeated same-shape populations).  With `attribution` the
    sweep also carries the stall decomposition (both backends) and each
    metrics dict gains per-kernel critical-path / category shares of
    baseline and full-opt cycles (``paths_base/full``,
    ``stalls_base/full``) for `attribution_loss`.  `method` picks the
    instruction-axis algorithm on the jax backend (``scan`` / ``assoc``,
    see `repro.core.api.simulate`).  `bucket` / `shard` are the
    execution-planner axes (shape bucketing and P-axis device sharding
    — wide candidate populations are exactly the sweeps that shard
    well); both default to the planner's measured-crossover ``auto``
    and never change results."""
    traces = traces or _traces()
    names = list(traces)
    params_list = list(params_list)
    obs_metrics.counter("calibration.populations").inc()
    obs_metrics.counter("calibration.candidates").inc(len(params_list))
    stacked = stack_traces([traces[k] for k in names])
    with obs_spans.span("calibration.evaluate",
                        candidates=len(params_list), backend=backend,
                        method=method):
        res = api.simulate(stacked, _CONFIGS, params_list,
                           backend=backend, method=method,
                           assoc_chunk=assoc_chunk,
                           attribution=attribution,
                           bucket=bucket, shard=shard, sim=_SIM)
    cycles = res.cycles                        # (kernel, config, candidate)
    gflops = res.gflops
    if attribution:
        denom = np.maximum(cycles[..., None], 1e-9)
        path_share = path_sums(res.stalls) / denom     # (K, C, ci, 3)
        cat_share = res.stalls / denom                 # (K, C, ci, 9)

    outs = []
    for ci in range(cycles.shape[2]):
        out = {"speedup": {}, "norm_base": {}, "norm_opt": {},
               "ablation": {}}
        for ki, name in enumerate(names):
            oi = traces[name].operational_intensity
            out["speedup"][name] = cycles[ki, 0, ci] / cycles[ki, 1, ci]
            out["norm_base"][name] = normalized(gflops[ki, 0, ci], oi)
            out["norm_opt"][name] = normalized(gflops[ki, 1, ci], oi)
        for name in ABL_KERNELS:
            ki = names.index(name)
            out["ablation"][name] = {
                label: cycles[ki, 0, ci] / cycles[ki, col, ci]
                for label, col in _ABL_COL.items()}
        out["geomean_speedup"] = geomean(list(out["speedup"].values()))
        out["geomean_norm_base"] = geomean(list(out["norm_base"].values()))
        out["geomean_norm_opt"] = geomean(list(out["norm_opt"].values()))
        if attribution:
            for col, tag in ((0, "base"), (1, "full")):
                out[f"paths_{tag}"] = {
                    name: dict(zip(PATH_NAMES,
                                   map(float, path_share[ki, col, ci])))
                    for ki, name in enumerate(names)}
                out[f"stalls_{tag}"] = {
                    name: dict(zip(STALL_CATEGORIES,
                                   map(float, cat_share[ki, col, ci])))
                    for ki, name in enumerate(names)}
        outs.append(out)
    return outs


def evaluate(params: SimParams, traces=None, backend: str = "numpy",
             attribution: bool = False, method: str = "scan") -> dict:
    """Simulate everything the loss needs; returns a metrics dict."""
    return evaluate_many([params], traces, backend=backend,
                         attribution=attribution, method=method)[0]


def loss(metrics: dict) -> float:
    err = 0.0
    for k, tgt in paper.FIG3_SPEEDUP.items():
        err += (math.log(metrics["speedup"][k] / tgt)) ** 2
    for k, (nb, no) in paper.FIG4_NORMALIZED.items():
        err += 1.5 * (metrics["norm_base"][k] - nb) ** 2
        err += 0.75 * (metrics["norm_opt"][k] - no) ** 2
    cols = dict(zip(paper.TABLE1_CONFIGS, range(7)))
    for k in ABL_KERNELS:
        for label in ("M", "C", "O", "M+C"):
            tgt = paper.TABLE1[k][cols[label]]
            err += 0.5 * (math.log(metrics["ablation"][k][label] / tgt)) ** 2
    return err


#: §VI.C anchor: gemm's VRF bank-conflict stretch is 14% at baseline and
#: 5% with the operand-delivery optimizations — as a share of a fully
#: lane-bound kernel's cycles that is stretch/(1+stretch).
_CONFLICT_SHARE = {"base": 0.14 / 1.14, "full": 0.05 / 1.05}


def attribution_loss(metrics: dict) -> float:
    """Score the stall *decomposition* against the paper's §IV / §VI.C
    narrative, not just end-to-end cycles.

    Terms (all on shares of cycles, so they compose with `loss`):
      * scal/axpy at baseline must lose primarily to memory-side supply
        (§IV.A) — squared hinge on any other path overtaking it;
      * gemm's bank-conflict share is anchored to the measured stretch
        (§VI.C: 14% baseline -> 5% full);
      * gemm at baseline must keep operand delivery among its stalls —
        squared hinge on the operand path falling below half the
        mem-supply path.

    Needs ``evaluate(..., attribution=True)`` metrics; combine as
    ``loss(m) + weight * attribution_loss(m)`` (see `calibrate`'s
    ``attribution_weight``).
    """
    err = 0.0
    pb = metrics["paths_base"]
    for k in ("scal", "axpy"):
        other = max(pb[k]["dep_issue"], pb[k]["operand"])
        err += max(0.0, other - pb[k]["mem_supply"]) ** 2
    for tag, target in _CONFLICT_SHARE.items():
        share = metrics[f"stalls_{tag}"]["gemm"]["opr_bank_conflict"]
        err += (share - target) ** 2
    err += max(0.0, 0.5 * pb["gemm"]["mem_supply"]
               - pb["gemm"]["operand"]) ** 2
    return err


def _losses_of(candidates: Sequence[dict], traces,
               backend: str = "numpy",
               attribution_weight: float = 0.0,
               method: str = "scan",
               assoc_chunk: int | None = None) -> list[float]:
    params = [SimParams(**vals) for vals in candidates]
    metrics = evaluate_many(params, traces, backend=backend,
                            attribution=attribution_weight > 0.0,
                            method=method, assoc_chunk=assoc_chunk)
    if attribution_weight > 0.0:
        return [loss(m) + attribution_weight * attribution_loss(m)
                for m in metrics]
    return [loss(m) for m in metrics]


#: Reduced problem sizes for the backend parity check: every kernel the
#: loss reads, but small instruction streams (the check guards numerical
#: agreement between backends, not paper fidelity, so it should be cheap).
_PARITY_SIZES = {
    "scal": (256,), "axpy": (256,), "dotp": (256,), "gemv": (16, 64),
    "symv": (16,), "ger": (32, 32), "gemm": (32, 32, 32), "trsm": (16,),
    "syrk": (16, 16), "spmv": (16,), "dwt": (256,),
}


def parity_traces():
    from repro.core.traces import KERNELS
    return {name: KERNELS[name](*args) for name, args in
            _PARITY_SIZES.items()}


def check_backend_parity(backend: str, traces=None,
                         tol: float = 1e-6,
                         attribution_weight: float = 0.0,
                         method: str = "scan") -> float:
    """Cross-check one candidate's loss between `backend` and numpy.

    Guards calibration against a silently-divergent accelerated backend;
    returns the absolute loss difference, raising if it exceeds `tol`.
    Defaults to reduced-size traces (`parity_traces`) so the guard stays
    cheap even on hosts where one backend is slow.  A non-zero
    `attribution_weight` routes the comparison through the attribution-
    carrying sweep, so the stall-decomposition tensors are parity-checked
    too.  `method` selects the jax instruction-axis algorithm under test
    (``scan`` or the max-plus ``assoc`` engine); the numpy reference side
    always runs the sequential scan."""
    traces = traces or parity_traces()
    vals = dict(dataclasses.asdict(SimParams()), **SEED_CANDIDATE)
    vals["idx_ovh_opt"] = 0.9 * vals["idx_ovh_base"]
    ref = _losses_of([vals], traces, backend="numpy",
                     attribution_weight=attribution_weight)[0]
    got = _losses_of([vals], traces, backend=backend,
                     attribution_weight=attribution_weight,
                     method=method)[0]
    diff = abs(got - ref)
    if not diff <= tol * max(abs(ref), 1.0):
        raise RuntimeError(
            f"backend {backend!r} (method {method!r}) disagrees with "
            f"numpy on the seed candidate loss: {got!r} vs {ref!r}")
    return diff


def calibrate(iters: int = 400, seed: int = 0, refine_rounds: int = 3,
              verbose: bool = True, chunk: int = 64,
              backend: str = "numpy",
              attribution_weight: float = 0.0,
              method: str = "scan",
              assoc_chunk: int | None = None) -> tuple[SimParams, float]:
    """Fit baseline parameters; `attribution_weight` > 0 adds
    ``attribution_weight * attribution_loss`` to every candidate's score
    (the sweep then carries stall tensors — supported on both backends,
    so ``--backend jax`` scores attribution-aware objectives in the same
    compiled scan).  ``method="assoc"`` (jax only) scores candidates with
    the log-depth max-plus engine; parity vs numpy is checked first."""
    rng = random.Random(seed)
    traces = _traces()
    if backend != "numpy" or method != "scan":
        diff = check_backend_parity(
            backend, attribution_weight=attribution_weight, method=method)
        if verbose:
            print(f"[parity] {backend}/{method} vs numpy "
                  f"seed-loss diff={diff:.2e}")
    defaults = dataclasses.asdict(SimParams())

    def population(k: int) -> list[dict]:
        # Latin-hypercube population seeding (the sensitivity
        # subsystem's stratified sampler): every batched evaluation
        # covers each knob's full range instead of clumping, which a
        # plain uniform draw does at small chunk sizes.
        from repro.launch.sensitivity import lhs_candidates
        outs = []
        for over in lhs_candidates(SPACE, k, rng):
            vals = dict(defaults, **over)
            vals["idx_ovh_opt"] = 0.9 * vals["idx_ovh_base"]
            outs.append(vals)
        return outs

    best_vals = dict(defaults, **SEED_CANDIDATE)
    best_vals["idx_ovh_opt"] = 0.9 * best_vals["idx_ovh_base"]
    best = _losses_of([best_vals], traces, backend,
                      attribution_weight, method, assoc_chunk)[0]
    if verbose:
        print(f"[seed] loss={best:.4f}")
    # Random search, `chunk` candidates per batched evaluation.
    done = 0
    while done < iters:
        cands = population(min(chunk, iters - done))
        for off, l in enumerate(_losses_of(cands, traces, backend,
                                           attribution_weight, method,
                                           assoc_chunk)):
            if l < best:
                best, best_vals = l, cands[off]
                if verbose:
                    print(f"[{done + off:4d}] loss={best:.4f}")
        done += len(cands)
    # Coordinate refinement: per parameter, all scale factors in one batch.
    for _ in range(refine_rounds):
        for name, lo, hi in SPACE:
            cur = best_vals[name]
            cands = []
            for f in (0.5, 0.75, 0.9, 1.1, 1.33, 2.0):
                cand = dict(best_vals)
                cand[name] = min(hi, max(lo, cur * f))
                if name == "idx_ovh_base":
                    cand["idx_ovh_opt"] = 0.9 * cand[name]
                cands.append(cand)
            for cand, l in zip(cands, _losses_of(cands, traces, backend,
                                                 attribution_weight,
                                                 method, assoc_chunk)):
                if l < best:
                    best, best_vals = l, cand
        if verbose:
            print(f"[refine] loss={best:.4f}")
    return SimParams(**best_vals), best


def save(params: SimParams, loss_value: float,
         path: pathlib.Path = CAL_PATH, metrics: dict | None = None) -> None:
    """Persist calibrated params + headline fidelity numbers.

    The recorded ``geomean_speedup`` is the drift sentinel
    `examples/ara_paper_repro.py` checks reproduced runs against;
    ``drift_tol`` records the tolerance the sentinel should apply, so a
    recalibration can tighten or relax the tripwire without a code
    change (consumers fall back to `GEOMEAN_DRIFT_TOL`).  A tolerance
    already present in the record survives recalibration."""
    if metrics is None:
        metrics = evaluate(params)
    prior_tol = load_payload(path).get("drift_tol", GEOMEAN_DRIFT_TOL)
    payload = {"params": dataclasses.asdict(params), "loss": loss_value,
               "geomean_speedup": metrics["geomean_speedup"],
               "drift_tol": prior_tol}
    path.write_text(json.dumps(payload, indent=2))


def load(path: pathlib.Path = CAL_PATH) -> SimParams:
    if path.exists():
        payload = json.loads(path.read_text())
        return SimParams(**payload["params"])
    return SimParams()


def load_payload(path: pathlib.Path = CAL_PATH) -> dict:
    """Full calibration record (params, loss, recorded geomean) or {}."""
    if path.exists():
        return json.loads(path.read_text())
    return {}


def main() -> None:  # pragma: no cover - CLI
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--refine", type=int, default=3,
                    help="coordinate-refinement rounds")
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="batched engine for candidate scoring (jax wins "
                         "on accelerator hosts; parity-checked vs numpy)")
    ap.add_argument("--method", choices=("scan", "assoc"), default="scan",
                    help="jax instruction-axis algorithm: sequential "
                         "lax.scan or the log-depth max-plus assoc engine "
                         "(parity-checked vs numpy before the search)")
    ap.add_argument("--assoc-chunk", type=int, default=None,
                    help="assoc instruction-chunk length; raise it (e.g. "
                         "512) to fit the full-size calibration grid "
                         "under the assoc memory guard")
    ap.add_argument("--attribution-weight", type=float, default=0.0,
                    help="weight of attribution_loss in candidate scores "
                         "(0 disables; the sweep then also carries the "
                         "stall decomposition on either backend)")
    args = ap.parse_args()
    params, best = calibrate(iters=args.iters, seed=args.seed,
                             chunk=args.chunk, refine_rounds=args.refine,
                             backend=args.backend, method=args.method,
                             assoc_chunk=args.assoc_chunk,
                             attribution_weight=args.attribution_weight)
    metrics = evaluate(params)
    save(params, best, metrics=metrics)
    print(json.dumps({"loss": best,
                      "speedup": metrics["speedup"],
                      "geomean": metrics["geomean_speedup"],
                      "norm_base": metrics["norm_base"]}, indent=2))
    print(f"saved -> {CAL_PATH}")


if __name__ == "__main__":
    main()
