"""Shape-bucketed grid execution: stop paying for the longest trace.

`repro.core.traces.stack_traces` pads every trace in a grid to the
longest one, and the jax backends scan the *padded* instruction axis —
masked no-op steps for every `PAD` row.  On a mixed grid (scal's ~10
instructions stacked with gemm's hundreds) the majority of all scan
steps are padding.  The numpy backend never pays this (its per-row
Python loop stops at `n_instrs[b]`), which is also why bucketing is
*structurally* bit-exact there: rows are independent, so any row
subset computes exactly the same numbers.

This module groups the trace rows of a `StackedTraces` into **shape
buckets** by padded instruction length (power-of-two bucket edges, so
at most `log2(I)` compiled programs exist per grid family and a bucket
never groups rows more than 2x apart; each bucket then pads only to
its own longest member), runs the batched engine once per bucket via
`StackedTraces.subset`, and scatters the per-bucket results back into
the caller's original row order.  The scatter covers every
`BatchResult` field — per-cell tensors, per-trace flops/bytes, the
attribution/phase observables — so callers cannot tell a bucketed run
from an unbucketed one except by wall-clock and the `bucket.*` metrics.

Bucketing also shrinks the assoc engine's basis: `D = 8 + 3R` is
computed from the *bucket's* `max_regs`, so a bucket without the
register-heavy kernels composes smaller transfer matrices.

The decision to bucket lives in `repro.core.api.resolve_plan`
(`bucket="auto"` weighs the measured pad-waste share against
`BUCKET_WASTE_CROSSOVER`); this module only executes the plan.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.batch_sim import BatchResult
from repro.core.traces import StackedTraces
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

#: Bucket-edge policies understood by `plan_buckets` (and the values the
#: `bucket=` plan axis can resolve to, besides "none").
POLICIES = ("pow2",)


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One shape bucket: which stacked rows run together, padded to cap."""
    rows: tuple[int, ...]              # row indices into the original stack
    cap: int                           # padded instruction length


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


def plan_buckets(stacked: StackedTraces, policy: str = "pow2"
                 ) -> list[Bucket]:
    """Group trace rows into shape buckets by padded instruction length.

    ``pow2`` groups by each trace's instruction count rounded up to the
    next power of two (clamped to the stack's own padded length), then
    pads each bucket only to its *longest member* — the edges bound how
    far apart grouped rows can be (2x), the member-max cap keeps the
    residual waste to the intra-bucket spread (measured 3% vs 15% for
    raw pow2 caps on the smoke grid).  A single-bucket plan therefore
    degenerates to the unbucketed shape exactly.  Buckets are returned
    shortest-cap first; row order within a bucket preserves the
    original stack order.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown bucket policy {policy!r} "
                         f"(known: {POLICIES})")
    by_edge: dict[int, list[int]] = {}
    I = stacked.max_instrs
    for b, n in enumerate(stacked.n_instrs):
        edge = min(_next_pow2(int(n)), I)
        by_edge.setdefault(edge, []).append(b)
    return [Bucket(rows=tuple(rows),
                   cap=int(max(stacked.n_instrs[r] for r in rows)))
            for _, rows in sorted(by_edge.items())]


def pad_waste_share(stacked: StackedTraces,
                    buckets: Sequence[Bucket] | None = None) -> float:
    """Share of scan steps spent on padding, in [0, 1).

    With `buckets=None` this is the *unbucketed* waste: the stack pays
    `B * max_instrs` scan steps for `sum(n_instrs)` real instructions.
    With a bucket plan, each bucket pays `len(rows) * cap` instead.
    """
    valid = int(stacked.n_instrs.sum())
    if buckets is None:
        steps = stacked.batch * stacked.max_instrs
    else:
        steps = sum(len(bk.rows) * bk.cap for bk in buckets)
    return 1.0 - valid / steps if steps else 0.0


def _scatter(stacked: StackedTraces, buckets: Sequence[Bucket],
             parts: Sequence[BatchResult]) -> BatchResult:
    """Reassemble per-bucket results into the original row order.

    Every ndarray field of `BatchResult` has the trace axis first, so
    one row-scatter per field covers per-cell tensors and per-trace
    vectors alike — a future field is scattered automatically, the same
    derivation trick `_per_cell_fields` uses for P-axis chunking.
    """
    out: dict[str, np.ndarray | None] = {}
    for f in dataclasses.fields(BatchResult):
        if f.name == "names":
            continue
        vals = [getattr(p, f.name) for p in parts]
        if vals[0] is None:
            out[f.name] = None
            continue
        arr = np.empty((stacked.batch,) + vals[0].shape[1:],
                       vals[0].dtype)
        for bk, v in zip(buckets, vals):
            arr[np.asarray(bk.rows, np.intp)] = v
        out[f.name] = arr
    return BatchResult(names=stacked.names, **out)


def run_bucketed(sim, stacked: StackedTraces, opts, params, *,
                 policy: str = "pow2", backend: str = "numpy",
                 method: str = "scan", attribution: bool = False,
                 p_chunk: int | None = None,
                 assoc_chunk: int | None = None,
                 use_pallas: bool = False,
                 shard: str = "none") -> BatchResult:
    """Execute the grid bucket-by-bucket through `sim._run` and scatter.

    `sim` is a `BatchAraSimulator`; each bucket reuses its compiled-fn
    caches (keyed on shape signatures, so two grids sharing bucket
    shapes share compiles).  Emits `bucket.*` metrics: how many buckets
    the plan formed and the pad-waste share before/after.
    """
    buckets = plan_buckets(stacked, policy)
    obs_metrics.counter("bucket.groups").inc(len(buckets))
    obs_metrics.gauge("bucket.baseline_waste_share").set(
        pad_waste_share(stacked))
    obs_metrics.gauge("bucket.pad_waste_share").set(
        pad_waste_share(stacked, buckets))
    parts = []
    for bk in buckets:
        sub = stacked
        if len(buckets) > 1 or bk.cap != stacked.max_instrs:
            sub = stacked.subset(bk.rows, bk.cap)
        with obs_spans.span("exec.bucket", rows=len(bk.rows),
                            cap=bk.cap):
            parts.append(sim._run(
                sub, opts, params, backend=backend,
                attribution=attribution, p_chunk=p_chunk, method=method,
                assoc_chunk=assoc_chunk, use_pallas=use_pallas,
                shard=shard))
    if len(parts) == 1 and parts[0].names == stacked.names:
        return parts[0]
    return _scatter(stacked, buckets, parts)
