"""Parameterized, seed-deterministic RVV trace generation.

Everything before this module ran the paper's 11 hand-written kernels
(`repro.core.traces`), so every claim — attribution shares, gap-closed
ratios, planner crossovers — was only ever tested on the workloads the
paper picked.  This module turns the trace axis into a *generator*: a
`GenSpec` names a workload class plus a handful of structural knobs
(stride/gather mixes, RAW-chain depth, accumulator pressure, slide
storms, mixed-VL segments, LMUL), and `generate(spec)` deterministically
expands it into a strip-mined `KernelTrace` that runs through the exact
same `api.simulate` grid as the paper kernels.

Determinism contract: `generate` draws randomness only from
`numpy.random.Generator.integers`/`.random` seeded by
``(class, seed, index)`` `SeedSequence` entropy — the same spec yields a
byte-identical serialized trace on every run and platform
(`tests/test_tracegen.py`; `tools/gen_corpus.py --check` enforces it on
the committed corpus in CI).

Classification: each trace is classified by arithmetic intensity against
the Ara roofline (`repro.core.roofline`), so per-class gap-closed
normalization stays well-defined — a "memory_bound" scenario's ideal is
the bandwidth roof, a "compute_bound" one's the FLOP roof
(docs/workloads.md has the taxonomy; the knob table there is CI-synced
against `GenSpec`'s fields).

The hypothesis strategies in `tests/trace_gen.py` are thin wrappers over
this module (the ``fuzz`` class absorbs the old independent
random-instruction builder), so property tests exercise the shipped
generator path.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

import numpy as np

from repro.core import roofline
from repro.core.isa import (KernelTrace, OpKind, Stride, VInstr, strips,
                            vlmax_for)

__all__ = [
    "GenSpec", "CLASSES", "CORPUS_CLASSES", "INTENSITY_CLASSES",
    "generate", "sample_spec", "intensity_class", "intensity_index",
    "classify", "retotaled", "spec_to_dict", "spec_from_dict",
    "trace_to_dict", "trace_from_dict", "trace_bytes",
]


@dataclasses.dataclass(frozen=True)
class GenSpec:
    """Knobs of one generated workload (docs/workloads.md knob table).

    ``cls`` picks the structural emitter; the remaining fields shape it.
    Class presets (`sample_spec`) draw each knob from a class-appropriate
    range, but any combination is legal — the generator only ever emits
    structurally-valid instruction streams.
    """
    cls: str = "streaming"       # workload class, one of CLASSES
    seed: int = 0                # RNG stream selector (byte-determinism key)
    n: int = 512                 # elements per memory stream
    sew: int = 4                 # element width in bytes
    lmul: int = 8                # register-group size (sets VLMAX)
    n_streams: int = 2           # distinct input memory streams
    compute_per_mem: int = 1     # independent compute chains per strip
    flops_per_elem: int = 2      # flops per element of each compute op
    stride_mix: tuple[float, float, float] = (1.0, 0.0, 0.0)
    #                            # unit/strided/indexed stream weights
    chain_depth: int = 1         # RAW-dependent ops per compute chain
    accum_regs: int = 2          # accumulator registers rotated across strips
    reduce_interval: int = 0     # vfredsum every k-th strip (0: never)
    slide_share: float = 0.0     # fraction of chain ops emitted as slides
    div_share: float = 0.0       # fraction of chain ops that are divides
    vl_jitter: float = 0.0       # per-strip VL shrink factor (mixed-VL)
    store_share: float = 1.0     # probability a strip stores its result
    max_instrs: int = 256        # hard cap on emitted instructions


#: Workload classes, in a stable order (`_CLASS_IDS` feeds the RNG seed).
CLASSES: tuple[str, ...] = (
    "streaming",        # unit-stride load/compute/store, low intensity
    "strided",          # strided even/odd-style streams (dwt-shaped)
    "gather",           # indexed gather/scatter mixes (spmv-shaped)
    "reduction",        # accumulate + vfredsum tails (dotp-shaped)
    "raw_chain",        # long serialized RAW chains on one register
    "queue_pressure",   # accumulator-rich chains stressing operand queues
    "slide_storm",      # vslide/permute-heavy traffic
    "mixed_vl",         # mixed-VL segments with LMUL variation
    "compute_tile",     # register-blocked FMA tiles (gemm-shaped)
    "fuzz",             # arbitrary-but-valid instruction soup
)

#: Classes the committed scenario corpus covers (all of them).
CORPUS_CLASSES: tuple[str, ...] = CLASSES

_CLASS_IDS = {name: i for i, name in enumerate(CLASSES)}

#: Arithmetic-intensity classes, ordered from memory- to compute-limited.
INTENSITY_CLASSES: tuple[str, ...] = ("memory_bound", "balanced",
                                      "compute_bound")

#: Band edges relative to the Ara ridge point (peak_flops / peak_bw):
#: below half the ridge the bandwidth roof binds decisively, above twice
#: the ridge the FLOP roof does; in between both terms matter.
_BAND_LO = 0.5
_BAND_HI = 2.0


def intensity_class(oi: float) -> str:
    """Arithmetic-intensity class of operational intensity ``oi``
    (flops/byte) against the Ara roofline ridge."""
    ridge = roofline.ARA_PEAK_GFLOPS / roofline.ARA_PEAK_BW
    if oi < _BAND_LO * ridge:
        return "memory_bound"
    if oi <= _BAND_HI * ridge:
        return "balanced"
    return "compute_bound"


def intensity_index(name: str) -> int:
    """Position of an intensity class on the memory->compute axis."""
    return INTENSITY_CLASSES.index(name)


def classify(trace: KernelTrace) -> str:
    """Intensity class of a trace's roofline accounting."""
    return intensity_class(trace.operational_intensity)


# ---------------------------------------------------------------------------
# Generation
# ---------------------------------------------------------------------------

_STRIDES = (Stride.UNIT, Stride.STRIDED, Stride.INDEXED)


def _rng_for(spec: GenSpec) -> np.random.Generator:
    # SeedSequence over (class, seed) gives independent, reproducible
    # streams; only Generator.integers/.random are used downstream (their
    # bit streams are stable across numpy versions).
    return np.random.default_rng([_CLASS_IDS[spec.cls], spec.seed])


def _pick_stride(mix: Sequence[float], u: float) -> Stride:
    """Weighted stride draw from a uniform sample (no Generator.choice —
    its internals are not bit-stream pinned)."""
    w = [max(float(x), 0.0) for x in mix]
    total = sum(w) or 1.0
    acc = 0.0
    for stride, wi in zip(_STRIDES, w):
        acc += wi / total
        if u < acc:
            return stride
    return _STRIDES[-1]


def _mem_name(kind: OpKind, stride: Stride) -> str:
    if kind is OpKind.LOAD:
        return {Stride.UNIT: "vle32", Stride.STRIDED: "vlse32",
                Stride.INDEXED: "vluxei32"}[stride]
    return {Stride.UNIT: "vse32", Stride.STRIDED: "vsse32",
            Stride.INDEXED: "vsuxei32"}[stride]


def _emit_fuzz(spec: GenSpec, rng: np.random.Generator) -> list[VInstr]:
    """Arbitrary-but-valid instruction soup: the deterministic successor
    of the old hypothesis tuple builder in tests/trace_gen.py, kept as a
    first-class workload class so property tests fuzz the shipped path."""
    pool = ("v0", "v4", "v8", "v12", "v16", "v20")
    kinds = (OpKind.LOAD, OpKind.STORE, OpKind.COMPUTE, OpKind.REDUCE,
             OpKind.SLIDE)
    count = max(3, min(spec.max_instrs,
                       3 + int(rng.integers(0, spec.max_instrs))))
    ins: list[VInstr] = []
    for _ in range(count):
        kind = kinds[int(rng.integers(0, len(kinds)))]
        vl = 1 + int(rng.integers(0, 300))
        dst = pool[int(rng.integers(0, len(pool)))]
        srcs = tuple(pool[int(rng.integers(0, len(pool)))]
                     for _ in range(int(rng.integers(0, 3))))
        stride = _STRIDES[int(rng.integers(0, 3))]
        mem = kind in (OpKind.LOAD, OpKind.STORE)
        if kind is OpKind.STORE and not srcs:
            srcs = (dst,)
        if kind is OpKind.LOAD:
            srcs = srcs[:1] if stride is Stride.INDEXED else ()
        isdiv = kind is OpKind.COMPUTE and rng.random() < 0.2
        name = "vfdiv" if isdiv else (
            _mem_name(kind, stride) if mem else
            {OpKind.COMPUTE: "vop", OpKind.REDUCE: "vfredsum",
             OpKind.SLIDE: "vslide"}[kind])
        ins.append(VInstr(
            name=name, kind=kind, vl=vl, sew=spec.sew,
            dst=None if kind is OpKind.STORE else dst, srcs=srcs,
            stride=stride if mem else Stride.UNIT,
            flops=vl if kind in (OpKind.COMPUTE, OpKind.REDUCE) else 0,
            stream="s", first_strip=bool(rng.random() < 0.3)))
    return ins


def _emit_structured(spec: GenSpec, rng: np.random.Generator
                     ) -> list[VInstr]:
    """One strip-mined loop nest shaped by the spec's knobs."""
    vlmax = max(1, vlmax_for(spec.sew, 1024, max(1, spec.lmul)))
    n_streams = max(1, spec.n_streams)
    n_chains = max(1, spec.compute_per_mem)
    chain_depth = max(1, spec.chain_depth)
    accum_regs = max(1, spec.accum_regs)

    # Bounded register pools: load buffers double-buffer per stream,
    # chain registers rotate (or serialize, for raw_chain), accumulators
    # persist across strips.  Small pools keep the interned register
    # count (and the assoc engine's D = 8 + 3R) bounded.
    load_regs = [f"v{8 * s}" for s in range(min(n_streams, 3))]
    load_alt = [f"v{8 * s + 4}" for s in range(min(n_streams, 3))]
    chain_regs = [f"vc{c}" for c in range(min(chain_depth, 4))]
    accums = [f"va{a}" for a in range(min(accum_regs, 4))]
    serialize = spec.cls == "raw_chain"

    # Per-stream stride is fixed for the stream's lifetime (prefetcher
    # state is per stream), drawn once from the mix.
    stream_strides = [_pick_stride(spec.stride_mix, rng.random())
                      for _ in range(n_streams)]
    idx_reg = "v28"                      # index vector for gathers

    ins: list[VInstr] = []
    strip_vls = list(strips(max(1, spec.n), vlmax))
    for t, base_vl in enumerate(strip_vls):
        if len(ins) >= spec.max_instrs:
            break
        vl = base_vl
        if spec.vl_jitter > 0.0:
            shrink = 1.0 - spec.vl_jitter * rng.random()
            vl = max(1, int(round(base_vl * shrink)))
        first = t == 0

        # Mixed-VL segments also vary the effective LMUL: halve the
        # strip on a coin flip so short and long vectors interleave.
        if spec.cls == "mixed_vl" and rng.random() < 0.5:
            vl = max(1, vl // 2)

        loaded: list[str] = []
        for s in range(n_streams):
            stride = stream_strides[s]
            dst = (load_regs[s % len(load_regs)] if t % 2 == 0
                   else load_alt[s % len(load_alt)])
            if stride is Stride.INDEXED:
                ins.append(VInstr(name="vle32", kind=OpKind.LOAD, vl=vl,
                                  sew=spec.sew, dst=idx_reg, srcs=(),
                                  stride=Stride.UNIT, flops=0,
                                  stream=f"idx{s}", first_strip=first))
                srcs: tuple[str, ...] = (idx_reg,)
            else:
                srcs = ()
            ins.append(VInstr(name=_mem_name(OpKind.LOAD, stride),
                              kind=OpKind.LOAD, vl=vl, sew=spec.sew,
                              dst=dst, srcs=srcs, stride=stride, flops=0,
                              stream=f"in{s}", first_strip=first))
            loaded.append(dst)

        last_dst = loaded[-1]
        for c in range(n_chains):
            acc = accums[(t * n_chains + c) % len(accums)]
            prev = loaded[c % len(loaded)]
            for d in range(chain_depth):
                u = rng.random()
                dst = (chain_regs[0] if serialize
                       else chain_regs[(c + d) % len(chain_regs)])
                if u < spec.slide_share:
                    ins.append(VInstr(name="vslideup", kind=OpKind.SLIDE,
                                      vl=vl, sew=spec.sew, dst=dst,
                                      srcs=(prev,), flops=0, stream="s"))
                else:
                    isdiv = u < spec.slide_share + spec.div_share
                    name = "vfdiv" if isdiv else "vfmacc"
                    srcs = (prev, acc) if d == chain_depth - 1 else (prev,)
                    ins.append(VInstr(name=name, kind=OpKind.COMPUTE,
                                      vl=vl, sew=spec.sew, dst=dst,
                                      srcs=srcs,
                                      flops=spec.flops_per_elem * vl,
                                      stream="s"))
                prev = dst
                if len(ins) >= spec.max_instrs:
                    break
            # Fold the chain into the accumulator (RAW on the rotating
            # accumulator: the dotp-style loop-carried dependence).
            ins.append(VInstr(name="vfmacc", kind=OpKind.COMPUTE, vl=vl,
                              sew=spec.sew, dst=acc, srcs=(prev, acc),
                              flops=spec.flops_per_elem * vl, stream="s"))
            last_dst = acc
            if len(ins) >= spec.max_instrs:
                break

        if spec.reduce_interval and t % spec.reduce_interval == 0:
            ins.append(VInstr(name="vfredsum", kind=OpKind.REDUCE, vl=vl,
                              sew=spec.sew, dst="f0", srcs=(last_dst,),
                              flops=vl, stream="s"))
        if rng.random() < spec.store_share:
            stride = stream_strides[0]
            ins.append(VInstr(name=_mem_name(OpKind.STORE, stride),
                              kind=OpKind.STORE, vl=vl, sew=spec.sew,
                              dst=None, srcs=(last_dst,), stride=stride,
                              flops=0, stream="out", first_strip=first))
    return ins[:spec.max_instrs]


def generate(spec: GenSpec) -> KernelTrace:
    """Deterministically expand a spec into a strip-mined kernel trace.

    Roofline accounting (`total_flops` / `total_bytes`) is summed from
    the emitted instructions, so classification is exactly a function of
    the op mix — invariant under any reordering that preserves it.
    """
    if spec.cls not in _CLASS_IDS:
        raise ValueError(f"unknown workload class {spec.cls!r} "
                         f"(known: {', '.join(CLASSES)})")
    rng = _rng_for(spec)
    if spec.cls == "fuzz":
        ins = _emit_fuzz(spec, rng)
    else:
        ins = _emit_structured(spec, rng)
    flops = sum(i.flops for i in ins)
    nbytes = sum(i.bytes for i in ins)
    name = f"{spec.cls}_{spec.seed:04d}"
    return KernelTrace(name, tuple(ins), total_flops=max(flops, 1),
                       total_bytes=max(nbytes, 1),
                       problem=f"N={spec.n},cls={spec.cls}")


def retotaled(trace: KernelTrace,
              instrs: Sequence[VInstr] | None = None) -> KernelTrace:
    """A copy of `trace` (optionally with a different instruction order)
    whose roofline totals are re-summed from its instructions — the
    reorder-stability tests build permuted twins through this."""
    ins = tuple(instrs if instrs is not None else trace.instrs)
    flops = sum(i.flops for i in ins)
    nbytes = sum(i.bytes for i in ins)
    return KernelTrace(trace.name, ins, total_flops=max(flops, 1),
                       total_bytes=max(nbytes, 1), problem=trace.problem)


# ---------------------------------------------------------------------------
# Class presets / corpus sampling
# ---------------------------------------------------------------------------

def _u(rng: np.random.Generator, lo: float, hi: float) -> float:
    return lo + (hi - lo) * rng.random()


def _i(rng: np.random.Generator, lo: int, hi: int) -> int:
    return int(rng.integers(lo, hi + 1))


def sample_spec(cls: str, seed: int = 0, index: int = 0,
                max_instrs: int = 160) -> GenSpec:
    """Draw a class-shaped spec: knobs vary scenario-to-scenario inside
    class-appropriate ranges, deterministically from ``(cls, seed,
    index)``.  `tools/gen_corpus.py` builds the committed corpus from
    exactly these draws."""
    if cls not in _CLASS_IDS:
        raise ValueError(f"unknown workload class {cls!r}")
    rng = np.random.default_rng([_CLASS_IDS[cls], seed, index, 0x5eed])
    spec_seed = (seed << 12) | index
    common = dict(cls=cls, seed=spec_seed, sew=4,
                  max_instrs=max_instrs)
    if cls == "streaming":
        return GenSpec(n=_i(rng, 256, 1024), lmul=8,
                       n_streams=_i(rng, 1, 3), compute_per_mem=1,
                       flops_per_elem=_i(rng, 1, 2),
                       stride_mix=(1.0, 0.0, 0.0), chain_depth=1,
                       accum_regs=2, store_share=1.0, **common)
    if cls == "strided":
        return GenSpec(n=_i(rng, 256, 768), lmul=4,
                       n_streams=_i(rng, 2, 3), compute_per_mem=1,
                       flops_per_elem=1,
                       stride_mix=(_u(rng, 0.0, 0.3), 1.0, 0.0),
                       chain_depth=_i(rng, 1, 2), accum_regs=2,
                       store_share=1.0, **common)
    if cls == "gather":
        return GenSpec(n=_i(rng, 128, 512), lmul=2,
                       n_streams=_i(rng, 2, 3), compute_per_mem=1,
                       flops_per_elem=_i(rng, 1, 2),
                       stride_mix=(_u(rng, 0.0, 0.4), 0.0, 1.0),
                       chain_depth=1, accum_regs=2,
                       store_share=_u(rng, 0.4, 1.0), **common)
    if cls == "reduction":
        return GenSpec(n=_i(rng, 256, 1024), lmul=8,
                       n_streams=_i(rng, 1, 2),
                       compute_per_mem=_i(rng, 1, 2), flops_per_elem=2,
                       stride_mix=(1.0, 0.0, 0.0),
                       chain_depth=_i(rng, 1, 2), accum_regs=1,
                       reduce_interval=_i(rng, 1, 3), store_share=0.0,
                       **common)
    if cls == "raw_chain":
        return GenSpec(n=_i(rng, 128, 512), lmul=4, n_streams=1,
                       compute_per_mem=1, flops_per_elem=2,
                       stride_mix=(1.0, 0.0, 0.0),
                       chain_depth=_i(rng, 6, 12), accum_regs=1,
                       div_share=_u(rng, 0.0, 0.15),
                       store_share=_u(rng, 0.0, 0.5), **common)
    if cls == "queue_pressure":
        return GenSpec(n=_i(rng, 256, 512), lmul=2, n_streams=1,
                       compute_per_mem=_i(rng, 3, 4),
                       flops_per_elem=2, stride_mix=(1.0, 0.0, 0.0),
                       chain_depth=_i(rng, 2, 4),
                       accum_regs=_i(rng, 3, 4),
                       store_share=_u(rng, 0.0, 0.3), **common)
    if cls == "slide_storm":
        return GenSpec(n=_i(rng, 256, 768), lmul=4,
                       n_streams=_i(rng, 1, 2), compute_per_mem=1,
                       flops_per_elem=1, stride_mix=(1.0, 0.0, 0.0),
                       chain_depth=_i(rng, 3, 5), accum_regs=2,
                       slide_share=_u(rng, 0.5, 0.85), store_share=1.0,
                       **common)
    if cls == "mixed_vl":
        return GenSpec(n=_i(rng, 256, 1024), lmul=_i(rng, 1, 3) * 2,
                       n_streams=_i(rng, 1, 3),
                       compute_per_mem=_i(rng, 1, 2), flops_per_elem=2,
                       stride_mix=(1.0, _u(rng, 0.0, 0.5), 0.0),
                       chain_depth=_i(rng, 1, 3), accum_regs=2,
                       vl_jitter=_u(rng, 0.4, 0.9), store_share=1.0,
                       **common)
    if cls == "compute_tile":
        return GenSpec(n=_i(rng, 128, 384), lmul=2, n_streams=1,
                       compute_per_mem=_i(rng, 4, 6),
                       flops_per_elem=2, stride_mix=(1.0, 0.0, 0.0),
                       chain_depth=_i(rng, 3, 6),
                       accum_regs=_i(rng, 2, 4),
                       store_share=_u(rng, 0.1, 0.4), **common)
    # fuzz
    return GenSpec(n=_i(rng, 64, 512), lmul=4, n_streams=1, **common)


# ---------------------------------------------------------------------------
# Serialization (the committed-corpus wire format)
# ---------------------------------------------------------------------------

_KIND_TAGS = {k: k.value for k in OpKind}
_KIND_FROM = {k.value: k for k in OpKind}
_STRIDE_FROM = {s.value: s for s in Stride}


def spec_to_dict(spec: GenSpec) -> dict:
    d = dataclasses.asdict(spec)
    d["stride_mix"] = list(d["stride_mix"])
    return d


def spec_from_dict(d: dict) -> GenSpec:
    d = dict(d)
    d["stride_mix"] = tuple(float(x) for x in d["stride_mix"])
    return GenSpec(**d)


def trace_to_dict(trace: KernelTrace) -> dict:
    """Compact, JSON-stable trace form: one row per instruction,
    ``[name, kind, vl, sew, dst, srcs, stride, flops, stream, first]``."""
    return {
        "name": trace.name,
        "problem": trace.problem,
        "total_flops": int(trace.total_flops),
        "total_bytes": int(trace.total_bytes),
        "instrs": [[i.name, i.kind.value, i.vl, i.sew, i.dst,
                    list(i.srcs), i.stride.value, i.flops, i.stream,
                    bool(i.first_strip)] for i in trace.instrs],
    }


def trace_from_dict(d: dict) -> KernelTrace:
    instrs = tuple(
        VInstr(name=row[0], kind=_KIND_FROM[row[1]], vl=int(row[2]),
               sew=int(row[3]), dst=row[4],
               srcs=tuple(row[5]), stride=_STRIDE_FROM[row[6]],
               flops=int(row[7]), stream=row[8], first_strip=bool(row[9]))
        for row in d["instrs"])
    return KernelTrace(d["name"], instrs,
                       total_flops=int(d["total_flops"]),
                       total_bytes=int(d["total_bytes"]),
                       problem=d.get("problem", ""))


def trace_bytes(trace: KernelTrace) -> bytes:
    """Canonical serialized form — the byte-determinism tests compare
    exactly these bytes across repeated generation."""
    return json.dumps(trace_to_dict(trace), sort_keys=True,
                      separators=(",", ":")).encode()
