"""Unified simulation entrypoint: `simulate(traces, opts, params, ...)`.

Before this module, execution strategy lived in kwargs scattered across
five callers (`benchmarks.gridlib`, `launch.sensitivity`,
`core.calibration`, the examples, ad-hoc scripts), each re-implementing
backend resolution.  `simulate()` makes the strategy a declared
capability:

    from repro.core import api
    res = api.simulate(traces, opts, params,
                       backend="auto",      # "numpy" | "jax" | "auto"
                       method="auto",       # "scan" | "assoc" | "auto"
                       attribution=True)

* ``backend`` picks the array engine (`numpy` mirrors the scalar
  simulator bit-for-bit; `jax` compiles the grid into one program).
* ``method`` picks the instruction-axis algorithm on the jax backend:
  ``scan`` is the sequential `lax.scan` recurrence, ``assoc`` the
  log-depth max-plus `associative_scan` engine (`repro.core.assoc_sim`).
  numpy only supports ``scan``.
* ``auto`` resolves both from the *measured* crossover points recorded in
  docs/backends.md (`resolve_plan` below) instead of the former CPU-only
  heuristic in `launch.sensitivity.resolve_backend`.

The pre-API entrypoints `BatchAraSimulator.run` / `.sweep` are gone
(deprecation shims lasted exactly one PR); the old-call → new-call
mapping remains documented in docs/architecture.md.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import pathlib
import platform
from typing import Mapping, Sequence

import time

from repro.core import bucketing
from repro.core.batch_sim import BatchAraSimulator, BatchResult
from repro.core.isa import KernelTrace, MachineConfig, OptConfig
from repro.core.simulator import SimParams
from repro.core.traces import StackedTraces, stack_traces
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

__all__ = [
    "ExecutionPlan", "simulate", "simulate_groups", "resolve_plan",
    "have_jax", "jax_accelerator", "local_device_count",
    "measured_crossovers", "JAX_WIDTH_CROSSOVER",
    "ASSOC_INSTR_CROSSOVER", "BUCKET_WASTE_CROSSOVER",
]

#: Measured numpy-vs-jax crossover (grid width ``O * P``): the numbers in
#: docs/backends.md show the numpy loop ahead of the compiled jax scan at
#: every width we sweep on CPU-only hosts, so this threshold only gates
#: when an accelerator device is present (where compiling the one-program
#: scan is worthwhile once the grid is wide enough to amortize it).
JAX_WIDTH_CROSSOVER = 512

#: Measured scan-vs-assoc crossover (padded instruction count): the assoc
#: engine does ~``D = 8 + 3R`` times the per-instruction work of the scan
#: to buy log-depth over instructions, and the BENCH_simulate.json numbers
#: (see docs/backends.md) show the sequential scan ahead on CPU at every
#: profile we run — CPU throughput, not latency, is the binding
#: constraint.  ``auto`` therefore only picks assoc on accelerator hosts,
#: and only for traces long enough that scan depth dominates compile+run.
ASSOC_INSTR_CROSSOVER = 4096

#: Pad-waste share above which ``bucket="auto"`` turns on shape
#: bucketing for jax execution (`repro.core.bucketing`): below it the
#: extra dispatches + compiles cost more than the masked pad steps they
#: save; well above it the bucketed path wins big (the measured planner
#: entry in benchmarks/BENCH_simulate.json shows the smoke grid at 85%
#: waste running >8x faster bucketed).  numpy never buckets on auto —
#: its per-row loop already skips padding, so there is nothing to save.
BUCKET_WASTE_CROSSOVER = 0.25

#: Recorded crossover entries (benchmarks/BENCH_simulate.json, this
#: machine's key, ``entry["crossovers"]``) override the three policy
#: constants above when present and non-null.  `bench_record.py` only
#: records a crossover it actually measured — on CPU-only hosts the
#: numpy/scan side wins at every measured point, so the recorded values
#: stay null and the conservative code constants keep gating (ROADMAP
#: item 1: an accelerator host records real values, and `resolve_plan`
#: starts trusting them with no code change).
_BENCH_PATH = (pathlib.Path(__file__).resolve().parents[3]
               / "benchmarks" / "BENCH_simulate.json")


def _machine_key() -> str:
    """Mirror of `benchmarks.bench_record.machine_key` (kept here so the
    core package never imports the benchmarks tree)."""
    if not have_jax():                     # pragma: no cover - env-dep
        return f"{platform.machine()}-{os.cpu_count()}cpu-nojax"
    import jax
    return (f"{platform.machine()}-{os.cpu_count()}cpu-"
            f"{jax.default_backend()}")


@functools.lru_cache(maxsize=1)
def _recorded_crossovers() -> dict:
    """This machine's recorded ``crossovers`` fold, or `{}`."""
    try:
        records = json.loads(_BENCH_PATH.read_text())
    except (OSError, ValueError):
        return {}
    entry = records.get(_machine_key(), {})
    cw = entry.get("crossovers", {})
    return cw if isinstance(cw, dict) else {}


def measured_crossovers() -> dict[str, float]:
    """Effective ``auto`` thresholds: recorded values where measured,
    code-constant fallbacks otherwise."""
    cw = _recorded_crossovers()
    return {
        "jax_width": cw.get("jax_width") or JAX_WIDTH_CROSSOVER,
        "assoc_instrs": cw.get("assoc_instrs") or ASSOC_INSTR_CROSSOVER,
        "bucket_waste": cw.get("bucket_waste") or BUCKET_WASTE_CROSSOVER,
    }


def have_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except ImportError:                    # pragma: no cover - env-dep
        return False


def jax_accelerator() -> bool:
    """True when jax is importable and backed by a non-CPU device."""
    if not have_jax():
        return False
    import jax
    try:
        return jax.default_backend() != "cpu"
    except RuntimeError:                   # pragma: no cover - env-dep
        return False


def local_device_count() -> int:
    """Local jax device count (1 without jax — nothing to shard over)."""
    if not have_jax():                     # pragma: no cover - env-dep
        return 1
    import jax
    try:
        return len(jax.devices())
    except RuntimeError:                   # pragma: no cover - env-dep
        return 1


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A fully-resolved execution strategy for one `simulate` call."""
    backend: str                       # "numpy" | "jax"
    method: str                        # "scan" | "assoc"
    attribution: bool = False
    p_chunk: int | None = None         # params-axis chunking
    assoc_chunk: int | None = None     # assoc instruction-chunk length
    use_pallas: bool = False           # fuse the assoc combine via Pallas
    bucket: str = "none"               # "none" | "pow2" shape bucketing
    shard: str = "none"                # "none" | "devices" P-axis shard

    def __post_init__(self):
        if self.backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.method not in ("scan", "assoc"):
            raise ValueError(f"unknown method {self.method!r}")
        if self.backend == "numpy" and self.method == "assoc":
            raise ValueError("method='assoc' requires backend='jax' "
                             "(the max-plus engine is jax-only)")
        if self.bucket not in ("none", *bucketing.POLICIES):
            raise ValueError(f"unknown bucket policy {self.bucket!r} "
                             f"(known: none, {', '.join(bucketing.POLICIES)})")
        if self.shard not in ("none", "devices"):
            raise ValueError(f"unknown shard mode {self.shard!r} "
                             "(known: none, devices)")
        if self.shard == "devices" and self.backend != "jax":
            raise ValueError("shard='devices' requires backend='jax' "
                             "(shard_map shards the compiled sweep)")
        if self.shard == "devices" and self.method != "scan":
            raise ValueError("shard='devices' supports method='scan' "
                             "only (the assoc engine chunks the "
                             "instruction axis, not P)")


def resolve_plan(*, backend: str = "auto", method: str = "auto",
                 width: int = 1, n_instrs: int = 0,
                 attribution: bool = False, p_chunk: int | None = None,
                 assoc_chunk: int | None = None,
                 use_pallas: bool = False,
                 bucket: str = "auto", shard: str = "auto",
                 pad_waste: float = 0.0,
                 n_params: int = 1) -> ExecutionPlan:
    """Resolve ``auto`` strategy choices against the measured crossovers.

    ``width`` is the grid width ``len(opts) * len(params)``; ``n_instrs``
    the (longest) trace length; ``pad_waste`` the stacked grid's padded-
    step share (`repro.core.bucketing.pad_waste_share` — 0.0 when the
    caller has no stack at hand, which resolves ``bucket="auto"`` to
    "none").  Thresholds come from `measured_crossovers()`: the values
    this machine's BENCH_simulate.json entry recorded where measured,
    the conservative code constants where not.  The decision table
    (measured numbers in docs/backends.md):

    * backend ``auto`` → ``jax`` only on accelerator hosts with
      ``width >= JAX_WIDTH_CROSSOVER``; otherwise ``numpy`` (on CPU the
      numpy loop wins at every measured width).
    * method ``auto`` → ``assoc`` only on an accelerator backend with
      ``n_instrs >= ASSOC_INSTR_CROSSOVER``; otherwise ``scan`` (on CPU
      the sequential scan wins at every measured trace length — the
      assoc engine trades ~``D``x work for log depth, which only pays
      when depth, not throughput, is the bottleneck).
    * bucket ``auto`` → ``pow2`` on the jax backend when ``pad_waste >=
      BUCKET_WASTE_CROSSOVER`` (the numpy loop already skips pad rows,
      so bucketing can only cost there); otherwise ``none``.
    * shard ``auto`` → ``devices`` on the jax scan path when more than
      one local device exists and the params axis has at least one
      column per device; otherwise ``none`` (a 1-device host gains
      nothing from the shard_map detour).
    """
    cw = measured_crossovers()
    if backend == "auto":
        backend = ("jax" if width >= cw["jax_width"]
                   and jax_accelerator() else "numpy")
        obs_metrics.counter("plan.auto_backend", backend).inc()
    if method == "auto":
        method = ("assoc" if backend == "jax" and jax_accelerator()
                  and n_instrs >= cw["assoc_instrs"] else "scan")
        obs_metrics.counter("plan.auto_method", method).inc()
    if bucket == "auto":
        bucket = ("pow2" if backend == "jax"
                  and pad_waste >= cw["bucket_waste"] else "none")
        obs_metrics.counter("plan.auto_bucket", bucket).inc()
    if shard == "auto":
        n_dev = local_device_count()
        shard = ("devices" if backend == "jax" and method == "scan"
                 and n_dev > 1 and n_params >= n_dev else "none")
        obs_metrics.counter("plan.auto_shard", shard).inc()
    obs_metrics.counter("plan.resolved").inc()
    return ExecutionPlan(backend=backend, method=method,
                         attribution=attribution, p_chunk=p_chunk,
                         assoc_chunk=assoc_chunk, use_pallas=use_pallas,
                         bucket=bucket, shard=shard)


_SIMS: dict[tuple, BatchAraSimulator] = {}


def _shared_sim(mc: MachineConfig) -> BatchAraSimulator:
    """Process-wide simulator per machine config, so every `simulate`
    caller shares one compiled-program cache."""
    key = dataclasses.astuple(mc)
    sim = _SIMS.get(key)
    if sim is None:
        sim = BatchAraSimulator(mc)
        _SIMS[key] = sim
    return sim


def _as_stacked(traces) -> StackedTraces:
    if isinstance(traces, StackedTraces):
        return traces
    if isinstance(traces, KernelTrace):
        return stack_traces([traces])
    if isinstance(traces, Mapping):
        return stack_traces(list(traces.values()))
    return stack_traces(list(traces))


def simulate(traces, opts: Sequence[OptConfig],
             params: SimParams | Sequence[SimParams] = SimParams(),
             *, mc: MachineConfig = MachineConfig(),
             backend: str = "auto", method: str = "auto",
             attribution: bool = False, p_chunk: int | None = None,
             assoc_chunk: int | None = None, use_pallas: bool = False,
             bucket: str = "auto", shard: str = "auto",
             sim: BatchAraSimulator | None = None,
             runlog=None) -> BatchResult:
    """Evaluate the `(traces x opts x params)` grid under one resolved
    execution plan.

    `traces` may be a single `KernelTrace`, a sequence or mapping of
    them, or an already-stacked `StackedTraces`.  Strategy kwargs are
    resolved by `resolve_plan` (pass concrete values to pin them); `sim`
    optionally reuses a caller-owned `BatchAraSimulator` (its compiled
    jax programs) instead of the shared per-`mc` instance.

    ``bucket`` groups mixed-length traces into shape buckets so the jax
    backends stop scanning padded no-op steps (`repro.core.bucketing`;
    results are scattered back into input order and parity-tested
    against the unbucketed path).  ``shard`` splits the params axis
    across local devices via `shard_map` (`repro.launch.mesh`); on a
    single-device host the sharded program is the unsharded one.

    ``runlog`` (or the ``REPRO_RUNLOG`` env var) names a JSON-lines file
    to append this call's span tree and a metrics snapshot to; it
    enables the tracer for the call if it was off (docs/observability.md).
    """
    target = obs_export.runlog_target(runlog)
    was_enabled = obs_spans.enabled()
    if target is not None and not was_enabled:
        obs_spans.enable()
    t0 = time.perf_counter()
    try:
        with obs_spans.span("simulate") as root:
            with obs_spans.span("traces.stack"):
                stacked = _as_stacked(traces)
            opts = list(opts)
            if isinstance(params, SimParams):
                params = [params]
            params = list(params)
            with obs_spans.span("plan.resolve"):
                plan = resolve_plan(backend=backend, method=method,
                                    width=len(opts) * len(params),
                                    n_instrs=int(stacked.kind.shape[1]),
                                    attribution=attribution,
                                    p_chunk=p_chunk,
                                    assoc_chunk=assoc_chunk,
                                    use_pallas=use_pallas,
                                    bucket=bucket, shard=shard,
                                    pad_waste=bucketing.pad_waste_share(
                                        stacked),
                                    n_params=len(params))
            root.set(backend=plan.backend, method=plan.method,
                     attribution=plan.attribution,
                     n_traces=int(stacked.kind.shape[0]),
                     n_opts=len(opts), n_params=len(params),
                     bucket=plan.bucket, shard=plan.shard)
            simulator = sim if sim is not None else _shared_sim(mc)
            with obs_spans.span("exec", backend=plan.backend,
                                method=plan.method):
                if plan.bucket != "none":
                    result = bucketing.run_bucketed(
                        simulator, stacked, opts, params,
                        policy=plan.bucket, backend=plan.backend,
                        method=plan.method,
                        attribution=plan.attribution,
                        p_chunk=plan.p_chunk,
                        assoc_chunk=plan.assoc_chunk,
                        use_pallas=plan.use_pallas, shard=plan.shard)
                else:
                    result = simulator._run(
                        stacked, opts, params, backend=plan.backend,
                        attribution=plan.attribution,
                        p_chunk=plan.p_chunk,
                        method=plan.method,
                        assoc_chunk=plan.assoc_chunk,
                        use_pallas=plan.use_pallas, shard=plan.shard)
        obs_metrics.counter("simulate.calls").inc()
        obs_metrics.counter("simulate.cells").inc(
            stacked.kind.shape[0] * len(opts) * len(params))
        obs_metrics.histogram("simulate.wall_us").observe(
            (time.perf_counter() - t0) * 1e6)
        return result
    finally:
        if target is not None:
            obs_export.flush(target)
            if not was_enabled:
                obs_spans.disable()


def simulate_groups(traces, groups: Sequence[tuple[Sequence[OptConfig],
                                                   Sequence[SimParams]]],
                    *, mc: MachineConfig = MachineConfig(),
                    backend: str = "auto", method: str = "auto",
                    attribution: bool = False,
                    p_chunk: int | None = None,
                    assoc_chunk: int | None = None,
                    bucket: str = "auto", shard: str = "auto",
                    sim: BatchAraSimulator | None = None
                    ) -> list[BatchResult]:
    """Evaluate several `(opts, params)` grids over ONE shared trace
    stack: `groups[g]` is an ``(opts, params)`` pair and the g-th
    result is ``simulate(traces, *groups[g])``.

    This is the population-scoring entrypoint for callers whose
    candidates do not form a dense `(opts x params)` product — the
    design-space searcher's populations mix opt corners, and simulating
    the bounding product would waste `O(corners)` times the work.
    Grouping by corner instead keeps every group one batched call, the
    trace stacking/padding is paid once for the whole population, and
    all groups share one simulator (compiled-program cache).  Each
    group still counts one ``simulate.calls`` tick, so obs metrics can
    assert a search generation cost at most `corners + 1` batched
    calls (`tests/test_design_search.py`).
    """
    stacked = _as_stacked(traces)
    simulator = sim if sim is not None else _shared_sim(mc)
    obs_metrics.counter("simulate.groups").inc(len(groups))
    with obs_spans.span("simulate.groups", n_groups=len(groups),
                        n_traces=int(stacked.kind.shape[0])):
        return [simulate(stacked, opts, params, mc=mc, backend=backend,
                         method=method, attribution=attribution,
                         p_chunk=p_chunk, assoc_chunk=assoc_chunk,
                         bucket=bucket, shard=shard, sim=simulator)
                for opts, params in groups]
