"""Unified simulation entrypoint: `simulate(traces, opts, params, ...)`.

Before this module, execution strategy lived in kwargs scattered across
five callers (`benchmarks.gridlib`, `launch.sensitivity`,
`core.calibration`, the examples, ad-hoc scripts), each re-implementing
backend resolution.  `simulate()` makes the strategy a declared
capability:

    from repro.core import api
    res = api.simulate(traces, opts, params,
                       backend="auto",      # "numpy" | "jax" | "auto"
                       method="auto",       # "scan" | "assoc" | "auto"
                       attribution=True)

* ``backend`` picks the array engine (`numpy` mirrors the scalar
  simulator bit-for-bit; `jax` compiles the grid into one program).
* ``method`` picks the instruction-axis algorithm on the jax backend:
  ``scan`` is the sequential `lax.scan` recurrence, ``assoc`` the
  log-depth max-plus `associative_scan` engine (`repro.core.assoc_sim`).
  numpy only supports ``scan``.
* ``auto`` resolves both from the *measured* crossover points recorded in
  docs/backends.md (`resolve_plan` below) instead of the former CPU-only
  heuristic in `launch.sensitivity.resolve_backend`.

The pre-API entrypoints `BatchAraSimulator.run` / `.sweep` are gone
(deprecation shims lasted exactly one PR); the old-call → new-call
mapping remains documented in docs/architecture.md.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import time

from repro.core.batch_sim import BatchAraSimulator, BatchResult
from repro.core.isa import KernelTrace, MachineConfig, OptConfig
from repro.core.simulator import SimParams
from repro.core.traces import StackedTraces, stack_traces
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

__all__ = [
    "ExecutionPlan", "simulate", "resolve_plan", "have_jax",
    "jax_accelerator", "JAX_WIDTH_CROSSOVER", "ASSOC_INSTR_CROSSOVER",
]

#: Measured numpy-vs-jax crossover (grid width ``O * P``): the numbers in
#: docs/backends.md show the numpy loop ahead of the compiled jax scan at
#: every width we sweep on CPU-only hosts, so this threshold only gates
#: when an accelerator device is present (where compiling the one-program
#: scan is worthwhile once the grid is wide enough to amortize it).
JAX_WIDTH_CROSSOVER = 512

#: Measured scan-vs-assoc crossover (padded instruction count): the assoc
#: engine does ~``D = 8 + 3R`` times the per-instruction work of the scan
#: to buy log-depth over instructions, and the BENCH_simulate.json numbers
#: (see docs/backends.md) show the sequential scan ahead on CPU at every
#: profile we run — CPU throughput, not latency, is the binding
#: constraint.  ``auto`` therefore only picks assoc on accelerator hosts,
#: and only for traces long enough that scan depth dominates compile+run.
ASSOC_INSTR_CROSSOVER = 4096


def have_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except ImportError:                    # pragma: no cover - env-dep
        return False


def jax_accelerator() -> bool:
    """True when jax is importable and backed by a non-CPU device."""
    if not have_jax():
        return False
    import jax
    try:
        return jax.default_backend() != "cpu"
    except RuntimeError:                   # pragma: no cover - env-dep
        return False


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A fully-resolved execution strategy for one `simulate` call."""
    backend: str                       # "numpy" | "jax"
    method: str                        # "scan" | "assoc"
    attribution: bool = False
    p_chunk: int | None = None         # params-axis chunking
    assoc_chunk: int | None = None     # assoc instruction-chunk length
    use_pallas: bool = False           # fuse the assoc combine via Pallas

    def __post_init__(self):
        if self.backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.method not in ("scan", "assoc"):
            raise ValueError(f"unknown method {self.method!r}")
        if self.backend == "numpy" and self.method == "assoc":
            raise ValueError("method='assoc' requires backend='jax' "
                             "(the max-plus engine is jax-only)")


def resolve_plan(*, backend: str = "auto", method: str = "auto",
                 width: int = 1, n_instrs: int = 0,
                 attribution: bool = False, p_chunk: int | None = None,
                 assoc_chunk: int | None = None,
                 use_pallas: bool = False) -> ExecutionPlan:
    """Resolve ``auto`` strategy choices against the measured crossovers.

    ``width`` is the grid width ``len(opts) * len(params)``; ``n_instrs``
    the (longest) trace length.  The decision table (measured numbers in
    docs/backends.md):

    * backend ``auto`` → ``jax`` only on accelerator hosts with
      ``width >= JAX_WIDTH_CROSSOVER``; otherwise ``numpy`` (on CPU the
      numpy loop wins at every measured width).
    * method ``auto`` → ``assoc`` only on an accelerator backend with
      ``n_instrs >= ASSOC_INSTR_CROSSOVER``; otherwise ``scan`` (on CPU
      the sequential scan wins at every measured trace length — the
      assoc engine trades ~``D``x work for log depth, which only pays
      when depth, not throughput, is the bottleneck).
    """
    if backend == "auto":
        backend = ("jax" if width >= JAX_WIDTH_CROSSOVER
                   and jax_accelerator() else "numpy")
        obs_metrics.counter("plan.auto_backend", backend).inc()
    if method == "auto":
        method = ("assoc" if backend == "jax" and jax_accelerator()
                  and n_instrs >= ASSOC_INSTR_CROSSOVER else "scan")
        obs_metrics.counter("plan.auto_method", method).inc()
    obs_metrics.counter("plan.resolved").inc()
    return ExecutionPlan(backend=backend, method=method,
                         attribution=attribution, p_chunk=p_chunk,
                         assoc_chunk=assoc_chunk, use_pallas=use_pallas)


_SIMS: dict[tuple, BatchAraSimulator] = {}


def _shared_sim(mc: MachineConfig) -> BatchAraSimulator:
    """Process-wide simulator per machine config, so every `simulate`
    caller shares one compiled-program cache."""
    key = dataclasses.astuple(mc)
    sim = _SIMS.get(key)
    if sim is None:
        sim = BatchAraSimulator(mc)
        _SIMS[key] = sim
    return sim


def _as_stacked(traces) -> StackedTraces:
    if isinstance(traces, StackedTraces):
        return traces
    if isinstance(traces, KernelTrace):
        return stack_traces([traces])
    if isinstance(traces, Mapping):
        return stack_traces(list(traces.values()))
    return stack_traces(list(traces))


def simulate(traces, opts: Sequence[OptConfig],
             params: SimParams | Sequence[SimParams] = SimParams(),
             *, mc: MachineConfig = MachineConfig(),
             backend: str = "auto", method: str = "auto",
             attribution: bool = False, p_chunk: int | None = None,
             assoc_chunk: int | None = None, use_pallas: bool = False,
             sim: BatchAraSimulator | None = None,
             runlog=None) -> BatchResult:
    """Evaluate the `(traces x opts x params)` grid under one resolved
    execution plan.

    `traces` may be a single `KernelTrace`, a sequence or mapping of
    them, or an already-stacked `StackedTraces`.  Strategy kwargs are
    resolved by `resolve_plan` (pass concrete values to pin them); `sim`
    optionally reuses a caller-owned `BatchAraSimulator` (its compiled
    jax programs) instead of the shared per-`mc` instance.

    ``runlog`` (or the ``REPRO_RUNLOG`` env var) names a JSON-lines file
    to append this call's span tree and a metrics snapshot to; it
    enables the tracer for the call if it was off (docs/observability.md).
    """
    target = obs_export.runlog_target(runlog)
    was_enabled = obs_spans.enabled()
    if target is not None and not was_enabled:
        obs_spans.enable()
    t0 = time.perf_counter()
    try:
        with obs_spans.span("simulate") as root:
            with obs_spans.span("traces.stack"):
                stacked = _as_stacked(traces)
            opts = list(opts)
            if isinstance(params, SimParams):
                params = [params]
            params = list(params)
            with obs_spans.span("plan.resolve"):
                plan = resolve_plan(backend=backend, method=method,
                                    width=len(opts) * len(params),
                                    n_instrs=int(stacked.kind.shape[1]),
                                    attribution=attribution,
                                    p_chunk=p_chunk,
                                    assoc_chunk=assoc_chunk,
                                    use_pallas=use_pallas)
            root.set(backend=plan.backend, method=plan.method,
                     attribution=plan.attribution,
                     n_traces=int(stacked.kind.shape[0]),
                     n_opts=len(opts), n_params=len(params))
            simulator = sim if sim is not None else _shared_sim(mc)
            with obs_spans.span("exec", backend=plan.backend,
                                method=plan.method):
                result = simulator._run(
                    stacked, opts, params, backend=plan.backend,
                    attribution=plan.attribution, p_chunk=plan.p_chunk,
                    method=plan.method, assoc_chunk=plan.assoc_chunk,
                    use_pallas=plan.use_pallas)
        obs_metrics.counter("simulate.calls").inc()
        obs_metrics.counter("simulate.cells").inc(
            stacked.kind.shape[0] * len(opts) * len(params))
        obs_metrics.histogram("simulate.wall_us").observe(
            (time.perf_counter() - t0) * 1e6)
        return result
    finally:
        if target is not None:
            obs_export.flush(target)
            if not was_enabled:
                obs_spans.disable()
