"""Fused tropical (max, +) matrix composition — the inner step of the
``method="assoc"`` engine (`repro.core.assoc_sim`).

The associative-scan formulation composes per-chunk transfer matrices in
the tropical semiring:

    C[i, j] = max_k ( B[i, k] + A[k, j] )        (apply A first, then B)

together with the *argmax binding index* ``K[i, j]`` that the attribution
machinery uses to route payload vectors through the composition (see
`assoc_sim` for the payload invariant).  This module provides two
implementations with identical semantics:

  * ``_compose_jnp``     — plain jax.numpy reference (an unrolled loop over
    the shared dimension; the matrices are small, ``D = 8 + 3R``), used by
    default on CPU where Pallas runs in interpreter mode and is slow.
  * ``_compose_pallas``  — a Pallas kernel (`pl.pallas_call`) that fuses the
    whole max/+/argmax loop into one kernel over a flattened batch of
    matrix pairs.  On CPU it runs with ``interpret=True`` so CI exercises
    the exact kernel body; on an accelerator backend it compiles for real.

Both return ``(C, K)`` with ``K`` the *first* maximising ``k`` (ties keep
the lowest index), so the two paths agree bit-for-bit — asserted by
``tests/test_assoc.py::test_pallas_matches_jnp``.

``-inf`` entries (absent transitions) are first-class: ``-inf + x = -inf``
and a strict ``>`` comparison never adopts them over a finite incumbent.
No subtraction happens here, so no NaNs can appear.
"""
from __future__ import annotations

import functools


def _compose_jnp(b, a):
    """Reference tropical matmul: ``C = B (.) A`` with argmax indices.

    `b`, `a`: ``(..., D, D)`` float arrays.  Returns ``(C, K)`` where
    ``C[..., i, j] = max_k b[..., i, k] + a[..., k, j]`` and ``K`` is the
    first maximising ``k`` (int32).
    """
    import jax.numpy as jnp

    D = a.shape[-1]
    best = b[..., :, 0][..., :, None] + a[..., 0, :][..., None, :]
    arg = jnp.zeros(best.shape, jnp.int32)
    for k in range(1, D):
        t = b[..., :, k][..., :, None] + a[..., k, :][..., None, :]
        take = t > best
        best = jnp.where(take, t, best)
        arg = jnp.where(take, k, arg)
    return best, arg


@functools.lru_cache(maxsize=None)
def _make_kernel(D: int):
    """Build the Pallas kernel body for a fixed matrix dimension."""
    import jax.numpy as jnp

    def kernel(b_ref, a_ref, c_ref, k_ref):
        bb = b_ref[...]                            # (block, D, D)
        aa = a_ref[...]
        best = bb[:, :, 0][:, :, None] + aa[:, 0, :][:, None, :]
        arg = jnp.zeros(best.shape, jnp.int32)
        for k in range(1, D):                      # D is static: unrolled
            t = bb[:, :, k][:, :, None] + aa[:, k, :][:, None, :]
            take = t > best
            best = jnp.where(take, t, best)
            arg = jnp.where(take, k, arg)
        c_ref[...] = best
        k_ref[...] = arg

    return kernel


def _pick_block(n: int, D: int) -> int:
    """Choose the kernel block size from the batch size and matrix dim.

    A batch smaller than the old fixed ``block=8`` must not pad up to a
    full block (a 2-pair compose would run 4x the work); a large batch
    bounds the per-block working set — four ``(block, D, D)`` float64
    tiles live at once — to ~256 KiB so blocks stay cache-resident as
    ``D = 8 + 3R`` grows with the register count."""
    if n <= 0:
        return 1
    budget = max(1, (1 << 18) // (4 * D * D * 8))
    return max(1, min(n, budget, 64))


def _tropical_identity(n: int, D: int, dtype):
    """``n`` stacked tropical identity matrices: 0 on the diagonal,
    ``-inf`` elsewhere — the semiring's neutral element, so padded rows
    compose to exact identities instead of the finite garbage zero
    padding would produce (``tests/test_bucketing.py`` asserts this on
    the ``n % block != 0`` path)."""
    import jax.numpy as jnp

    eye = jnp.where(jnp.eye(D, dtype=bool),
                    jnp.zeros((), dtype), -jnp.inf).astype(dtype)
    return jnp.broadcast_to(eye, (n, D, D))


def _compose_pallas(b, a, *, block: int | None = None,
                    interpret: bool | None = None):
    """Pallas-fused tropical matmul over a flattened batch of pairs.

    Leading dims of `b`/`a` are flattened to one batch axis, padded up to a
    multiple of `block` (default: `_pick_block` from the batch size and
    `D`) with tropical identity matrices, and the kernel runs one grid
    step per block.  ``interpret`` defaults to True on CPU (no Pallas
    lowering there) and False on accelerator backends.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    D = a.shape[-1]
    lead = b.shape[:-2]
    n = 1
    for d in lead:
        n *= d
    if block is None:
        block = _pick_block(n, D)
    bf = b.reshape(n, D, D)
    af = a.reshape(n, D, D)
    n2 = -(-n // block) * block
    if n2 != n:
        ident = _tropical_identity(n2 - n, D, b.dtype)
        bf = jnp.concatenate([bf, ident], axis=0)
        af = jnp.concatenate([af, ident], axis=0)
    c, k = pl.pallas_call(
        _make_kernel(D),
        grid=(n2 // block,),
        in_specs=[pl.BlockSpec((block, D, D), lambda i: (i, 0, 0)),
                  pl.BlockSpec((block, D, D), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((block, D, D), lambda i: (i, 0, 0)),
                   pl.BlockSpec((block, D, D), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((n2, D, D), b.dtype),
                   jax.ShapeDtypeStruct((n2, D, D), jnp.int32)],
        interpret=interpret,
    )(bf, af)
    return (c[:n].reshape(*lead, D, D), k[:n].reshape(*lead, D, D))


def tropical_compose(b, a, *, use_pallas: bool = False,
                     interpret: bool | None = None):
    """``C[i,j] = max_k b[i,k] + a[k,j]`` plus argmax indices.

    `a` is the earlier transfer matrix, `b` the later one (apply `a`
    first).  With ``use_pallas`` the fused kernel is used (interpreter
    mode on CPU); otherwise the jnp reference.  Semantics are identical.
    """
    if use_pallas:
        return _compose_pallas(b, a, interpret=interpret)
    return _compose_jnp(b, a)
