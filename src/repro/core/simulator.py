"""Strip-level cycle-approximate simulator of Ara / Ara-Opt.

The paper evaluates an RTL implementation; RTL is not reproducible here, so
we model the machine at vector-instruction (strip) granularity with the
microarchitectural mechanisms the paper identifies, each switchable per the
2^3 ablation (Table I):

  M — memory path.  Baseline is demand-driven: a load's DRAM latency is
      hidden only while the request stream is continuous; when the VLSU's
      result queue fills because VRF write-back is hazard-gated, back-
      pressure propagates to transaction generation ("bus-handshake stalls
      propagate back to address expansion", §IV.A) and the stream gaps,
      exposing latency.  Coupled address expansion adds per-burst overhead
      and read/write transactions interfere (turnaround).  Ara-Opt decouples
      the front end (overheads hidden, r/w separated) and next-VL prefetch
      turns warm unit-stride streams into prefetch-buffer hits.

  C — dependence & issue.  Baseline releases WAR read-occupancy only at
      *instruction completion* plus an overhead, and pays a conservative
      per-instruction issue gap.  Ara-Opt releases at *read-done* (source
      operands drained into operand queues) and issues with the dynamic
      release-aware gap.

  O — operand delivery.  Baseline routes producer->consumer values through
      the VRF (write-back + re-read: chain delay d_chain), suffers VRF
      bank-conflict stretch (paper §VI.C: gemm 14% -> 5%), and has shallow
      operand/result queues (small run-ahead).  Ara-Opt forwards results
      (d_fwd), cuts conflicts, and deepens queues (dual-source).

Timing semantics follow the ideal-chaining model of §II.C: RAW consumers
start once the producer's first results exist (chaining) and can finish no
earlier than the producer finishes plus the propagation delay.

Deviation attribution: every absolute time the recurrence tracks carries a
component vector (``repro.core.stalls``) decomposing it into ideal time
plus nine stall categories over the paper's three critical paths.  The
vector follows the exact same max/+ dataflow as the scalar time itself —
``max`` adopts the components of the binding argument, additions charge
the responsible category — so ``ideal + sum(stalls) == measured`` holds
per instruction and per kernel, and the kernel-level vector explains the
finishing instruction's critical path.  Totals are computed by the same
float expressions as before, so cycles stay bit-identical to the
pre-attribution simulator.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.isa import (KernelTrace, MachineConfig, OpKind, OptConfig,
                            Stride, VInstr)
from repro.core.stalls import (DEP_ISSUE_GAP, DEP_WAR_RELEASE, IDEAL,
                               MEM_DEMAND_LATENCY, MEM_RW_TURNAROUND,
                               MEM_STORE_COMMIT, MEM_TX_OVERHEAD, NCOMP,
                               OPR_BANK_CONFLICT, OPR_CHAIN_DELAY,
                               OPR_QUEUE_LIMIT)


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Microarchitectural timing parameters.

    `*_base` values model baseline Ara and are calibrated once against the
    paper's Fig. 3 / Fig. 4 (core/calibration.py); opt-side values are fixed
    small constants.  VRF conflict rates come directly from the paper
    (§VI.C: gemm bank-conflict ratio 14% -> 5%).
    """
    mem_latency: float = 38.0          # demand-load latency (cycles)
    prefetch_hit: float = 4.0          # prefetch-buffer hit latency
    tx_ovh_base: float = 1.0           # per-burst overhead, coupled front end
    tx_ovh_opt: float = 0.1            # decoupled front end
    idx_ovh_base: float = 2.0          # per-element overhead, indexed access
    idx_ovh_opt: float = 1.8           # gathers defeat next-VL prefetch:
    div_factor: float = 8.0            # non-pipelined divide cycles/element
    rw_turnaround_base: float = 10.0   # read<->write bus switch penalty
    rw_turnaround_opt: float = 1.0
    store_commit_base: float = 24.0    # write-commit latency holding the
    store_commit_opt: float = 0.0      # unified baseline r/w path (§IV.A)
    issue_gap_base: float = 3.0        # cycles between issues (conservative)
    issue_gap_opt: float = 1.0         # dynamic release-aware issue
    war_release_ovh: float = 6.0       # extra cycles after completion (base)
    d_chain_base: float = 12.0         # produce->writeback->reread delay
    d_fwd: float = 2.0                 # multi-source forwarding delay
    conflict_base: float = 0.14        # VRF bank-conflict stretch (paper)
    conflict_opt: float = 0.05
    queue_adv_base: float = 48.0       # result/operand queue run-ahead (cyc)
    queue_adv_opt: float = 96.0        # deep dual-source queues


@dataclasses.dataclass
class InstrTiming:
    start: float
    first_out: float
    complete: float
    read_done: float                   # when source-operand reads finish
    ideal: float = 0.0                 # ideal component of `complete`
    stalls: np.ndarray | None = None   # (9,) stall categories of `complete`


@dataclasses.dataclass
class SimResult:
    kernel: str
    cycles: float
    flops: int
    bytes: int
    timings: list[InstrTiming]
    busy_fpu: float = 0.0
    busy_bus: float = 0.0
    ideal: float = 0.0                 # ideal component of `cycles`
    stalls: np.ndarray | None = None   # (9,) stall categories of `cycles`
    # Phase-split columns (prologue/steady/tail, dp/ii_eff/dt, t_ideal) —
    # attached by grid-level attribution passes (`benchmarks.gridlib`);
    # scalar runs leave it None (use `analysis.attribution.phase_decompose`
    # on the timings instead).
    phases: dict | None = None

    @property
    def gflops(self) -> float:
        # 1 GHz machine: flops/cycle == GFLOPS.
        return self.flops / max(self.cycles, 1e-9)

    @property
    def lane_utilization(self) -> float:
        return self.busy_fpu / max(self.cycles, 1e-9)

    @property
    def bus_utilization(self) -> float:
        return self.busy_bus / max(self.cycles, 1e-9)


def _vmax(*cands: tuple[float, np.ndarray | None]
          ) -> tuple[float, np.ndarray | None]:
    """max over (time, components) pairs; ties keep the earliest argument,
    matching Python ``max``'s first-maximal semantics."""
    t, c = cands[0]
    for t2, c2 in cands[1:]:
        if t2 > t:
            t, c = t2, c2
    return t, c


def _bump(c: np.ndarray | None,
          *pairs: tuple[int, float]) -> np.ndarray | None:
    """Copy a component vector, adding `amount` at each `(index, amount)`.

    `None` passes through: with attribution disabled no component state
    exists and the accounting collapses to cheap no-ops."""
    if c is None:
        return None
    out = c.copy()
    for idx, amount in pairs:
        out[idx] += amount
    return out


class AraSimulator:
    """Simulate a kernel trace under a given optimization configuration.

    `attribution` (default on) tracks the per-instruction/per-kernel
    stall decomposition; cycles are identical either way, so callers that
    only need totals (timing loops, large scalar sweeps) can turn it off
    to skip the component bookkeeping (~3x on the scalar path).
    """

    def __init__(self, mc: MachineConfig = MachineConfig(),
                 params: SimParams = SimParams(),
                 attribution: bool = True):
        self.mc = mc
        self.p = params
        self.attribution = attribution

    # -- per-config parameter views -----------------------------------------
    def _view(self, opt: OptConfig):
        p = self.p
        return dict(
            tx_ovh=p.tx_ovh_opt if opt.memory else p.tx_ovh_base,
            idx_ovh=p.idx_ovh_opt if opt.memory else p.idx_ovh_base,
            rw_turn=p.rw_turnaround_opt if opt.memory else p.rw_turnaround_base,
            store_commit=(p.store_commit_opt if opt.memory
                          else p.store_commit_base),
            issue_gap=p.issue_gap_opt if opt.control else p.issue_gap_base,
            d_chain=p.d_fwd if opt.operand else p.d_chain_base,
            conflict=1.0 + (p.conflict_opt if opt.operand else p.conflict_base),
            queue_adv=p.queue_adv_opt if opt.operand else p.queue_adv_base,
        )

    def run(self, trace: KernelTrace, opt: OptConfig) -> SimResult:
        mc, p = self.mc, self.p
        v = self._view(opt)
        epc = mc.elems_per_cycle
        bpc = mc.axi_bytes_per_cycle

        issue_t = 0.0                       # in-order dispatch pointer
        # Baseline: one issue path — loads queue *behind* stores that are
        # still waiting for their data (r/w not separated, §IV.A).
        # Ara-Opt: reads and writes issue on separate AXI channels.
        split_rw = opt.memory
        bus_free = 0.0                      # shared (baseline) / read chan
        wbus_free = 0.0                     # write channel (opt only)
        addr_free = 0.0                     # VLSU front-end serialization
        bus_last_kind: OpKind | None = None
        fpu_free = 0.0
        sldu_free = 0.0
        writer: dict[str, InstrTiming] = {}      # last writer per register
        reader_release: dict[str, float] = {}    # latest WAR release per reg
        timings: list[InstrTiming] = []
        busy_fpu = busy_bus = 0.0

        # Component vectors mirror each tracked time (see module docstring);
        # arrays are treated as immutable, `_bump` copies on write.  With
        # attribution off every component is None and `_bump` passes it
        # through, leaving only the (identical) total arithmetic.
        att = self.attribution
        Z = np.zeros(NCOMP) if att else None
        c_issue = Z
        c_bus = Z
        c_wbus = Z
        c_addr = Z
        c_fpu = Z
        c_sldu = Z
        writer_c: dict[str, tuple[np.ndarray, np.ndarray]] = {}
        rrel_c: dict[str, np.ndarray] = {}
        total = 0.0
        c_total = Z
        # Chain-propagation split: the forwarding floor is part of the
        # ideal prologue (Eq. (1) startup delays); the write-back/re-read
        # excess is an operand-delivery stall.
        d_chain_ideal = min(v["d_chain"], p.d_fwd)
        d_chain_stall = v["d_chain"] - d_chain_ideal

        for ins in trace.instrs:
            # ---- dependence constraints (lane side) --------------------
            raw_start = issue_t
            c_rs = c_issue
            raw_complete = 0.0
            c_rc = Z
            for s in ins.srcs:
                w = writer.get(s)
                if w is not None:
                    cf, cc = writer_c[s]
                    cand = w.first_out + v["d_chain"]
                    if cand > raw_start:
                        raw_start = cand
                        c_rs = _bump(cf, (IDEAL, d_chain_ideal),
                                     (OPR_CHAIN_DELAY, d_chain_stall))
                    cand = w.complete + v["d_chain"]
                    if cand > raw_complete:
                        raw_complete = cand
                        c_rc = _bump(cc, (IDEAL, d_chain_ideal),
                                     (OPR_CHAIN_DELAY, d_chain_stall))
            war_gate = 0.0
            c_wg = Z
            if ins.dst is not None:
                rel = reader_release.get(ins.dst)
                if rel is not None and rel > war_gate:     # WAR
                    war_gate = rel
                    c_wg = rrel_c[ins.dst]
                w = writer.get(ins.dst)
                if w is not None and w.first_out > war_gate:
                    war_gate = w.first_out                 # WAW (in order)
                    c_wg = writer_c[ins.dst][0]

            # ---- execute on resource ----------------------------------
            if ins.kind is OpKind.LOAD:
                nbytes = ins.bytes
                if ins.stride is Stride.INDEXED:
                    # Indexed loads need their index vector first (RAW).
                    dur_bus = ins.vl * (ins.sew / bpc) + ins.vl * v["idx_ovh"]
                    dur_ideal = ins.vl * (ins.sew / bpc)
                else:
                    nburst = max(1, math.ceil(nbytes / mc.burst_bytes))
                    dur_bus = nbytes / bpc + nburst * v["tx_ovh"]
                    dur_ideal = nbytes / bpc
                dur_stall = dur_bus - dur_ideal
                turn = v["rw_turn"] if (bus_last_kind is OpKind.STORE) else 0.0
                # The sequencer does not hand a load to the VLSU until its
                # WAR/WAW hazards release (§IV.B conservative blocking) —
                # under baseline release policy that is predecessor
                # *completion* + overhead; under C it is read-done, which
                # the operand/result queues (queue_adv) pull earlier.
                # Demand data always arrives `mem_latency` after its
                # request; next-VL prefetch (M) turns warm unit-stride
                # streams into prefetch-buffer hits, cutting the latency
                # out of the dependence recurrence.
                req_start, c_req = _vmax(
                    (issue_t, c_issue), (raw_start, c_rs),
                    (addr_free, c_addr),
                    (bus_free + turn,
                     c_bus if turn == 0.0
                     else _bump(c_bus, (MEM_RW_TURNAROUND, turn))),
                    (war_gate, c_wg))
                if opt.memory and ins.stride is Stride.UNIT:
                    lat = p.mem_latency if ins.first_strip else p.prefetch_hit
                elif opt.memory and ins.stride is Stride.STRIDED:
                    lat = (p.mem_latency if ins.first_strip else
                           0.5 * (p.mem_latency + p.prefetch_hit))
                else:
                    lat = p.mem_latency
                # A prefetch-buffer hit is the best any front end achieves:
                # latency up to that floor is ideal fill, the rest is
                # exposed demand latency.
                lat_ideal = lat if lat < p.prefetch_hit else p.prefetch_hit
                lat_stall = lat - lat_ideal
                data_done = req_start + lat + dur_bus
                c_dd = _bump(c_req, (IDEAL, lat_ideal + dur_ideal),
                             (MEM_DEMAND_LATENCY, lat_stall),
                             (MEM_TX_OVERHEAD, dur_stall))
                writeback_gate = war_gate
                first_out, c_fo = _vmax(
                    (req_start + lat + mc.burst_bytes / bpc,
                     _bump(c_req, (IDEAL, lat_ideal + mc.burst_bytes / bpc),
                           (MEM_DEMAND_LATENCY, lat_stall))),
                    (writeback_gate, c_wg))
                complete, c_cp = _vmax(
                    (data_done, c_dd),
                    (writeback_gate + ins.vl / epc,
                     _bump(c_wg, (IDEAL, ins.vl / epc))))
                read_done = req_start            # loads read no lane vregs
                c_rd = c_req
                busy_start = req_start
                bus_free = req_start + dur_bus
                c_bus = _bump(c_req, (IDEAL, dur_ideal),
                              (MEM_TX_OVERHEAD, dur_stall))
                addr_free = (req_start + (0.0 if opt.memory else dur_bus))
                c_addr = c_req if opt.memory else c_bus
                bus_last_kind = OpKind.LOAD
                busy_bus += dur_bus

            elif ins.kind is OpKind.STORE:
                nbytes = ins.bytes
                if ins.stride is Stride.INDEXED:
                    dur_bus = ins.vl * (ins.sew / bpc) + ins.vl * v["idx_ovh"]
                    dur_ideal = ins.vl * (ins.sew / bpc)
                else:
                    nburst = max(1, math.ceil(nbytes / mc.burst_bytes))
                    dur_bus = nbytes / bpc + nburst * v["tx_ovh"]
                    dur_ideal = nbytes / bpc
                dur_stall = dur_bus - dur_ideal
                if split_rw:
                    busy_start, c_bs = _vmax(
                        (raw_start, c_rs), (war_gate, c_wg),
                        (addr_free, c_addr), (wbus_free, c_wbus))
                    wbus_free = busy_start + dur_bus
                    c_wbus = _bump(c_bs, (IDEAL, dur_ideal),
                                   (MEM_TX_OVERHEAD, dur_stall))
                    # Separate issue path, SHARED DRAM bandwidth: the write
                    # still consumes read-channel-visible bandwidth at its
                    # drain time (no ordering block, no free bandwidth).
                    bus_free, c_bus = _vmax((bus_free, c_bus),
                                            (busy_start, c_bs))
                    bus_free = bus_free + dur_bus
                    c_bus = _bump(c_bus, (IDEAL, dur_ideal),
                                  (MEM_TX_OVERHEAD, dur_stall))
                else:
                    turn = v["rw_turn"] if (bus_last_kind is OpKind.LOAD) \
                        else 0.0
                    busy_start, c_bs = _vmax(
                        (raw_start, c_rs), (war_gate, c_wg),
                        (addr_free, c_addr),
                        (bus_free + turn,
                         c_bus if turn == 0.0
                         else _bump(c_bus, (MEM_RW_TURNAROUND, turn))))
                    # Unified path: the store holds the issue path until its
                    # data drains + commit — subsequent loads queue behind.
                    bus_free = busy_start + dur_bus + v["store_commit"]
                    c_bus = _bump(c_bs, (IDEAL, dur_ideal),
                                  (MEM_TX_OVERHEAD, dur_stall),
                                  (MEM_STORE_COMMIT, v["store_commit"]))
                # A store *completes* (retires, hazard-wise) only when the
                # memory system acknowledges the write — a full memory
                # round trip after the last data beat.  Baseline WAR
                # release waits for this (C releases at read-done instead).
                complete, c_cp = _vmax(
                    (busy_start + dur_bus + p.mem_latency,
                     _bump(c_bs, (IDEAL, dur_ideal),
                           (MEM_TX_OVERHEAD, dur_stall),
                           (MEM_STORE_COMMIT, p.mem_latency))),
                    (raw_complete, c_rc))
                first_out = complete
                c_fo = c_cp
                # Store reads its source into the store queue at lane rate,
                # bounded by queue depth vs. bus drain: any excess over the
                # lane-rate read is queue-depth run-ahead shortfall.
                t1 = busy_start + ins.vl / epc
                t2 = busy_start + dur_bus - v["queue_adv"]
                read_done = max(t1, t2)
                c_rd = _bump(c_bs, (IDEAL, ins.vl / epc))
                if t2 > t1:
                    c_rd = _bump(c_rd, (OPR_QUEUE_LIMIT, t2 - t1))
                addr_free = (busy_start + (0.0 if opt.memory else dur_bus))
                c_addr = c_bs if opt.memory else \
                    _bump(c_bs, (IDEAL, dur_ideal),
                          (MEM_TX_OVERHEAD, dur_stall))
                bus_last_kind = OpKind.STORE
                busy_bus += dur_bus

            elif ins.kind in (OpKind.COMPUTE, OpKind.REDUCE, OpKind.SLIDE):
                dur = (ins.vl / epc) * v["conflict"]
                dur_ideal = ins.vl / epc
                if ins.name.startswith("vfdiv"):
                    # Non-pipelined divider: inherent serialization neither
                    # baseline nor Ara-Opt can hide — all ideal time.
                    dur = (ins.vl / epc) * p.div_factor
                    dur_ideal = dur
                if ins.kind is OpKind.REDUCE:
                    red = math.ceil(math.log2(max(ins.vl, 2))) * mc.fu_latency
                    dur += red
                    dur_ideal += red        # reduction tree is inherent
                dur_stall = dur - dur_ideal  # VRF bank-conflict stretch
                unit_free = sldu_free if ins.kind is OpKind.SLIDE else fpu_free
                c_unit = c_sldu if ins.kind is OpKind.SLIDE else c_fpu
                busy_start, c_bs = _vmax((raw_start, c_rs),
                                         (war_gate, c_wg),
                                         (unit_free, c_unit))
                complete, c_cp = _vmax(
                    (busy_start + mc.fu_latency + dur,
                     _bump(c_bs, (IDEAL, mc.fu_latency + dur_ideal),
                           (OPR_BANK_CONFLICT, dur_stall))),
                    (raw_complete, c_rc))
                if ins.kind is OpKind.REDUCE:
                    first_out = complete                # scalar at the end
                    c_fo = c_cp
                else:
                    first_out = busy_start + mc.fu_latency
                    c_fo = _bump(c_bs, (IDEAL, mc.fu_latency))
                t1 = busy_start + ins.vl / epc
                t2 = complete - mc.fu_latency - v["queue_adv"]
                read_done = max(t1, t2)
                c_rd = _bump(c_bs, (IDEAL, ins.vl / epc))
                if t2 > t1:
                    c_rd = _bump(c_rd, (OPR_QUEUE_LIMIT, t2 - t1))
                # Unit occupancy may be held past its own duration by the
                # trailing operand-delivery constraint (raw_complete).
                t1 = busy_start + dur
                t2 = complete - mc.fu_latency
                occupancy_end = max(t1, t2)
                c_occ = _bump(c_bs, (IDEAL, dur_ideal),
                              (OPR_BANK_CONFLICT, dur_stall))
                if t2 > t1:
                    c_occ = _bump(c_occ, (OPR_CHAIN_DELAY, t2 - t1))
                if ins.kind is OpKind.SLIDE:
                    sldu_free = occupancy_end
                    c_sldu = c_occ
                else:
                    fpu_free = occupancy_end
                    c_fpu = c_occ
                    busy_fpu += ins.vl / epc            # useful compute time
            else:                                        # pragma: no cover
                raise ValueError(f"unknown kind {ins.kind}")

            t = InstrTiming(start=busy_start, first_out=first_out,
                            complete=complete, read_done=read_done,
                            ideal=c_cp[IDEAL] if att else 0.0,
                            stalls=c_cp[1:].copy() if att else None)
            timings.append(t)

            # ---- update hazard state ----------------------------------
            # Dispatch is throughput-limited (issue_gap) but NOT head-of-
            # line blocked on execution start: Ara's sequencer hands
            # instructions to per-unit queues and chaining paces them.
            issue_t = issue_t + v["issue_gap"]
            c_issue = _bump(c_issue, (DEP_ISSUE_GAP, v["issue_gap"]))
            if ins.dst is not None:
                writer[ins.dst] = t
                writer_c[ins.dst] = (c_fo, c_cp)
            if ins.srcs:
                if opt.control:
                    release, c_rel = t.read_done, c_rd
                else:
                    release = t.complete + p.war_release_ovh
                    c_rel = _bump(c_cp, (DEP_WAR_RELEASE, p.war_release_ovh))
                for s in ins.srcs:
                    if release > reader_release.get(s, 0.0):
                        reader_release[s] = release
                        rrel_c[s] = c_rel
            if complete > total:
                total = complete
                c_total = c_cp

        return SimResult(kernel=trace.name, cycles=total,
                         flops=trace.total_flops, bytes=trace.total_bytes,
                         timings=timings, busy_fpu=busy_fpu, busy_bus=busy_bus,
                         ideal=c_total[IDEAL] if att else 0.0,
                         stalls=c_total[1:].copy() if att else None)

    # ------------------------------------------------------------------
    def speedup(self, trace: KernelTrace, opt: OptConfig) -> float:
        base = self.run(trace, OptConfig.baseline())
        new = self.run(trace, opt)
        return base.cycles / new.cycles
