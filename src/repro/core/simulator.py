"""Strip-level cycle-approximate simulator of Ara / Ara-Opt.

The paper evaluates an RTL implementation; RTL is not reproducible here, so
we model the machine at vector-instruction (strip) granularity with the
microarchitectural mechanisms the paper identifies, each switchable per the
2^3 ablation (Table I):

  M — memory path.  Baseline is demand-driven: a load's DRAM latency is
      hidden only while the request stream is continuous; when the VLSU's
      result queue fills because VRF write-back is hazard-gated, back-
      pressure propagates to transaction generation ("bus-handshake stalls
      propagate back to address expansion", §IV.A) and the stream gaps,
      exposing latency.  Coupled address expansion adds per-burst overhead
      and read/write transactions interfere (turnaround).  Ara-Opt decouples
      the front end (overheads hidden, r/w separated) and next-VL prefetch
      turns warm unit-stride streams into prefetch-buffer hits.

  C — dependence & issue.  Baseline releases WAR read-occupancy only at
      *instruction completion* plus an overhead, and pays a conservative
      per-instruction issue gap.  Ara-Opt releases at *read-done* (source
      operands drained into operand queues) and issues with the dynamic
      release-aware gap.

  O — operand delivery.  Baseline routes producer->consumer values through
      the VRF (write-back + re-read: chain delay d_chain), suffers VRF
      bank-conflict stretch (paper §VI.C: gemm 14% -> 5%), and has shallow
      operand/result queues (small run-ahead).  Ara-Opt forwards results
      (d_fwd), cuts conflicts, and deepens queues (dual-source).

Timing semantics follow the ideal-chaining model of §II.C: RAW consumers
start once the producer's first results exist (chaining) and can finish no
earlier than the producer finishes plus the propagation delay.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.isa import (KernelTrace, MachineConfig, OpKind, OptConfig,
                            Stride, VInstr)


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Microarchitectural timing parameters.

    `*_base` values model baseline Ara and are calibrated once against the
    paper's Fig. 3 / Fig. 4 (core/calibration.py); opt-side values are fixed
    small constants.  VRF conflict rates come directly from the paper
    (§VI.C: gemm bank-conflict ratio 14% -> 5%).
    """
    mem_latency: float = 38.0          # demand-load latency (cycles)
    prefetch_hit: float = 4.0          # prefetch-buffer hit latency
    tx_ovh_base: float = 1.0           # per-burst overhead, coupled front end
    tx_ovh_opt: float = 0.1            # decoupled front end
    idx_ovh_base: float = 2.0          # per-element overhead, indexed access
    idx_ovh_opt: float = 1.8           # gathers defeat next-VL prefetch:
    div_factor: float = 8.0            # non-pipelined divide cycles/element
    rw_turnaround_base: float = 10.0   # read<->write bus switch penalty
    rw_turnaround_opt: float = 1.0
    store_commit_base: float = 24.0    # write-commit latency holding the
    store_commit_opt: float = 0.0      # unified baseline r/w path (§IV.A)
    issue_gap_base: float = 3.0        # cycles between issues (conservative)
    issue_gap_opt: float = 1.0         # dynamic release-aware issue
    war_release_ovh: float = 6.0       # extra cycles after completion (base)
    d_chain_base: float = 12.0         # produce->writeback->reread delay
    d_fwd: float = 2.0                 # multi-source forwarding delay
    conflict_base: float = 0.14        # VRF bank-conflict stretch (paper)
    conflict_opt: float = 0.05
    queue_adv_base: float = 48.0       # result/operand queue run-ahead (cyc)
    queue_adv_opt: float = 96.0        # deep dual-source queues


@dataclasses.dataclass
class InstrTiming:
    start: float
    first_out: float
    complete: float
    read_done: float                   # when source-operand reads finish


@dataclasses.dataclass
class SimResult:
    kernel: str
    cycles: float
    flops: int
    bytes: int
    timings: list[InstrTiming]
    busy_fpu: float = 0.0
    busy_bus: float = 0.0

    @property
    def gflops(self) -> float:
        # 1 GHz machine: flops/cycle == GFLOPS.
        return self.flops / max(self.cycles, 1e-9)

    @property
    def lane_utilization(self) -> float:
        return self.busy_fpu / max(self.cycles, 1e-9)

    @property
    def bus_utilization(self) -> float:
        return self.busy_bus / max(self.cycles, 1e-9)


class AraSimulator:
    """Simulate a kernel trace under a given optimization configuration."""

    def __init__(self, mc: MachineConfig = MachineConfig(),
                 params: SimParams = SimParams()):
        self.mc = mc
        self.p = params

    # -- per-config parameter views -----------------------------------------
    def _view(self, opt: OptConfig):
        p = self.p
        return dict(
            tx_ovh=p.tx_ovh_opt if opt.memory else p.tx_ovh_base,
            idx_ovh=p.idx_ovh_opt if opt.memory else p.idx_ovh_base,
            rw_turn=p.rw_turnaround_opt if opt.memory else p.rw_turnaround_base,
            store_commit=(p.store_commit_opt if opt.memory
                          else p.store_commit_base),
            issue_gap=p.issue_gap_opt if opt.control else p.issue_gap_base,
            d_chain=p.d_fwd if opt.operand else p.d_chain_base,
            conflict=1.0 + (p.conflict_opt if opt.operand else p.conflict_base),
            queue_adv=p.queue_adv_opt if opt.operand else p.queue_adv_base,
        )

    def run(self, trace: KernelTrace, opt: OptConfig) -> SimResult:
        mc, p = self.mc, self.p
        v = self._view(opt)
        epc = mc.elems_per_cycle
        bpc = mc.axi_bytes_per_cycle

        issue_t = 0.0                       # in-order dispatch pointer
        # Baseline: one issue path — loads queue *behind* stores that are
        # still waiting for their data (r/w not separated, §IV.A).
        # Ara-Opt: reads and writes issue on separate AXI channels.
        split_rw = opt.memory
        bus_free = 0.0                      # shared (baseline) / read chan
        wbus_free = 0.0                     # write channel (opt only)
        addr_free = 0.0                     # VLSU front-end serialization
        bus_last_kind: OpKind | None = None
        fpu_free = 0.0
        sldu_free = 0.0
        writer: dict[str, InstrTiming] = {}      # last writer per register
        reader_release: dict[str, float] = {}    # latest WAR release per reg
        timings: list[InstrTiming] = []
        busy_fpu = busy_bus = 0.0

        for ins in trace.instrs:
            # ---- dependence constraints (lane side) --------------------
            raw_start = issue_t
            raw_complete = 0.0
            for s in ins.srcs:
                w = writer.get(s)
                if w is not None:
                    raw_start = max(raw_start, w.first_out + v["d_chain"])
                    raw_complete = max(raw_complete, w.complete + v["d_chain"])
            war_gate = 0.0
            if ins.dst is not None:
                rel = reader_release.get(ins.dst)
                if rel is not None:
                    war_gate = max(war_gate, rel)          # WAR
                w = writer.get(ins.dst)
                if w is not None:
                    war_gate = max(war_gate, w.first_out)  # WAW (in order)

            # ---- execute on resource ----------------------------------
            if ins.kind is OpKind.LOAD:
                nbytes = ins.bytes
                if ins.stride is Stride.INDEXED:
                    # Indexed loads need their index vector first (RAW).
                    dur_bus = ins.vl * (ins.sew / bpc) + ins.vl * v["idx_ovh"]
                else:
                    nburst = max(1, math.ceil(nbytes / mc.burst_bytes))
                    dur_bus = nbytes / bpc + nburst * v["tx_ovh"]
                turn = v["rw_turn"] if (bus_last_kind is OpKind.STORE) else 0.0
                # The sequencer does not hand a load to the VLSU until its
                # WAR/WAW hazards release (§IV.B conservative blocking) —
                # under baseline release policy that is predecessor
                # *completion* + overhead; under C it is read-done, which
                # the operand/result queues (queue_adv) pull earlier.
                # Demand data always arrives `mem_latency` after its
                # request; next-VL prefetch (M) turns warm unit-stride
                # streams into prefetch-buffer hits, cutting the latency
                # out of the dependence recurrence.
                req_start = max(issue_t, raw_start, addr_free,
                                bus_free + turn, war_gate)
                if opt.memory and ins.stride is Stride.UNIT:
                    lat = p.mem_latency if ins.first_strip else p.prefetch_hit
                elif opt.memory and ins.stride is Stride.STRIDED:
                    lat = (p.mem_latency if ins.first_strip else
                           0.5 * (p.mem_latency + p.prefetch_hit))
                else:
                    lat = p.mem_latency
                data_done = req_start + lat + dur_bus
                writeback_gate = war_gate
                first_out = max(req_start + lat + mc.burst_bytes / bpc,
                                writeback_gate)
                complete = max(data_done, writeback_gate + ins.vl / epc)
                read_done = req_start            # loads read no lane vregs
                busy_start = req_start
                bus_free = req_start + dur_bus
                addr_free = (req_start + (0.0 if opt.memory else dur_bus))
                bus_last_kind = OpKind.LOAD
                busy_bus += dur_bus

            elif ins.kind is OpKind.STORE:
                nbytes = ins.bytes
                if ins.stride is Stride.INDEXED:
                    dur_bus = ins.vl * (ins.sew / bpc) + ins.vl * v["idx_ovh"]
                else:
                    nburst = max(1, math.ceil(nbytes / mc.burst_bytes))
                    dur_bus = nbytes / bpc + nburst * v["tx_ovh"]
                if split_rw:
                    busy_start = max(raw_start, war_gate, addr_free,
                                     wbus_free)
                    wbus_free = busy_start + dur_bus
                    # Separate issue path, SHARED DRAM bandwidth: the write
                    # still consumes read-channel-visible bandwidth at its
                    # drain time (no ordering block, no free bandwidth).
                    bus_free = max(bus_free, busy_start) + dur_bus
                else:
                    turn = v["rw_turn"] if (bus_last_kind is OpKind.LOAD) \
                        else 0.0
                    busy_start = max(raw_start, war_gate, addr_free,
                                     bus_free + turn)
                    # Unified path: the store holds the issue path until its
                    # data drains + commit — subsequent loads queue behind.
                    bus_free = busy_start + dur_bus + v["store_commit"]
                # A store *completes* (retires, hazard-wise) only when the
                # memory system acknowledges the write — a full memory
                # round trip after the last data beat.  Baseline WAR
                # release waits for this (C releases at read-done instead).
                complete = max(busy_start + dur_bus + p.mem_latency,
                               raw_complete)
                first_out = complete
                # Store reads its source into the store queue at lane rate,
                # bounded by queue depth vs. bus drain.
                read_done = max(busy_start + ins.vl / epc,
                                busy_start + dur_bus - v["queue_adv"])
                addr_free = (busy_start + (0.0 if opt.memory else dur_bus))
                bus_last_kind = OpKind.STORE
                busy_bus += dur_bus

            elif ins.kind in (OpKind.COMPUTE, OpKind.REDUCE, OpKind.SLIDE):
                dur = (ins.vl / epc) * v["conflict"]
                if ins.name.startswith("vfdiv"):
                    # Non-pipelined divider: inherent serialization neither
                    # baseline nor Ara-Opt can hide.
                    dur = (ins.vl / epc) * p.div_factor
                if ins.kind is OpKind.REDUCE:
                    dur += math.ceil(math.log2(max(ins.vl, 2))) * mc.fu_latency
                unit_free = sldu_free if ins.kind is OpKind.SLIDE else fpu_free
                busy_start = max(raw_start, war_gate, unit_free)
                complete = max(busy_start + mc.fu_latency + dur, raw_complete)
                if ins.kind is OpKind.REDUCE:
                    first_out = complete                # scalar at the end
                else:
                    first_out = busy_start + mc.fu_latency
                read_done = max(busy_start + ins.vl / epc,
                                complete - mc.fu_latency - v["queue_adv"])
                occupancy_end = max(busy_start + dur, complete - mc.fu_latency)
                if ins.kind is OpKind.SLIDE:
                    sldu_free = occupancy_end
                else:
                    fpu_free = occupancy_end
                    busy_fpu += ins.vl / epc            # useful compute time
            else:                                        # pragma: no cover
                raise ValueError(f"unknown kind {ins.kind}")

            t = InstrTiming(start=busy_start, first_out=first_out,
                            complete=complete, read_done=read_done)
            timings.append(t)

            # ---- update hazard state ----------------------------------
            # Dispatch is throughput-limited (issue_gap) but NOT head-of-
            # line blocked on execution start: Ara's sequencer hands
            # instructions to per-unit queues and chaining paces them.
            issue_t = issue_t + v["issue_gap"]
            if ins.dst is not None:
                writer[ins.dst] = t
            for s in ins.srcs:
                release = (t.read_done if opt.control
                           else t.complete + p.war_release_ovh)
                reader_release[s] = max(reader_release.get(s, 0.0), release)

        total = max((t.complete for t in timings), default=0.0)
        return SimResult(kernel=trace.name, cycles=total,
                         flops=trace.total_flops, bytes=trace.total_bytes,
                         timings=timings, busy_fpu=busy_fpu, busy_bus=busy_bus)

    # ------------------------------------------------------------------
    def speedup(self, trace: KernelTrace, opt: OptConfig) -> float:
        base = self.run(trace, OptConfig.baseline())
        new = self.run(trace, opt)
        return base.cycles / new.cycles
