"""Ideal multi-lane chaining model (paper §II.C, Eq. (1)-(5)).

The model decomposes execution of a dependent vector-instruction chain into
prologue startup, steady-state progression, and tail drain:

    p_N      = sum_i d_{i,i+1} + T_fill                             (1)
    T_steady = ceil(VL / L)                                          (2)
    T_ideal  = p_N + T_steady + T_tail                               (3)
    T_real   = (p_N + dp) + T_steady * II_eff + (T_tail + dt)        (4)
    dT       = dp + T_steady * (II_eff - 1) + dt                     (5)

It is used three ways in this framework:
  * as the analytical reference the simulator is measured against,
  * to attribute a simulated/real execution into (dp, II_eff, dt),
  * to model TPU pipeline prologue/steady/tail (Pallas grid pipelines and
    pipeline-parallel schedules share exactly this decomposition).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ChainSpec:
    """A dependent chain of N stages over VL elements on L lanes."""
    startup_delays: tuple[float, ...]   # d_{i,i+1}, length N-1
    fill_time: float                    # T_fill
    tail_time: float                    # T_tail
    vl: int
    lanes: int

    @property
    def prologue(self) -> float:
        """Eq. (1): ideal prologue p_N."""
        return sum(self.startup_delays) + self.fill_time

    @property
    def steady_ideal(self) -> float:
        """Eq. (2): ideal steady-state time (one element group / cycle)."""
        return math.ceil(self.vl / self.lanes)

    @property
    def t_ideal(self) -> float:
        """Eq. (3)."""
        return self.prologue + self.steady_ideal + self.tail_time


@dataclasses.dataclass(frozen=True)
class Deviation:
    """Real-execution deviation terms of Eq. (4)."""
    dp: float          # additional prologue delay
    ii_eff: float      # effective initiation interval (cycles/element group)
    dt: float          # additional tail overhead

    def t_real(self, spec: ChainSpec) -> float:
        """Eq. (4)."""
        return ((spec.prologue + self.dp)
                + spec.steady_ideal * self.ii_eff
                + (spec.tail_time + self.dt))

    def loss(self, spec: ChainSpec) -> float:
        """Eq. (5): dT = dp + T_steady*(II_eff - 1) + dt."""
        return (self.dp + spec.steady_ideal * (self.ii_eff - 1.0) + self.dt)


IDEAL = Deviation(dp=0.0, ii_eff=1.0, dt=0.0)


def attribute(spec: ChainSpec, t_real: float, prologue_real: float,
              tail_real: float) -> Deviation:
    """Back out (dp, II_eff, dt) from measured phase times.

    Given a measured total split into (prologue_real, steady_real,
    tail_real), returns the deviation triple such that
    ``Deviation.t_real(spec) == t_real`` exactly.
    """
    dp = prologue_real - spec.prologue
    dt = tail_real - spec.tail_time
    steady_real = t_real - prologue_real - tail_real
    ii_eff = steady_real / max(spec.steady_ideal, 1e-12)
    return Deviation(dp=dp, ii_eff=ii_eff, dt=dt)


def pipeline_spec(num_stages: int, per_stage_delay: float, num_items: int,
                  item_time: float, tail: float | None = None) -> ChainSpec:
    """Chaining spec for a software pipeline (Pallas grid / PP schedule).

    A double-buffered Pallas kernel over G grid steps, or a pipeline-parallel
    schedule over M microbatches, is the same object as the paper's chain:
    prologue = stage fill, steady state = one item per interval, tail =
    drain.  `item_time` plays the role of 1/L (time per element group).
    """
    delays = tuple([per_stage_delay] * max(num_stages - 1, 0))
    return ChainSpec(startup_delays=delays,
                     fill_time=per_stage_delay,
                     tail_time=per_stage_delay if tail is None else tail,
                     vl=num_items,
                     lanes=max(int(round(1.0 / item_time)), 1)
                     if item_time <= 1.0 else 1)


def pipeline_efficiency(num_items: int, num_stages: int) -> float:
    """Steady-state fraction of an ideal chained pipeline:
    items / (items + stages - 1).  The classic bubble formula — identical
    in form to T_steady / T_ideal with unit delays."""
    return num_items / float(num_items + num_stages - 1)


def ii_eff_from_rates(consume_rate: float,
                      supply_rates: Sequence[float]) -> float:
    """Steady-state II_eff when progression is gated by the slowest of the
    consumer and its suppliers (paper §IV: II_eff > 1 whenever data supply,
    dependence release, or operand delivery falls behind the lanes)."""
    rates = [consume_rate, *supply_rates]
    slowest = min(r for r in rates if r > 0)
    return consume_rate / slowest
