"""Roofline models: the paper's Ara roofline (Fig. 4) and the TPU v5e
roofline used by the dry-run analysis (EXPERIMENTS.md §Roofline).

Paper normalization:  P_ideal = min(P_peak, BW * OI),
gap-closed ratio     = (P_opt - P_base) / (P_ideal - P_base).

TPU three-term model (per device):
    compute term    = HLO_FLOPs / peak_FLOPs
    memory term     = HLO_bytes / HBM_bw
    collective term = collective_bytes / ICI_bw
"""
from __future__ import annotations

import dataclasses


# --- Ara side (paper §VI.B) -------------------------------------------------

ARA_PEAK_GFLOPS = 16.0      # GFLOPS (4 lanes, fp32 FMA, 1 GHz)
ARA_PEAK_BW = 16.0          # GB/s (128-bit AXI @ 1 GHz)


def p_ideal(oi: float, peak_gflops: float = ARA_PEAK_GFLOPS,
            bw_gbs: float = ARA_PEAK_BW) -> float:
    """Roofline bound in GFLOPS for operational intensity `oi` (flops/byte)."""
    return min(peak_gflops, bw_gbs * oi)


def normalized(perf_gflops: float, oi: float, **kw) -> float:
    return perf_gflops / p_ideal(oi, **kw)


def gap_closed(base_gflops: float, opt_gflops: float, oi: float,
               **kw) -> float:
    """Fraction of the baseline->roofline gap recovered by the optimization."""
    ideal = p_ideal(oi, **kw)
    gap = ideal - base_gflops
    if gap <= 0:
        return 1.0
    return (opt_gflops - base_gflops) / gap


# --- TPU side (dry-run §Roofline) -------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """Hardware constants supplied by the brief (TPU v5e-class chip)."""
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link (brief: ~50 GB/s)


TPU_V5E = TPUSpec()


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Three-term roofline for one compiled (arch x shape x mesh) cell.

    All inputs are per-device quantities (XLA cost_analysis on an SPMD
    executable reports the per-device partitioned program).
    """
    flops: float                 # HLO flops per device
    hbm_bytes: float             # HLO bytes accessed per device
    collective_bytes: float      # summed collective operand bytes per device
    spec: TPUSpec = TPU_V5E

    @property
    def compute_s(self) -> float:
        return self.flops / self.spec.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.spec.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.spec.ici_bw

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate: the dominant term (perfect overlap
        of the other two is the optimistic bound we climb toward)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def step_time_serial_s(self) -> float:
        """Pessimistic no-overlap bound."""
        return self.compute_s + self.memory_s + self.collective_s

    def roofline_fraction(self, model_flops_per_device: float) -> float:
        """Fraction of peak sustained on *useful* model FLOPs if the step
        runs at the dominant-term time: the §Perf score."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return (model_flops_per_device / t) / self.spec.peak_flops

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound": self.bound,
            "step_time_s": self.step_time_s,
        }


def model_flops_training(n_params: float, n_tokens: float) -> float:
    """6*N*D rule (fwd 2ND + bwd 4ND) for dense training; for MoE pass
    active params."""
    return 6.0 * n_params * n_tokens

def model_flops_inference(n_params: float, n_tokens: float) -> float:
    """2*N*D for a forward pass (prefill) or per decoded token set."""
    return 2.0 * n_params * n_tokens
