"""Stall-category vocabulary for deviation attribution (paper §II.C, §IV).

The paper explains every cycle a kernel loses against the ideal chaining
model through three critical paths; the simulators decompose each timing
value into an *ideal* component plus nine stall categories along those
paths:

  memory-side supply      demand latency exposed beyond a prefetch hit,
                          per-transaction overhead (burst/index expansion),
                          read<->write bus turnaround, and store-commit
                          round trips holding the unified path (§IV.A);
  dependence & issue      conservative inter-instruction issue gaps and
                          WAR read-occupancy released only at completion
                          plus overhead (§IV.B);
  operand delivery        producer->consumer chain delay beyond the
                          forwarding floor, VRF bank-conflict stretch, and
                          shallow operand/result queues limiting run-ahead
                          (§IV.C, §VI.C).

Every tracked absolute time T carries a component vector c of length
``NCOMP`` with ``c[IDEAL] + c[1:].sum() == T`` (to float64 resolution);
`repro.core.simulator` and `repro.core.batch_sim` maintain the vectors
through the timing recurrence, `repro.analysis` consumes them.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

# Component indices.  Index 0 is the ideal-time component; 1..9 are the
# stall categories (``STALL_CATEGORIES[i - 1]`` names component ``i``).
IDEAL = 0
MEM_DEMAND_LATENCY = 1
MEM_TX_OVERHEAD = 2
MEM_RW_TURNAROUND = 3
MEM_STORE_COMMIT = 4
DEP_ISSUE_GAP = 5
DEP_WAR_RELEASE = 6
OPR_CHAIN_DELAY = 7
OPR_BANK_CONFLICT = 8
OPR_QUEUE_LIMIT = 9
NCOMP = 10

#: Stall-category names, ordered to match component indices 1..9.
STALL_CATEGORIES: tuple[str, ...] = (
    "mem_demand_latency",
    "mem_tx_overhead",
    "mem_rw_turnaround",
    "mem_store_commit",
    "dep_issue_gap",
    "dep_war_release",
    "opr_chain_delay",
    "opr_bank_conflict",
    "opr_queue_limit",
)

#: The paper's three critical paths -> stall categories on that path.
CRITICAL_PATHS: dict[str, tuple[str, ...]] = {
    "mem_supply": ("mem_demand_latency", "mem_tx_overhead",
                   "mem_rw_turnaround", "mem_store_commit"),
    "dep_issue": ("dep_issue_gap", "dep_war_release"),
    "operand": ("opr_chain_delay", "opr_bank_conflict", "opr_queue_limit"),
}

_CAT_INDEX = {name: i for i, name in enumerate(STALL_CATEGORIES)}

#: Per-path index lists into a 9-long stall vector.
PATH_INDICES: dict[str, tuple[int, ...]] = {
    path: tuple(_CAT_INDEX[c] for c in cats)
    for path, cats in CRITICAL_PATHS.items()
}

#: Critical-path names in `CRITICAL_PATHS` order (stable row order for
#: `PATH_MATRIX` / `path_sums`).
PATH_NAMES: tuple[str, ...] = tuple(CRITICAL_PATHS)

#: `(paths, categories)` 0/1 indicator matrix, rows ordered like
#: `PATH_NAMES`, columns like `STALL_CATEGORIES`.  ``stalls @
#: PATH_MATRIX.T`` collapses a `(..., 9)` stall tensor to `(..., 3)`
#: per-path sums in one matmul — grid-shaped analyses and the batched
#: calibration objective use this instead of per-cell python loops.
PATH_MATRIX: np.ndarray = np.zeros(
    (len(PATH_NAMES), len(STALL_CATEGORIES)), np.float64)
for _pi, _path in enumerate(PATH_NAMES):
    for _ci in PATH_INDICES[_path]:
        PATH_MATRIX[_pi, _ci] = 1.0
PATH_MATRIX.setflags(write=False)


def path_sums(stalls: Sequence[float] | np.ndarray) -> np.ndarray:
    """Collapse a `(..., 9)` stall tensor to `(..., 3)` critical-path sums
    (trailing axis ordered like `PATH_NAMES`).  Vectorized counterpart of
    `group_stalls` for batched grids."""
    vec = np.asarray(stalls, np.float64)
    if vec.shape[-1] != len(STALL_CATEGORIES):
        raise ValueError(f"expected trailing axis of "
                         f"{len(STALL_CATEGORIES)}, got {vec.shape[-1]}")
    return vec @ PATH_MATRIX.T


def stall_dict(stalls: Sequence[float] | np.ndarray) -> dict[str, float]:
    """Name the entries of a 9-long stall vector."""
    vec = np.asarray(stalls, np.float64)
    if vec.shape[-1] != len(STALL_CATEGORIES):
        raise ValueError(f"expected {len(STALL_CATEGORIES)} stall entries, "
                         f"got {vec.shape[-1]}")
    return {name: float(vec[..., i])
            for i, name in enumerate(STALL_CATEGORIES)}


def group_stalls(stalls: Sequence[float] | np.ndarray) -> dict[str, float]:
    """Sum a stall vector (trailing axis = 9 categories) per critical path."""
    vec = np.asarray(stalls, np.float64)
    return {path: float(vec[..., list(idx)].sum(axis=-1))
            if vec.ndim == 1 else vec[..., list(idx)].sum(axis=-1)
            for path, idx in PATH_INDICES.items()}


def top_sources(stalls: Sequence[float] | np.ndarray,
                n: int = 2) -> list[tuple[str, float]]:
    """The `n` largest stall categories of a 9-long vector, descending."""
    vec = np.asarray(stalls, np.float64)
    order = np.argsort(vec)[::-1][:n]
    return [(STALL_CATEGORIES[i], float(vec[i])) for i in order]


def top_paths(stalls: Sequence[float] | np.ndarray,
              n: int = 2) -> list[tuple[str, float]]:
    """The `n` critical paths with the largest summed stall, descending."""
    groups = group_stalls(np.asarray(stalls, np.float64))
    ranked = sorted(groups.items(), key=lambda kv: kv[1], reverse=True)
    return [(path, float(val)) for path, val in ranked[:n]]


def path_of(category: str) -> str:
    """Critical path a stall category belongs to."""
    for path, cats in CRITICAL_PATHS.items():
        if category in cats:
            return path
    raise KeyError(category)


def check_invariant(ideal: float, stalls: Sequence[float] | np.ndarray,
                    measured: float, rel: float = 1e-9,
                    abs_tol: float = 1e-6) -> bool:
    """``ideal + sum(stalls) == measured`` to float64 resolution."""
    total = float(ideal) + float(np.sum(stalls))
    return abs(total - measured) <= max(abs_tol, rel * abs(measured))


def as_row(ideal: float, stalls: Sequence[float] | np.ndarray,
           measured: float) -> dict[str, float]:
    """Flatten one attribution into CSV-friendly columns."""
    row: dict[str, float] = {"cycles": float(measured),
                             "ideal": float(ideal)}
    row.update(stall_dict(stalls))
    for path, val in group_stalls(stalls).items():
        row[path] = float(val)
    return row


def zero_components(*shape: int) -> np.ndarray:
    """A fresh all-zero component vector/tensor with trailing NCOMP axis."""
    return np.zeros((*shape, NCOMP), np.float64)


__all__ = [
    "IDEAL", "MEM_DEMAND_LATENCY", "MEM_TX_OVERHEAD", "MEM_RW_TURNAROUND",
    "MEM_STORE_COMMIT", "DEP_ISSUE_GAP", "DEP_WAR_RELEASE",
    "OPR_CHAIN_DELAY", "OPR_BANK_CONFLICT", "OPR_QUEUE_LIMIT", "NCOMP",
    "STALL_CATEGORIES", "CRITICAL_PATHS", "PATH_INDICES", "PATH_NAMES",
    "PATH_MATRIX", "path_sums", "stall_dict", "group_stalls",
    "top_sources", "top_paths", "path_of", "check_invariant", "as_row",
    "zero_components",
]
