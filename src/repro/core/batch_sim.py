"""Batched ablation-sweep engine for the Ara simulator.

`AraSimulator.run` walks one `(kernel, opt, params)` cell at a time in
scalar Python; the paper's artifacts (Fig. 3-5, Table I/II) and the
calibration search all evaluate *grids* of such cells over the same traces.
This module evaluates the full `(kernel x ablation x SimParams)` grid as a
stacked array program:

  * traces are padded into `(B, max_instrs)` struct-of-arrays form
    (`repro.core.traces.stack_traces`);
  * the per-instruction timing recurrence of `AraSimulator.run` is
    refactored into a pure per-step transition (`hazard state -> hazard
    state`) that is scanned over the instruction axis and broadcast over a
    `width` axis holding every `(OptConfig, SimParams)` cell;
  * register hazard state becomes dense `(regs, width)` arrays instead of
    per-name dicts, because `stack_traces` interns register names.

Two backends:

  * ``numpy``  — float64, mirrors the scalar simulator operation-for-
    operation, so cycles match `AraSimulator.run` bit-for-bit.  The scan
    runs as a Python loop over instructions with all `(opt, params)` cells
    advanced per step; wall-clock win grows with grid width (calibration
    batches hundreds of candidates).
  * ``jax``    — the same step as a traced function under `lax.scan` over
    the padded instruction axis, all `(B, width)` cells in one compiled
    program (float64 via `jax.experimental.enable_x64`).  Best for large
    fixed-shape sweeps where compile time amortizes.

A third execution strategy, ``method="assoc"`` (jax-only, implemented in
`repro.core.assoc_sim`), recasts the same recurrence as composable
max-plus transfer matrices and runs `jax.lax.associative_scan` over the
instruction axis for log-depth evaluation.  The public entrypoint for
choosing among all of these is `repro.core.api.simulate` — the former
`run` / `sweep` deprecation shims are gone (they lasted exactly one PR;
docs/architecture.md keeps the call mapping).

Deviation attribution (``attribution=True``): the scan carries the same
component vectors as `AraSimulator.run` — every hazard state array gains a
trailing `repro.core.stalls.NCOMP` axis that follows the identical max/+
dataflow — so the whole grid yields `(B, O, P)` ideal and `(B, O, P, 9)`
stall tensors in one batched pass.  The numpy backend is bit-exact against
the scalar simulator's accounting; the jax backend carries the same
`(B, W, NCOMP)` component state through `lax.scan` (``jnp.where`` on the
binding-argument index replaces the scalar adoption branches, keeping the
compiled program a single scan) and matches numpy to float64 allclose,
with ``ideal + sum(stalls) == cycles`` holding to float64 resolution.

Both backends additionally report the phase observables that
`repro.analysis.attribution.phase_decompose_grid` needs to back out the
paper's ``(dp, II_eff, dt)`` deviation triple per cell: the earliest lane
``first_out`` (prologue end), the first instruction's ``first_out``
(fallback for lane-free traces), and the finishing instruction's start
(tail begin).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.isa import MachineConfig, OptConfig
from repro.core.simulator import SimParams
from repro.core.stalls import (DEP_ISSUE_GAP, DEP_WAR_RELEASE, IDEAL,
                               MEM_DEMAND_LATENCY, MEM_RW_TURNAROUND,
                               MEM_STORE_COMMIT, MEM_TX_OVERHEAD, NCOMP,
                               OPR_BANK_CONFLICT, OPR_CHAIN_DELAY,
                               OPR_QUEUE_LIMIT)
from repro.core.traces import PAD, StackedTraces
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

_LOAD, _STORE, _COMPUTE, _REDUCE, _SLIDE = 0, 1, 2, 3, 4
_UNIT, _STRIDED, _INDEXED = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class ParamView:
    """Per-cell parameter views, one array entry per `(opt, params)` cell.

    This is the batched analogue of `AraSimulator._view`: every field is a
    `(width,)` float64 array (bools for the opt-class flags).
    """
    mem_latency: np.ndarray
    prefetch_hit: np.ndarray
    div_factor: np.ndarray
    war_release_ovh: np.ndarray
    tx_ovh: np.ndarray
    idx_ovh: np.ndarray
    rw_turn: np.ndarray
    store_commit: np.ndarray
    issue_gap: np.ndarray
    d_chain: np.ndarray
    conflict: np.ndarray
    queue_adv: np.ndarray
    opt_memory: np.ndarray             # bool: M class (also r/w split)
    opt_control: np.ndarray            # bool: C class
    d_fwd: np.ndarray                  # forwarding floor (attribution split)

    @property
    def width(self) -> int:
        return len(self.mem_latency)


def stack_params(params: Sequence[SimParams]) -> dict[str, np.ndarray]:
    """Stack a params axis into struct-of-arrays form: one `(P,)` float64
    column per `SimParams` field.

    This is the wide-axis analogue of `stack_traces` for the P axis —
    sensitivity sweeps build hundreds-to-thousands of `SimParams`
    variants and every per-cell view below is then a vectorized select
    over these columns instead of a Python loop over cells.
    """
    cols = {f.name: np.empty(len(params), np.float64)
            for f in dataclasses.fields(SimParams)}
    for pi, p in enumerate(params):
        for name, col in cols.items():
            col[pi] = getattr(p, name)
    return cols


def make_views(opts: Sequence[OptConfig],
               params: Sequence[SimParams]) -> ParamView:
    """Cross `opts` x `params` into flat per-cell views (opt-major).

    Built from `stack_params` columns: each view field is one
    `np.where` select over the `(O, P)` broadcast, so wide params axes
    never loop per cell.  Values are identical (bit-for-bit) to the
    per-cell conditional expressions of `AraSimulator._view`.
    """
    sp = stack_params(params)
    O, P = len(opts), len(params)
    om = np.fromiter((o.memory for o in opts), bool, O)
    oc = np.fromiter((o.control for o in opts), bool, O)
    oo = np.fromiter((o.operand for o in opts), bool, O)

    def cross(name):                       # (P,) -> (O*P,) opt-major
        return np.broadcast_to(sp[name], (O, P)).ravel()

    def pick(flag, opt_name, base_name):   # per-opt-class select
        return np.where(flag[:, None], sp[opt_name][None, :],
                        sp[base_name][None, :]).ravel()

    return ParamView(
        mem_latency=cross("mem_latency"),
        prefetch_hit=cross("prefetch_hit"),
        div_factor=cross("div_factor"),
        war_release_ovh=cross("war_release_ovh"),
        tx_ovh=pick(om, "tx_ovh_opt", "tx_ovh_base"),
        idx_ovh=pick(om, "idx_ovh_opt", "idx_ovh_base"),
        rw_turn=pick(om, "rw_turnaround_opt", "rw_turnaround_base"),
        store_commit=pick(om, "store_commit_opt", "store_commit_base"),
        issue_gap=pick(oc, "issue_gap_opt", "issue_gap_base"),
        d_chain=pick(oo, "d_fwd", "d_chain_base"),
        conflict=1.0 + pick(oo, "conflict_opt", "conflict_base"),
        queue_adv=pick(oo, "queue_adv_opt", "queue_adv_base"),
        opt_memory=np.repeat(om, P),
        opt_control=np.repeat(oc, P),
        d_fwd=cross("d_fwd"),
    )


@dataclasses.dataclass(frozen=True)
class BatchResult:
    """Grid results: axis 0 = trace, axis 1 = opt, axis 2 = params."""
    names: tuple[str, ...]
    cycles: np.ndarray                 # (B, O, P)
    busy_fpu: np.ndarray               # (B, O, P)
    busy_bus: np.ndarray               # (B, O, P)
    flops: np.ndarray                  # (B,)
    bytes: np.ndarray                  # (B,)
    ideal: np.ndarray | None = None    # (B, O, P) ideal part of cycles
    stalls: np.ndarray | None = None   # (B, O, P, 9) stall categories
    # Phase observables for `analysis.attribution.phase_decompose_grid`:
    lane_first_out: np.ndarray | None = None   # (B, O, P) min lane first_out
    first_first_out: np.ndarray | None = None  # (B, O, P) instr 0 first_out
    finish_start: np.ndarray | None = None     # (B, O, P) finisher's start

    @property
    def gflops(self) -> np.ndarray:
        return self.flops[:, None, None] / np.maximum(self.cycles, 1e-9)

    @property
    def lane_utilization(self) -> np.ndarray:
        return self.busy_fpu / np.maximum(self.cycles, 1e-9)

    @property
    def bus_utilization(self) -> np.ndarray:
        return self.busy_bus / np.maximum(self.cycles, 1e-9)

    def speedup_vs(self, base_opt: int = 0) -> np.ndarray:
        """Per-cell speedup relative to opt column `base_opt`."""
        return self.cycles[:, base_opt:base_opt + 1, :] / self.cycles


def _per_cell_fields(res: BatchResult) -> list[str]:
    """BatchResult fields carrying a params axis: every array of rank
    >= 3 is `(B, O, P, ...)` by construction, so chunk slicing/concat
    derives the list instead of hardcoding it — a future per-cell
    field (as PR 2 added ideal/stalls) is chunked automatically."""
    return [f.name for f in dataclasses.fields(res)
            if isinstance(getattr(res, f.name), np.ndarray)
            and getattr(res, f.name).ndim >= 3]


def _slice_p(res: BatchResult, n: int) -> BatchResult:
    """Drop padded params columns (keep the first `n` of axis 2/P)."""
    return dataclasses.replace(
        res, **{name: getattr(res, name)[:, :, :n]
                for name in _per_cell_fields(res)})


def _concat_p(parts: Sequence[BatchResult]) -> BatchResult:
    """Concatenate chunked results along the params axis (axis 2)."""
    return dataclasses.replace(
        parts[0],
        **{name: np.concatenate([getattr(p, name) for p in parts],
                                axis=2)
           for name in _per_cell_fields(parts[0])})


class BatchAraSimulator:
    """Evaluate `(traces x opts x params)` grids in one batched call."""

    def __init__(self, mc: MachineConfig = MachineConfig()):
        self.mc = mc
        # Compiled jax programs, keyed by attribution flag (the component-
        # carrying scan is a different program than the plain one).
        self._jax_fns: dict[bool, object] = {}
        # Shape signatures already traced+compiled by jit: first call on
        # a fresh signature is reported as the "compile" span, later
        # calls as "execute" (the first-call vs cached-callable split).
        self._jax_seen: set[tuple] = set()
        # Device-resident trace fields, keyed by stack identity: a
        # chunked-P run re-dispatches the same (large, read-only) trace
        # arrays once per chunk, so they are uploaded once and the
        # device buffers reused across every chunk.  (The per-chunk
        # view buffers cannot be donated to outputs — their (W,) shape
        # never aliases the (B, W) results, XLA would just warn — so
        # buffer reuse on the trace side is where the transfer win is.)
        self._dev_fields: dict[int, tuple] = {}

    # -- engine dispatch ----------------------------------------------------
    # (`repro.core.api.simulate` is the public entrypoint; the former
    # `run`/`sweep` deprecation shims were dropped after their one-PR
    # grace period — docs/architecture.md keeps the call mapping.)
    def _run(self, stacked: StackedTraces, opts: Sequence[OptConfig],
             params: SimParams | Sequence[SimParams] = SimParams(),
             backend: str = "numpy",
             attribution: bool = False,
             p_chunk: int | None = None,
             method: str = "scan",
             assoc_chunk: int | None = None,
             use_pallas: bool = False,
             shard: str = "none",
             _chunk_lo: int = 0) -> BatchResult:
        """Evaluate the `(trace x opt x params)` grid.

        ``method`` picks the instruction-axis algorithm: ``scan`` is the
        sequential recurrence (both backends); ``assoc`` the log-depth
        max-plus associative-scan engine (`repro.core.assoc_sim`,
        jax-only; ``assoc_chunk``/``use_pallas`` tune it).

        `p_chunk` splits the params axis into chunks of at most that
        width so `large`-profile grids with hundreds-to-thousands of
        `SimParams` variants fit memory (state is `(B, R, W, NCOMP)` with
        `W = O * P`); results are concatenated back and bit-identical to
        the unchunked run (chunks are independent grid columns).  On the
        jax backend the last chunk is padded up to `p_chunk` (and the
        padding sliced off) so every chunk reuses one compiled shape,
        and the chunks run as an **async pipeline**: every chunk is
        dispatched before any result is pulled back to the host, so
        device execution of chunk `k` overlaps host-side view
        construction of chunk `k+1` and the host blocks exactly once.

        ``shard="devices"`` (jax scan only) runs each dispatch through
        `repro.launch.mesh.sharded_sweep`, splitting the params columns
        across the local devices under `shard_map`.
        """
        if isinstance(params, SimParams):
            params = [params]
        opts = list(opts)
        params = list(params)
        if method not in ("scan", "assoc"):
            raise ValueError(f"unknown method {method!r}")
        if method == "assoc" and backend != "jax":
            raise ValueError("method='assoc' requires backend='jax' "
                             "(the max-plus engine is jax-only)")
        if shard not in ("none", "devices"):
            raise ValueError(f"unknown shard mode {shard!r}")
        if shard == "devices" and (backend != "jax" or method != "scan"):
            raise ValueError("shard='devices' requires backend='jax' "
                             "and method='scan'")
        if p_chunk is not None and p_chunk < 1:
            raise ValueError(f"p_chunk must be >= 1, got {p_chunk}")
        if p_chunk is not None and len(params) > p_chunk:
            if backend == "jax" and method == "scan":
                return self._run_pipelined(stacked, opts, params,
                                           attribution, p_chunk, shard)
            parts = []
            for lo in range(0, len(params), p_chunk):
                chunk = params[lo:lo + p_chunk]
                pad = p_chunk - len(chunk) if backend == "jax" else 0
                part = self._run(stacked, opts,
                                 chunk + [chunk[-1]] * pad,
                                 backend=backend,
                                 attribution=attribution,
                                 method=method, assoc_chunk=assoc_chunk,
                                 use_pallas=use_pallas,
                                 _chunk_lo=lo)
                parts.append(_slice_p(part, len(chunk)) if pad else part)
            return _concat_p(parts)
        view = make_views(opts, params)
        # One exec.p_chunk span per executed params slice — an unchunked
        # run is a single chunk at lo=0, so the span tree has the same
        # shape either way (docs/observability.md).
        with obs_spans.span("exec.p_chunk", lo=_chunk_lo,
                            size=len(params), width=view.width):
            if method == "assoc":
                from repro.core import assoc_sim
                outs = assoc_sim.run_assoc(
                    self.mc, stacked, view, attribution,
                    chunk=assoc_chunk, use_pallas=use_pallas)
            elif backend == "numpy":
                with obs_spans.span("exec.numpy.scan",
                                    batch=stacked.batch,
                                    width=view.width):
                    outs = self._run_numpy(stacked, view, attribution)
            elif backend == "jax":
                raw = self._dispatch_jax(stacked, view, attribution,
                                         n_opts=len(opts), shard=shard,
                                         block=True)
                outs = _materialize_jax(raw, attribution)
            else:
                raise ValueError(f"unknown backend {backend!r}")
        return self._package(stacked, outs, len(opts), len(params))

    def _package(self, stacked: StackedTraces, outs, n_opts: int,
                 n_params: int) -> BatchResult:
        """Reshape a backend's flat `(B, W)` 7-tuple into a BatchResult."""
        cyc, bf, bb, comp, lfo, ffo, fst = outs
        shape = (stacked.batch, n_opts, n_params)
        return BatchResult(names=stacked.names,
                           cycles=cyc.reshape(shape),
                           busy_fpu=bf.reshape(shape),
                           busy_bus=bb.reshape(shape),
                           flops=stacked.total_flops.astype(np.float64),
                           bytes=stacked.total_bytes.astype(np.float64),
                           ideal=(comp[..., IDEAL].reshape(shape)
                                  if comp is not None else None),
                           stalls=(comp[..., 1:].reshape(*shape, NCOMP - 1)
                                   if comp is not None else None),
                           lane_first_out=lfo.reshape(shape),
                           first_first_out=ffo.reshape(shape),
                           finish_start=fst.reshape(shape))

    def _run_pipelined(self, stacked: StackedTraces,
                       opts: Sequence[OptConfig],
                       params: Sequence[SimParams],
                       attribution: bool, p_chunk: int,
                       shard: str) -> BatchResult:
        """Chunked-P jax execution as an async pipeline.

        All chunks are dispatched back-to-back — jax dispatch is async,
        so the device crunches chunk `k` while the host builds the views
        for chunk `k+1` — and results stay as device buffers until one
        final drain (`exec.jax.drain` span) materializes everything.
        The old path recursed through `_run` and paid a
        `block_until_ready` + host copy per chunk.  Reports
        `plan.pipeline_chunks` / `plan.pipeline_occupancy` (dispatch
        share of total wall-clock: ~1.0 means the drain found results
        already finished, i.e. the pipeline stayed full).
        """
        import time
        t0 = time.perf_counter()
        raws = []
        for lo in range(0, len(params), p_chunk):
            chunk = list(params[lo:lo + p_chunk])
            pad = p_chunk - len(chunk)
            view = make_views(opts, chunk + [chunk[-1]] * pad)
            with obs_spans.span("exec.p_chunk", lo=lo, size=len(chunk),
                                width=view.width):
                raw = self._dispatch_jax(stacked, view, attribution,
                                         n_opts=len(opts), shard=shard,
                                         block=False)
            raws.append((raw, len(chunk)))
        obs_metrics.counter("plan.pipeline_chunks").inc(len(raws))
        t_dispatch = time.perf_counter() - t0
        with obs_spans.span("exec.jax.drain", chunks=len(raws)):
            parts = []
            for raw, keep in raws:
                outs = _materialize_jax(raw, attribution)
                part = self._package(stacked, outs, len(opts), p_chunk)
                parts.append(_slice_p(part, keep)
                             if keep != p_chunk else part)
        total = time.perf_counter() - t0
        obs_metrics.gauge("plan.pipeline_occupancy").set(
            t_dispatch / total if total > 0 else 0.0)
        return _concat_p(parts)

    # -- numpy backend ------------------------------------------------------
    def _run_numpy(self, st: StackedTraces, v: ParamView,
                   attrib: bool = False):
        W = v.width
        cycles = np.zeros((st.batch, W))
        busy_fpu = np.zeros((st.batch, W))
        busy_bus = np.zeros((st.batch, W))
        lane_fo = np.zeros((st.batch, W))
        first_fo = np.zeros((st.batch, W))
        fin_start = np.zeros((st.batch, W))
        comp = np.zeros((st.batch, W, NCOMP)) if attrib else None
        for b in range(st.batch):
            (cycles[b], busy_fpu[b], busy_bus[b], cb, lane_fo[b],
             first_fo[b], fin_start[b]) = self._scan_row_numpy(
                st, b, v, attrib)
            if attrib:
                comp[b] = cb
        return cycles, busy_fpu, busy_bus, comp, lane_fo, first_fo, fin_start

    def _scan_row_numpy(self, st: StackedTraces, b: int, v: ParamView,
                        attrib: bool = False):
        """Scan one trace row; hazard state is `(width,)`-vectorized.

        Mirrors `AraSimulator.run` operation-for-operation in float64, so
        results are bit-identical to the scalar simulator.  With `attrib`,
        every hazard-state array carries a companion `(..., NCOMP)`
        component tensor maintained by the same max/+ dataflow (see
        `repro.core.stalls`), again matching the scalar accounting
        bit-for-bit.
        """
        mc = self.mc
        epc = mc.elems_per_cycle
        bpc = mc.axi_bytes_per_cycle
        burst_over_bpc = mc.burst_bytes / bpc
        n = int(st.n_instrs[b])
        R = max(int(st.n_regs[b]), 1)
        W = v.width

        # Cheap python-scalar access to the row's instruction fields.
        kind = st.kind[b, :n].tolist()
        vls = st.vl[b, :n].tolist()
        sews = st.sew[b, :n].tolist()
        nbs = st.nbytes[b, :n].tolist()
        strides = st.stride[b, :n].tolist()
        firsts = st.first_strip[b, :n].tolist()
        isdivs = st.is_div[b, :n].tolist()
        redlvs = st.red_levels[b, :n].tolist()
        dsts = st.dst[b, :n].tolist()
        src_rows = [[s for s in row if s != PAD]
                    for row in st.srcs[b, :n].tolist()]

        issue_t = np.zeros(W)
        bus_free = np.zeros(W)
        wbus_free = np.zeros(W)
        addr_free = np.zeros(W)
        fpu_free = np.zeros(W)
        sldu_free = np.zeros(W)
        bus_last = -1                              # trace-deterministic
        w_first = np.zeros((R, W))
        w_compl = np.zeros((R, W))
        has_w = [False] * R
        r_rel = np.zeros((R, W))
        busy_fpu = np.zeros(W)
        busy_bus = np.zeros(W)
        total = np.zeros(W)
        zero = np.zeros(W)
        # Phase observables (`analysis.attribution.phase_decompose_grid`):
        # earliest lane first_out, instruction 0's first_out, and the
        # start of the finishing (first-maximal complete) instruction.
        lane_fo = np.full(W, np.inf)
        first_fo = np.zeros(W)
        fin_start = np.zeros(W)

        opt_m, opt_c = v.opt_memory, v.opt_control
        lat_demand = v.mem_latency
        lat_warm_unit = np.where(opt_m, v.prefetch_hit, v.mem_latency)
        lat_warm_str = np.where(
            opt_m, 0.5 * (v.mem_latency + v.prefetch_hit), v.mem_latency)

        # ---- attribution companions (see repro.core.stalls) -----------
        # Comp tensors are (W, NCOMP) / (R, W, NCOMP); `sel` adopts the
        # binding argument's components (ties keep the incumbent, matching
        # the scalar simulator), `bump` charges additions to a category.
        def sel(mask, new_c, old_c):
            return np.where(mask[..., None], new_c, old_c)

        def bump(c, *pairs):
            out = c.copy()
            for idx, amount in pairs:
                out[:, idx] += amount
            return out

        if attrib:
            Zc = np.zeros((W, NCOMP))
            c_issue = Zc
            c_bus = Zc
            c_wbus = Zc
            c_addr = Zc
            c_fpu = Zc
            c_sldu = Zc
            wf_c = np.zeros((R, W, NCOMP))
            wc_c = np.zeros((R, W, NCOMP))
            rr_c = np.zeros((R, W, NCOMP))
            c_total = Zc
            dci = np.minimum(v.d_chain, v.d_fwd)       # ideal fwd floor
            dcs = v.d_chain - dci                      # chain-delay stall
        c_raws = c_rc = c_wg = c_req = c_bs = c_cp = c_fo = c_rd = None

        for i in range(n):
            k = kind[i]
            vl = vls[i]
            dst = dsts[i]
            srcs = src_rows[i]

            # ---- dependence constraints (lane side) --------------------
            raw_start = issue_t.copy()
            raw_complete = zero.copy()
            if attrib:
                c_raws = c_issue
                c_rc = Zc
            for s in srcs:
                if has_w[s]:
                    cand_s = w_first[s] + v.d_chain
                    cand_c = w_compl[s] + v.d_chain
                    if attrib:
                        c_raws = sel(cand_s > raw_start,
                                     bump(wf_c[s], (IDEAL, dci),
                                          (OPR_CHAIN_DELAY, dcs)), c_raws)
                        c_rc = sel(cand_c > raw_complete,
                                   bump(wc_c[s], (IDEAL, dci),
                                        (OPR_CHAIN_DELAY, dcs)), c_rc)
                    np.maximum(raw_start, cand_s, out=raw_start)
                    np.maximum(raw_complete, cand_c, out=raw_complete)
            war_gate = zero.copy()
            if attrib:
                c_wg = Zc
            if dst >= 0:
                if attrib:
                    c_wg = sel(r_rel[dst] > war_gate, rr_c[dst], c_wg)
                np.maximum(war_gate, r_rel[dst], out=war_gate)   # WAR
                if has_w[dst]:
                    if attrib:
                        c_wg = sel(w_first[dst] > war_gate, wf_c[dst], c_wg)
                    np.maximum(war_gate, w_first[dst], out=war_gate)  # WAW

            # ---- execute on resource ----------------------------------
            if k == _LOAD:
                if strides[i] == _INDEXED:
                    dur_bus = vl * (sews[i] / bpc) + vl * v.idx_ovh
                    dur_ideal = vl * (sews[i] / bpc)
                else:
                    nburst = max(1, -(-nbs[i] // mc.burst_bytes))
                    dur_bus = nbs[i] / bpc + nburst * v.tx_ovh
                    dur_ideal = nbs[i] / bpc
                dur_stall = dur_bus - dur_ideal
                turn = v.rw_turn if bus_last == _STORE else zero
                cand = bus_free + turn
                req_start = np.maximum(issue_t, raw_start)
                if attrib:
                    c_req = sel(raw_start > issue_t, c_raws, c_issue)
                    c_req = sel(addr_free > req_start, c_addr, c_req)
                np.maximum(req_start, addr_free, out=req_start)
                if attrib:
                    c_cand = (c_bus if bus_last != _STORE else
                              bump(c_bus, (MEM_RW_TURNAROUND, turn)))
                    c_req = sel(cand > req_start, c_cand, c_req)
                np.maximum(req_start, cand, out=req_start)
                if attrib:
                    c_req = sel(war_gate > req_start, c_wg, c_req)
                np.maximum(req_start, war_gate, out=req_start)
                if strides[i] == _UNIT:
                    lat = lat_demand if firsts[i] else lat_warm_unit
                elif strides[i] == _STRIDED:
                    lat = lat_demand if firsts[i] else lat_warm_str
                else:
                    lat = lat_demand
                data_done = req_start + lat + dur_bus
                cand = req_start + lat + burst_over_bpc
                first_out = np.maximum(cand, war_gate)
                complete = np.maximum(data_done, war_gate + vl / epc)
                read_done = req_start
                if attrib:
                    lat_ideal = np.minimum(lat, v.prefetch_hit)
                    lat_stall = lat - lat_ideal
                    c_fo = sel(war_gate > cand,
                               c_wg, bump(c_req,
                                          (IDEAL, lat_ideal + burst_over_bpc),
                                          (MEM_DEMAND_LATENCY, lat_stall)))
                    c_cp = sel(war_gate + vl / epc > data_done,
                               bump(c_wg, (IDEAL, vl / epc)),
                               bump(c_req, (IDEAL, lat_ideal + dur_ideal),
                                    (MEM_DEMAND_LATENCY, lat_stall),
                                    (MEM_TX_OVERHEAD, dur_stall)))
                    c_rd = c_req
                    c_bus = bump(c_req, (IDEAL, dur_ideal),
                                 (MEM_TX_OVERHEAD, dur_stall))
                    c_addr = sel(opt_m, c_req, c_bus)
                bus_free = req_start + dur_bus
                addr_free = np.where(opt_m, req_start, req_start + dur_bus)
                bus_last = _LOAD
                busy_bus += dur_bus
                busy_start = req_start

            elif k == _STORE:
                if strides[i] == _INDEXED:
                    dur_bus = vl * (sews[i] / bpc) + vl * v.idx_ovh
                    dur_ideal = vl * (sews[i] / bpc)
                else:
                    nburst = max(1, -(-nbs[i] // mc.burst_bytes))
                    dur_bus = nbs[i] / bpc + nburst * v.tx_ovh
                    dur_ideal = nbs[i] / bpc
                dur_stall = dur_bus - dur_ideal
                # split (M) path
                bs_split = np.maximum(raw_start, war_gate)
                if attrib:
                    c_bss = sel(war_gate > raw_start, c_wg, c_raws)
                    c_bss = sel(addr_free > bs_split, c_addr, c_bss)
                np.maximum(bs_split, addr_free, out=bs_split)
                if attrib:
                    c_bss = sel(wbus_free > bs_split, c_wbus, c_bss)
                np.maximum(bs_split, wbus_free, out=bs_split)
                # unified path
                turn = v.rw_turn if bus_last == _LOAD else zero
                cand = bus_free + turn
                bs_uni = np.maximum(raw_start, war_gate)
                if attrib:
                    c_bsu = sel(war_gate > raw_start, c_wg, c_raws)
                    c_bsu = sel(addr_free > bs_uni, c_addr, c_bsu)
                np.maximum(bs_uni, addr_free, out=bs_uni)
                if attrib:
                    c_cand = (c_bus if bus_last != _LOAD else
                              bump(c_bus, (MEM_RW_TURNAROUND, turn)))
                    c_bsu = sel(cand > bs_uni, c_cand, c_bsu)
                np.maximum(bs_uni, cand, out=bs_uni)
                busy_start = np.where(opt_m, bs_split, bs_uni)
                if attrib:
                    c_bs = sel(opt_m, c_bss, c_bsu)
                    c_wbus = sel(opt_m,
                                 bump(c_bss, (IDEAL, dur_ideal),
                                      (MEM_TX_OVERHEAD, dur_stall)), c_wbus)
                    c_split_bus = bump(
                        sel(bs_split > bus_free, c_bss, c_bus),
                        (IDEAL, dur_ideal), (MEM_TX_OVERHEAD, dur_stall))
                    c_uni_bus = bump(c_bsu, (IDEAL, dur_ideal),
                                     (MEM_TX_OVERHEAD, dur_stall),
                                     (MEM_STORE_COMMIT, v.store_commit))
                wbus_free = np.where(opt_m, bs_split + dur_bus, wbus_free)
                bus_free = np.where(
                    opt_m, np.maximum(bus_free, bs_split) + dur_bus,
                    bs_uni + dur_bus + v.store_commit)
                if attrib:
                    c_bus = sel(opt_m, c_split_bus, c_uni_bus)
                cand = busy_start + dur_bus + v.mem_latency
                complete = np.maximum(cand, raw_complete)
                first_out = complete
                t1 = busy_start + vl / epc
                t2 = busy_start + dur_bus - v.queue_adv
                read_done = np.maximum(t1, t2)
                if attrib:
                    c_cp = sel(raw_complete > cand, c_rc,
                               bump(c_bs, (IDEAL, dur_ideal),
                                    (MEM_TX_OVERHEAD, dur_stall),
                                    (MEM_STORE_COMMIT, v.mem_latency)))
                    c_fo = c_cp
                    c_rd = bump(c_bs, (IDEAL, vl / epc),
                                (OPR_QUEUE_LIMIT, np.maximum(t2 - t1, 0.0)))
                    c_addr = sel(opt_m, c_bs,
                                 bump(c_bs, (IDEAL, dur_ideal),
                                      (MEM_TX_OVERHEAD, dur_stall)))
                addr_free = np.where(opt_m, busy_start,
                                     busy_start + dur_bus)
                bus_last = _STORE
                busy_bus += dur_bus

            else:                                  # COMPUTE/REDUCE/SLIDE
                if isdivs[i]:
                    dur = (vl / epc) * v.div_factor
                    dur_ideal = dur
                else:
                    dur = (vl / epc) * v.conflict
                    dur_ideal = vl / epc
                if k == _REDUCE:
                    dur = dur + redlvs[i] * mc.fu_latency
                    dur_ideal = dur_ideal + redlvs[i] * mc.fu_latency
                dur_stall = dur - dur_ideal
                unit_free = sldu_free if k == _SLIDE else fpu_free
                busy_start = np.maximum(raw_start, war_gate)
                if attrib:
                    c_unit = c_sldu if k == _SLIDE else c_fpu
                    c_bs = sel(war_gate > raw_start, c_wg, c_raws)
                    c_bs = sel(unit_free > busy_start, c_unit, c_bs)
                np.maximum(busy_start, unit_free, out=busy_start)
                cand = busy_start + mc.fu_latency + dur
                complete = np.maximum(cand, raw_complete)
                if k == _REDUCE:
                    first_out = complete
                else:
                    first_out = busy_start + mc.fu_latency
                t1 = busy_start + vl / epc
                t2 = complete - mc.fu_latency - v.queue_adv
                read_done = np.maximum(t1, t2)
                t1o = busy_start + dur
                t2o = complete - mc.fu_latency
                occ = np.maximum(t1o, t2o)
                if attrib:
                    c_cp = sel(raw_complete > cand, c_rc,
                               bump(c_bs, (IDEAL, mc.fu_latency + dur_ideal),
                                    (OPR_BANK_CONFLICT, dur_stall)))
                    c_fo = c_cp if k == _REDUCE else \
                        bump(c_bs, (IDEAL, mc.fu_latency))
                    c_rd = bump(c_bs, (IDEAL, vl / epc),
                                (OPR_QUEUE_LIMIT, np.maximum(t2 - t1, 0.0)))
                    c_occ = bump(c_bs, (IDEAL, dur_ideal),
                                 (OPR_BANK_CONFLICT, dur_stall),
                                 (OPR_CHAIN_DELAY,
                                  np.maximum(t2o - t1o, 0.0)))
                    if k == _SLIDE:
                        c_sldu = c_occ
                    else:
                        c_fpu = c_occ
                if k == _SLIDE:
                    sldu_free = occ
                else:
                    fpu_free = occ
                    busy_fpu += vl / epc

            # ---- update hazard state ----------------------------------
            issue_t = issue_t + v.issue_gap
            if attrib:
                c_issue = bump(c_issue, (DEP_ISSUE_GAP, v.issue_gap))
            if dst >= 0:
                w_first[dst] = first_out
                w_compl[dst] = complete
                has_w[dst] = True
                if attrib:
                    wf_c[dst] = c_fo
                    wc_c[dst] = c_cp
            if srcs:
                release = np.where(opt_c, read_done,
                                   complete + v.war_release_ovh)
                if attrib:
                    c_rel = sel(opt_c, c_rd,
                                bump(c_cp,
                                     (DEP_WAR_RELEASE, v.war_release_ovh)))
                for s in srcs:
                    if attrib:
                        rr_c[s] = sel(release > r_rel[s], c_rel, rr_c[s])
                    np.maximum(r_rel[s], release, out=r_rel[s])
            if attrib:
                c_total = sel(complete > total, c_cp, c_total)
            if i == 0:
                first_fo = first_out.copy()
            if k not in (_LOAD, _STORE):
                np.minimum(lane_fo, first_out, out=lane_fo)
            fin_start = np.where(complete > total, busy_start, fin_start)
            np.maximum(total, complete, out=total)

        return (total, busy_fpu, busy_bus, (c_total if attrib else None),
                lane_fo, first_fo, fin_start)

    # -- jax backend --------------------------------------------------------
    def _device_fields(self, st: StackedTraces) -> tuple:
        """Trace fields as device-resident buffers, uploaded once per
        stack.  Identity-keyed with a strong reference to the stack (so
        a recycled `id()` can never alias) and bounded: chunked runs hit
        the same entry once per chunk instead of re-transferring the
        `(I, B)` arrays."""
        ent = self._dev_fields.get(id(st))
        if ent is not None and ent[0] is st:
            return ent[1]
        import jax
        fields = tuple(jax.device_put(a) for a in _jax_fields(st))
        if len(self._dev_fields) >= 8:
            self._dev_fields.clear()
        self._dev_fields[id(st)] = (st, fields)
        return fields

    def _dispatch_jax(self, st: StackedTraces, v: ParamView,
                      attribution: bool = False, n_opts: int = 1,
                      shard: str = "none", block: bool = True):
        """Dispatch one compiled sweep; returns the raw device-array
        7-tuple in the sweep's own order ``(cyc, bf, bb, lfo, ffo, fst,
        comp)``.  With ``block=False`` the call returns as soon as the
        computation is enqueued — the pipelined chunk loop relies on
        this to overlap chunks (`_materialize_jax` syncs later)."""
        from jax.experimental import enable_x64
        with enable_x64():
            fn = self._jax_fns.get(attribution)
            if fn is None:
                fn = _build_jax_sweep(self.mc, attribution)
                self._jax_fns[attribution] = fn
            fields = self._device_fields(st)
            views = dataclasses.astuple(v)
            R = max(st.max_regs, 1)
            sig = (attribution, st.kind.shape, st.srcs.shape[2],
                   v.width, R, shard)
            fresh = sig not in self._jax_seen
            name = "exec.jax.compile" if fresh else "exec.jax.execute"
            with obs_spans.span(name, batch=st.batch, width=v.width,
                                n_instrs=int(st.kind.shape[1])):
                if shard == "devices":
                    from repro.launch import mesh as launch_mesh
                    out = launch_mesh.sharded_sweep(
                        fn, fields, views, R, n_opts, attribution)
                else:
                    out = fn(fields, views, R)
                if block:
                    out[0].block_until_ready()
            self._jax_seen.add(sig)
        return out


def _materialize_jax(raw, attribution: bool):
    """Pull a `_dispatch_jax` result to host, reordered to the shared
    backend convention ``(cyc, bf, bb, comp, lfo, ffo, fst)``.  This is
    the only host sync on the jax path."""
    cyc, bf, bb, lfo, ffo, fst, comp = raw
    return (np.asarray(cyc), np.asarray(bf), np.asarray(bb),
            np.asarray(comp) if attribution else None,
            np.asarray(lfo), np.asarray(ffo), np.asarray(fst))


def _jax_fields(st: StackedTraces) -> tuple:
    """Instruction-major `(I, B)` field arrays for `lax.scan`."""
    t = lambda a, dt: np.ascontiguousarray(a.T.astype(dt))
    return (t(st.kind, np.int32), t(st.vl, np.float64),
            t(st.sew, np.float64), t(st.nbytes, np.float64),
            t(st.stride, np.int32), t(st.first_strip, bool),
            t(st.is_div, bool), t(st.red_levels, np.float64),
            t(st.dst, np.int32),
            np.ascontiguousarray(np.swapaxes(st.srcs, 0, 1)
                                 .astype(np.int32)))


def _build_jax_sweep(mc: MachineConfig, attribution: bool = False):
    """Compile the per-step recurrence as `lax.scan` over instructions.

    State lives as `(B, W)` / `(B, R, W)` arrays; one call evaluates the
    whole `(trace x opt x params)` grid.  Padded instruction slots
    (`kind == PAD`) leave state untouched.

    With `attribution`, every time-valued state array carries a companion
    `(..., NCOMP)` component tensor maintained by the same max/+ dataflow
    as the numpy backend (see `repro.core.stalls`): `jnp.where` on the
    binding-argument index replaces the scalar adoption branches and
    additions charge the responsible category, so the compiled program
    stays a single scan and the returned decomposition satisfies
    ``ideal + sum(stalls) == cycles`` to float64 resolution.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    epc = float(mc.elems_per_cycle)
    bpc = float(mc.axi_bytes_per_cycle)
    burst = float(mc.burst_bytes)
    ful = float(mc.fu_latency)

    def sweep(fields, views, R):
        (kind, vl, sew, nb, stride, first, isdiv, redlv, dst, srcs) = fields
        (mem_lat, pf_hit, div_f, war_ovh, tx_ovh, idx_ovh, rw_turn,
         store_commit, issue_gap, d_chain, conflict, queue_adv,
         opt_m, opt_c, d_fwd) = (jnp.asarray(x) for x in views)
        B = kind.shape[1]
        W = mem_lat.shape[0]
        S = srcs.shape[2]
        fz = jnp.zeros((B, W), jnp.float64)
        opt_m2 = opt_m[None, :]
        opt_c2 = opt_c[None, :]
        # Chain-propagation split (attribution): the forwarding floor is
        # ideal prologue, the write-back/re-read excess is operand stall.
        dci = jnp.minimum(d_chain, d_fwd)
        dcs = d_chain - dci
        Zc = jnp.zeros((B, W, NCOMP), jnp.float64)

        def selc(mask, new, old):
            """Adopt the binding argument's components where `mask`."""
            return jnp.where(mask[..., None], new, old)

        def bump(c, *pairs):
            for idx, amount in pairs:
                c = c.at[..., idx].add(amount)
            return c

        state = dict(
            issue_t=fz, bus_free=fz, wbus_free=fz, addr_free=fz,
            fpu_free=fz, sldu_free=fz, busy_fpu=fz, busy_bus=fz, total=fz,
            lane_fo=jnp.full((B, W), jnp.inf, jnp.float64),
            first_fo=fz, fin_start=fz,
            seen=jnp.zeros((B, 1), bool),
            bus_last=jnp.full((B,), -1, jnp.int32),
            w_first=jnp.zeros((B, R, W), jnp.float64),
            w_compl=jnp.zeros((B, R, W), jnp.float64),
            has_w=jnp.zeros((B, R), bool),
            r_rel=jnp.zeros((B, R, W), jnp.float64),
        )
        if attribution:
            state.update(
                c_issue=Zc, c_bus=Zc, c_wbus=Zc, c_addr=Zc, c_fpu=Zc,
                c_sldu=Zc, c_total=Zc,
                wf_c=jnp.zeros((B, R, W, NCOMP), jnp.float64),
                wc_c=jnp.zeros((B, R, W, NCOMP), jnp.float64),
                rr_c=jnp.zeros((B, R, W, NCOMP), jnp.float64),
            )

        def gather(tab, idx):                      # (B,R,W),(B,) -> (B,W)
            return jnp.take_along_axis(
                tab, idx[:, None, None], axis=1)[:, 0, :]

        def gather_c(tab, idx):            # (B,R,W,C),(B,) -> (B,W,C)
            return jnp.take_along_axis(
                tab, idx[:, None, None, None], axis=1)[:, 0]

        def step(s, x):
            (k, vl_i, sew_i, nb_i, str_i, fs_i, dv_i, rl_i, d_i, sr_i) = x
            att = attribution
            valid = (k != PAD)[:, None]            # (B, 1)
            is_load = (k == _LOAD)[:, None]
            is_store = (k == _STORE)[:, None]
            is_red = (k == _REDUCE)[:, None]
            is_slide = (k == _SLIDE)[:, None]
            vl2 = vl_i[:, None]

            # ---- dependence constraints -------------------------------
            raw_start = s["issue_t"]
            raw_complete = fz
            if att:
                c_raws = s["c_issue"]
                c_rc = Zc
            for j in range(S):
                src = sr_i[:, j]
                srcc = jnp.clip(src, 0, R - 1)
                ok = ((src >= 0) &
                      jnp.take_along_axis(s["has_w"], srcc[:, None],
                                          axis=1)[:, 0])[:, None]
                cand_s = gather(s["w_first"], srcc) + d_chain
                cand_c = gather(s["w_compl"], srcc) + d_chain
                if att:
                    c_raws = selc(ok & (cand_s > raw_start),
                                  bump(gather_c(s["wf_c"], srcc),
                                       (IDEAL, dci),
                                       (OPR_CHAIN_DELAY, dcs)), c_raws)
                    c_rc = selc(ok & (cand_c > raw_complete),
                                bump(gather_c(s["wc_c"], srcc),
                                     (IDEAL, dci),
                                     (OPR_CHAIN_DELAY, dcs)), c_rc)
                raw_start = jnp.where(
                    ok, jnp.maximum(raw_start, cand_s), raw_start)
                raw_complete = jnp.where(
                    ok, jnp.maximum(raw_complete, cand_c), raw_complete)
            dstc = jnp.clip(d_i, 0, R - 1)
            has_dst = (d_i >= 0)[:, None]
            dst_has_w = jnp.take_along_axis(s["has_w"], dstc[:, None],
                                            axis=1)
            rrel_d = gather(s["r_rel"], dstc)
            war_gate = jnp.where(has_dst, rrel_d, 0.0)
            wf_d = gather(s["w_first"], dstc)
            waw = has_dst & dst_has_w
            if att:
                c_wg = selc(has_dst & (rrel_d > 0.0),
                            gather_c(s["rr_c"], dstc), Zc)
                c_wg = selc(waw & (wf_d > war_gate),
                            gather_c(s["wf_c"], dstc), c_wg)
            war_gate = jnp.where(waw, jnp.maximum(war_gate, wf_d),
                                 war_gate)

            # ---- memory-op shared quantities --------------------------
            nburst = jnp.maximum(1.0, jnp.ceil(nb_i / burst))[:, None]
            indexed = (str_i == _INDEXED)[:, None]
            dur_bus = jnp.where(indexed,
                                vl2 * (sew_i[:, None] / bpc) + vl2 * idx_ovh,
                                nb_i[:, None] / bpc + nburst * tx_ovh)
            if att:
                dur_ideal_m = jnp.where(indexed,
                                        vl2 * (sew_i[:, None] / bpc),
                                        nb_i[:, None] / bpc)
                dur_stall_m = dur_bus - dur_ideal_m
            # ---- LOAD path --------------------------------------------
            turn_l = jnp.where((s["bus_last"] == _STORE)[:, None],
                               rw_turn, 0.0)
            r0 = jnp.maximum(s["issue_t"], raw_start)
            r1 = jnp.maximum(r0, s["addr_free"])
            cand_bus = s["bus_free"] + turn_l
            r2 = jnp.maximum(r1, cand_bus)
            req = jnp.maximum(r2, war_gate)
            lat_unit = jnp.where(fs_i[:, None], mem_lat, pf_hit)
            lat_str = jnp.where(fs_i[:, None], mem_lat,
                                0.5 * (mem_lat + pf_hit))
            lat_m = jnp.where((str_i == _UNIT)[:, None], lat_unit,
                              jnp.where((str_i == _STRIDED)[:, None],
                                        lat_str, mem_lat))
            lat = jnp.where(opt_m2, lat_m, mem_lat)
            data_done = req + lat + dur_bus
            fo_cand = req + lat + burst / bpc
            fo_l = jnp.maximum(fo_cand, war_gate)
            cp_wg = war_gate + vl2 / epc
            cp_l = jnp.maximum(data_done, cp_wg)
            rd_l = req
            busf_l = req + dur_bus
            addr_l = jnp.where(opt_m2, req, req + dur_bus)
            if att:
                c_req = selc(raw_start > s["issue_t"], c_raws,
                             s["c_issue"])
                c_req = selc(s["addr_free"] > r0, s["c_addr"], c_req)
                c_req = selc(cand_bus > r1,
                             bump(s["c_bus"], (MEM_RW_TURNAROUND, turn_l)),
                             c_req)
                c_req = selc(war_gate > r2, c_wg, c_req)
                lat_ideal = jnp.minimum(lat, pf_hit)
                lat_stall = lat - lat_ideal
                c_fo_l = selc(war_gate > fo_cand, c_wg,
                              bump(c_req, (IDEAL, lat_ideal + burst / bpc),
                                   (MEM_DEMAND_LATENCY, lat_stall)))
                c_cp_l = selc(cp_wg > data_done,
                              bump(c_wg, (IDEAL, vl2 / epc)),
                              bump(c_req, (IDEAL, lat_ideal + dur_ideal_m),
                                   (MEM_DEMAND_LATENCY, lat_stall),
                                   (MEM_TX_OVERHEAD, dur_stall_m)))
                c_rd_l = c_req
                c_bus_l = bump(c_req, (IDEAL, dur_ideal_m),
                               (MEM_TX_OVERHEAD, dur_stall_m))
                c_addr_l = selc(opt_m2, c_req, c_bus_l)
            # ---- STORE path -------------------------------------------
            bs0 = jnp.maximum(raw_start, war_gate)
            bs1 = jnp.maximum(bs0, s["addr_free"])
            bs_split = jnp.maximum(bs1, s["wbus_free"])
            turn_s = jnp.where((s["bus_last"] == _LOAD)[:, None],
                               rw_turn, 0.0)
            cand_bus_s = s["bus_free"] + turn_s
            bs_uni = jnp.maximum(bs1, cand_bus_s)
            bs_s = jnp.where(opt_m2, bs_split, bs_uni)
            wbus_s = jnp.where(opt_m2, bs_split + dur_bus, s["wbus_free"])
            busf_s = jnp.where(
                opt_m2, jnp.maximum(s["bus_free"], bs_split) + dur_bus,
                bs_uni + dur_bus + store_commit)
            cp_cand_s = bs_s + dur_bus + mem_lat
            cp_s = jnp.maximum(cp_cand_s, raw_complete)
            t1s = bs_s + vl2 / epc
            t2s = bs_s + dur_bus - queue_adv
            rd_s = jnp.maximum(t1s, t2s)
            addr_s = jnp.where(opt_m2, bs_s, bs_s + dur_bus)
            if att:
                c_bs0 = selc(war_gate > raw_start, c_wg, c_raws)
                c_bs1 = selc(s["addr_free"] > bs0, s["c_addr"], c_bs0)
                c_bss = selc(s["wbus_free"] > bs1, s["c_wbus"], c_bs1)
                c_bsu = selc(cand_bus_s > bs1,
                             bump(s["c_bus"], (MEM_RW_TURNAROUND, turn_s)),
                             c_bs1)
                c_bs_s = selc(opt_m2, c_bss, c_bsu)
                c_wbus_s = selc(opt_m2,
                                bump(c_bss, (IDEAL, dur_ideal_m),
                                     (MEM_TX_OVERHEAD, dur_stall_m)),
                                s["c_wbus"])
                c_split_bus = bump(
                    selc(bs_split > s["bus_free"], c_bss, s["c_bus"]),
                    (IDEAL, dur_ideal_m), (MEM_TX_OVERHEAD, dur_stall_m))
                c_uni_bus = bump(c_bsu, (IDEAL, dur_ideal_m),
                                 (MEM_TX_OVERHEAD, dur_stall_m),
                                 (MEM_STORE_COMMIT, store_commit))
                c_bus_s = selc(opt_m2, c_split_bus, c_uni_bus)
                c_cp_s = selc(raw_complete > cp_cand_s, c_rc,
                              bump(c_bs_s, (IDEAL, dur_ideal_m),
                                   (MEM_TX_OVERHEAD, dur_stall_m),
                                   (MEM_STORE_COMMIT, mem_lat)))
                c_fo_s = c_cp_s
                c_rd_s = bump(c_bs_s, (IDEAL, vl2 / epc),
                              (OPR_QUEUE_LIMIT,
                               jnp.maximum(t2s - t1s, 0.0)))
                c_addr_s = selc(opt_m2, c_bs_s,
                                bump(c_bs_s, (IDEAL, dur_ideal_m),
                                     (MEM_TX_OVERHEAD, dur_stall_m)))
            # ---- COMPUTE/REDUCE/SLIDE path ----------------------------
            dur_c = jnp.where(dv_i[:, None], (vl2 / epc) * div_f,
                              (vl2 / epc) * conflict) + rl_i[:, None] * ful
            unit_free = jnp.where(is_slide, s["sldu_free"], s["fpu_free"])
            bc0 = jnp.maximum(raw_start, war_gate)
            bs_c = jnp.maximum(bc0, unit_free)
            cp_cand_c = bs_c + ful + dur_c
            cp_c = jnp.maximum(cp_cand_c, raw_complete)
            fo_c = jnp.where(is_red, cp_c, bs_c + ful)
            t1c = bs_c + vl2 / epc
            t2c = cp_c - ful - queue_adv
            rd_c = jnp.maximum(t1c, t2c)
            t1o = bs_c + dur_c
            t2o = cp_c - ful
            occ = jnp.maximum(t1o, t2o)
            if att:
                dur_ideal_c = jnp.where(dv_i[:, None],
                                        (vl2 / epc) * div_f,
                                        vl2 / epc) + rl_i[:, None] * ful
                dur_stall_c = dur_c - dur_ideal_c
                c_unit = selc(is_slide, s["c_sldu"], s["c_fpu"])
                c_bc0 = selc(war_gate > raw_start, c_wg, c_raws)
                c_bs_c = selc(unit_free > bc0, c_unit, c_bc0)
                c_cp_c = selc(raw_complete > cp_cand_c, c_rc,
                              bump(c_bs_c, (IDEAL, ful + dur_ideal_c),
                                   (OPR_BANK_CONFLICT, dur_stall_c)))
                c_fo_c = selc(is_red, c_cp_c,
                              bump(c_bs_c, (IDEAL, ful)))
                c_rd_c = bump(c_bs_c, (IDEAL, vl2 / epc),
                              (OPR_QUEUE_LIMIT,
                               jnp.maximum(t2c - t1c, 0.0)))
                c_occ = bump(c_bs_c, (IDEAL, dur_ideal_c),
                             (OPR_BANK_CONFLICT, dur_stall_c),
                             (OPR_CHAIN_DELAY,
                              jnp.maximum(t2o - t1o, 0.0)))

            # ---- select by kind & merge -------------------------------
            busy_start = jnp.where(is_load, req,
                                   jnp.where(is_store, bs_s, bs_c))
            complete = jnp.where(is_load, cp_l,
                                 jnp.where(is_store, cp_s, cp_c))
            first_out = jnp.where(is_load, fo_l,
                                  jnp.where(is_store, cp_s, fo_c))
            read_done = jnp.where(is_load, rd_l,
                                  jnp.where(is_store, rd_s, rd_c))
            is_mem = is_load | is_store
            upd = lambda new, old, cond: jnp.where(valid & cond, new, old)
            ns = dict(s)
            ns["bus_free"] = upd(jnp.where(is_load, busf_l, busf_s),
                                 s["bus_free"], is_mem)
            ns["addr_free"] = upd(jnp.where(is_load, addr_l, addr_s),
                                  s["addr_free"], is_mem)
            ns["wbus_free"] = upd(wbus_s, s["wbus_free"], is_store)
            ns["busy_bus"] = upd(s["busy_bus"] + dur_bus,
                                 s["busy_bus"], is_mem)
            is_comp = valid & ~is_mem
            ns["sldu_free"] = jnp.where(is_comp & is_slide, occ,
                                        s["sldu_free"])
            ns["fpu_free"] = jnp.where(is_comp & ~is_slide, occ,
                                       s["fpu_free"])
            ns["busy_fpu"] = jnp.where(is_comp & ~is_slide,
                                       s["busy_fpu"] + vl2 / epc,
                                       s["busy_fpu"])
            ns["bus_last"] = jnp.where(
                (valid & is_mem)[:, 0],
                jnp.where(is_load[:, 0], _LOAD, _STORE), s["bus_last"])
            ns["issue_t"] = jnp.where(valid, s["issue_t"] + issue_gap,
                                      s["issue_t"])
            if att:
                c_cp = selc(is_load, c_cp_l,
                            selc(is_store, c_cp_s, c_cp_c))
                c_fo = selc(is_load, c_fo_l,
                            selc(is_store, c_fo_s, c_fo_c))
                c_rd = selc(is_load, c_rd_l,
                            selc(is_store, c_rd_s, c_rd_c))
                ns["c_bus"] = selc(valid & is_load, c_bus_l,
                                   selc(valid & is_store, c_bus_s,
                                        s["c_bus"]))
                ns["c_addr"] = selc(valid & is_load, c_addr_l,
                                    selc(valid & is_store, c_addr_s,
                                         s["c_addr"]))
                ns["c_wbus"] = selc(valid & is_store, c_wbus_s,
                                    s["c_wbus"])
                ns["c_sldu"] = selc(is_comp & is_slide, c_occ,
                                    s["c_sldu"])
                ns["c_fpu"] = selc(is_comp & ~is_slide, c_occ,
                                   s["c_fpu"])
                ns["c_issue"] = selc(
                    valid,
                    bump(s["c_issue"], (DEP_ISSUE_GAP, issue_gap)),
                    s["c_issue"])
            # writer / reader-release scatter via one-hot rows
            oh_dst = (jnp.arange(R)[None, :] == dstc[:, None]) \
                & (valid & has_dst)
            ns["w_first"] = jnp.where(oh_dst[:, :, None],
                                      first_out[:, None, :], s["w_first"])
            ns["w_compl"] = jnp.where(oh_dst[:, :, None],
                                      complete[:, None, :], s["w_compl"])
            ns["has_w"] = s["has_w"] | oh_dst
            if att:
                ns["wf_c"] = jnp.where(oh_dst[:, :, None, None],
                                       c_fo[:, None], s["wf_c"])
                ns["wc_c"] = jnp.where(oh_dst[:, :, None, None],
                                       c_cp[:, None], s["wc_c"])
            release = jnp.where(opt_c2, read_done,
                                complete + war_ovh)
            if att:
                c_rel = selc(opt_c2, c_rd,
                             bump(c_cp, (DEP_WAR_RELEASE, war_ovh)))
            r_rel = s["r_rel"]
            rr_c = s["rr_c"] if att else None
            for j in range(S):
                src = sr_i[:, j]
                srcc = jnp.clip(src, 0, R - 1)
                oh = (jnp.arange(R)[None, :] == srcc[:, None]) \
                    & (valid & (src >= 0)[:, None])
                if att:
                    adopt = oh[:, :, None] & (release[:, None, :] > r_rel)
                    rr_c = jnp.where(adopt[..., None], c_rel[:, None],
                                     rr_c)
                r_rel = jnp.where(
                    oh[:, :, None],
                    jnp.maximum(r_rel, release[:, None, :]), r_rel)
            ns["r_rel"] = r_rel
            if att:
                ns["rr_c"] = rr_c
            adopt_t = valid & (complete > s["total"])
            if att:
                ns["c_total"] = selc(adopt_t, c_cp, s["c_total"])
            ns["fin_start"] = jnp.where(adopt_t, busy_start,
                                        s["fin_start"])
            ns["total"] = jnp.where(valid, jnp.maximum(s["total"], complete),
                                    s["total"])
            ns["first_fo"] = jnp.where(valid & ~s["seen"], first_out,
                                       s["first_fo"])
            ns["seen"] = s["seen"] | valid
            ns["lane_fo"] = jnp.where(is_comp,
                                      jnp.minimum(s["lane_fo"], first_out),
                                      s["lane_fo"])
            return ns, None

        final, _ = lax.scan(step, state, fields)
        comp = final["c_total"] if attribution else final["total"]
        return (final["total"], final["busy_fpu"], final["busy_bus"],
                final["lane_fo"], final["first_fo"], final["fin_start"],
                comp)

    return jax.jit(sweep, static_argnums=(2,))
