"""Core: the paper's contribution — ideal multi-lane chaining model,
sustained-throughput simulator, and roofline analysis."""
from repro.core.chaining import (ChainSpec, Deviation, IDEAL, attribute,
                                 ii_eff_from_rates, pipeline_efficiency,
                                 pipeline_spec)
from repro.core.isa import (ABLATION_GRID, KernelTrace, MachineConfig,
                            OpKind, OptConfig, Stride, VInstr, geomean)
from repro.core.roofline import (ARA_PEAK_BW, ARA_PEAK_GFLOPS, RooflineTerms,
                                 TPU_V5E, TPUSpec, gap_closed,
                                 model_flops_inference, model_flops_training,
                                 normalized, p_ideal)
from repro.core.simulator import AraSimulator, SimParams, SimResult

__all__ = [
    "ChainSpec", "Deviation", "IDEAL", "attribute", "ii_eff_from_rates",
    "pipeline_efficiency", "pipeline_spec", "ABLATION_GRID", "KernelTrace",
    "MachineConfig", "OpKind", "OptConfig", "Stride", "VInstr", "geomean",
    "ARA_PEAK_BW", "ARA_PEAK_GFLOPS", "RooflineTerms", "TPU_V5E", "TPUSpec",
    "gap_closed", "model_flops_inference", "model_flops_training",
    "normalized", "p_ideal", "AraSimulator", "SimParams", "SimResult",
]
