"""Log-depth (max, +) associative-scan execution of the batched timing model.

`repro.core.batch_sim` evaluates the per-instruction timing recurrence with
`lax.scan`: wall-clock depth grows linearly with trace length even though
every `(trace, opt, params)` cell is independent.  The recurrence, however,
is *tropically linear*: every state update is a `max` of `state + constant`
terms, i.e. an affine map in the (max, +) semiring.  Affine tropical maps
compose associatively, so a trace of `I` instructions can be evaluated in
`O(log I)` composition depth via `jax.lax.associative_scan` — this module
implements that ``method="assoc"`` engine.

Formulation
-----------
The hazard state is embedded in a basis of ``D = 8 + 3R`` components::

    [const, issue_t, bus_free, wbus_free, addr_free, fpu_free, sldu_free,
     total,  w_first[0..R), w_compl[0..R), r_rel[0..R)]

Every tracked quantity is a *row* ``v`` of length ``D`` meaning
``value = max_j ( v[j] + state[j] )`` over the state at some reference
point; the ``const`` component is pinned to 0 so constants live in the
``const`` column and absent transitions are ``-inf``.  One instruction's
update is then a ``D x D`` transfer matrix, and a *chunk* of ``L``
instructions composes into one matrix by running the per-instruction row
step under a short `lax.scan` (pass 1).  Chunk matrices compose under
`associative_scan` with the tropical matmul of `repro.core.pallas_step`
(optionally Pallas-fused), giving the end-to-end matrix *and* every
chunk-entry state in log depth.  A second, embarrassingly-parallel pass
re-runs the same row step in *value mode* (``D = 1``, absolute times seeded
from the chunk-entry states) to recover the per-instruction observables
(`first_out` / `complete` / `busy_start`) that the phase decomposition
needs.

Attribution provenance
----------------------
With ``attribution=True`` every finite matrix entry ``V[i, j]`` carries a
payload ``P[i, j] in R^NCOMP`` (ideal + 9 stall categories, see
`repro.core.stalls`) with the invariant ``sum(P[i, j]) == V[i, j]`` (up to
float64 re-association).  Composition routes payloads through the argmax
binding index ``K`` of the tropical matmul::

    P_C[i, j] = P_B[i, K[i, j]] + P_A[K[i, j], j]

so the invariant is preserved exactly, and the final decomposition
satisfies ``ideal + sum(stalls) == cycles`` to float64 resolution.  The
per-category split matches the `lax.scan` engine's accounting on the
common dataflow; where the scan engine flattens a state-dependent max
(store/compute `read_done`, unit occupancy) into a relu charge, the row
step applies the same relu *per matrix entry* (`_rmax_shift` below), which
can route a tie differently than the sequential engine — the parity
contract only guarantees allclose cycles and the exact sum invariant, not
bit-equal category splits.

Cost model
----------
Transfer matrices are ``(nC, B, W, D, D)`` (+ payload ``x NCOMP``), so
memory grows with ``R^2``; `assoc_bytes` estimates the footprint and
`run_assoc` refuses grids beyond ``REPRO_ASSOC_MEM_LIMIT`` (default 4 GiB)
with a pointer at ``method="scan"`` or a larger ``chunk``.  See
docs/backends.md for measured scan-vs-assoc crossovers.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.isa import MachineConfig
from repro.core.stalls import (DEP_ISSUE_GAP, DEP_WAR_RELEASE, IDEAL,
                               MEM_DEMAND_LATENCY, MEM_RW_TURNAROUND,
                               MEM_STORE_COMMIT, MEM_TX_OVERHEAD, NCOMP,
                               OPR_BANK_CONFLICT, OPR_CHAIN_DELAY,
                               OPR_QUEUE_LIMIT)
from repro.core.traces import PAD, StackedTraces
from repro.obs import metrics as obs_metrics
from repro.obs import spans as obs_spans

_LOAD, _STORE, _COMPUTE, _REDUCE, _SLIDE = 0, 1, 2, 3, 4
_UNIT, _STRIDED, _INDEXED = 0, 1, 2

#: default instructions per chunk (pass-1 scan length); the matrix count is
#: ``ceil(I / chunk)`` so larger chunks trade scan depth for fewer/cheaper
#: compositions.
DEFAULT_CHUNK = 64
MEM_LIMIT_ENV = "REPRO_ASSOC_MEM_LIMIT"
DEFAULT_MEM_LIMIT = 4 * 2 ** 30

# State-basis component indices (the fixed scalar components; register
# tables follow at _NFIX + {0, R, 2R}).
_CONST, _ISSUE, _BUS, _WBUS, _ADDR, _FPU, _SLDU, _TOTAL = range(8)
_NFIX = 8


def basis_dim(n_regs: int) -> int:
    """Tropical state-basis size for `n_regs` architectural registers."""
    return _NFIX + 3 * max(n_regs, 1)


def assoc_bytes(n_instrs: int, batch: int, width: int, n_regs: int,
                attribution: bool = False,
                chunk: int = DEFAULT_CHUNK) -> int:
    """Rough peak-memory estimate (bytes) for an assoc run.

    Dominated by the chunk transfer matrices ``(nC, B, W, D, D)`` plus
    payloads; the factor 3 covers the `associative_scan` working set and
    the pass-1 carry."""
    D = basis_dim(n_regs)
    n_chunks = max(1, -(-n_instrs // chunk))
    per = n_chunks * batch * width * D * D * 8
    if attribution:
        per *= 1 + NCOMP
    return 3 * per


def _prep(st: StackedTraces, chunk: int):
    """Host-side precompute: padded instruction-major fields plus the
    trace-deterministic hazard metadata that frees the row step from
    non-(max,+) state.

    Returns ``(fields, n_chunks, padded_len)`` where every field is
    ``(L, nC*B, ...)`` — chunk-major so chunk ``c`` of trace ``b`` lands at
    merged index ``c*B + b``.  The metadata (all exact, data-independent):

      * ``blast``  — kind of the last *memory* instruction strictly before
        this one (-1 if none): replaces the scan's ``bus_last`` state.
      * ``sok``    — per source slot: the register was written earlier
        (replaces ``has_w`` gathers).
      * ``dhw``    — the destination register was written earlier (WAW).
    """
    B, I = st.kind.shape
    S = st.srcs.shape[2]
    n_chunks = max(1, -(-I // chunk))
    I2 = n_chunks * chunk
    R = max(int(st.max_regs), 1)

    def pad_im(a, dtype, fill=0):
        out = np.full((I2, B) + a.shape[2:], fill, dtype)
        out[:I] = np.swapaxes(np.asarray(a), 0, 1).astype(dtype)
        return out

    kind = pad_im(st.kind, np.int32, PAD)
    vl = pad_im(st.vl, np.float64)
    sew = pad_im(st.sew, np.float64)
    nb = pad_im(st.nbytes, np.float64)
    stride = pad_im(st.stride, np.int32)
    first = pad_im(st.first_strip, bool)
    isdiv = pad_im(st.is_div, bool)
    redlv = pad_im(st.red_levels, np.float64)
    dst = pad_im(st.dst, np.int32, -1)
    srcs = pad_im(st.srcs, np.int32, PAD if PAD < 0 else -1)

    valid = kind != PAD                                     # (I2, B)
    mem = valid & ((kind == _LOAD) | (kind == _STORE))
    # bus_last: index of the previous memory instruction, forward-filled.
    idx = np.arange(I2)[:, None]
    last_mem = np.maximum.accumulate(np.where(mem, idx, -1), axis=0)
    prev_mem = np.vstack([np.full((1, B), -1), last_mem[:-1]])
    cols = np.broadcast_to(np.arange(B), (I2, B))
    blast = np.where(prev_mem >= 0,
                     kind[np.clip(prev_mem, 0, None), cols],
                     -1).astype(np.int32)
    # has_w prefix: register r written by some earlier valid instruction.
    writes = ((dst[:, :, None] == np.arange(R)[None, None, :])
              & (valid & (dst >= 0))[:, :, None])           # (I2, B, R)
    seen = np.cumsum(writes, axis=0, dtype=np.int32) - writes
    hw_before = seen > 0
    dhw = np.take_along_axis(
        hw_before, np.clip(dst, 0, R - 1)[:, :, None], axis=2)[:, :, 0]
    sok = (srcs >= 0) & np.take_along_axis(
        hw_before, np.clip(srcs, 0, R - 1), axis=2)

    def cm(a):            # (I2, B, ...) -> (L, nC*B, ...)
        a = a.reshape(n_chunks, chunk, B, *a.shape[2:])
        a = np.swapaxes(a, 0, 1)
        return np.ascontiguousarray(
            a.reshape(chunk, n_chunks * B, *a.shape[3:]))

    fields = tuple(cm(x) for x in (kind, vl, sew, nb, stride, first,
                                   isdiv, redlv, dst, srcs, blast, sok,
                                   dhw))
    return fields, n_chunks, I2


def _build_assoc(mc: MachineConfig, attribution: bool, use_pallas: bool):
    """Compile the two-pass assoc engine for one machine config.

    Returns ``fn(fields, views, R, B) -> (cycles, comp, fo, cp, bs)`` with
    ``R``/``B`` static (they fix the basis size and the chunk/batch
    factorisation of the merged axis)."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.core.pallas_step import tropical_compose

    epc = float(mc.elems_per_cycle)
    bpc = float(mc.axi_bytes_per_cycle)
    burst = float(mc.burst_bytes)
    ful = float(mc.fu_latency)
    att = attribution

    def run(fields, views, R, B):
        (kind, vl, sew, nb, stride, first, isdiv, redlv, dst, srcs,
         blast, sok, dhw) = fields
        (mem_lat, pf_hit, div_f, war_ovh, tx_ovh, idx_ovh, rw_turn,
         store_commit, issue_gap, d_chain, conflict, queue_adv,
         opt_m, opt_c, d_fwd) = (jnp.asarray(x) for x in views)
        L, M = kind.shape
        S = srcs.shape[2]
        W = mem_lat.shape[0]
        D = _NFIX + 3 * R
        n_chunks = M // B
        opt_mb = opt_m[None, :, None]
        opt_cb = opt_c[None, :, None]
        dci = jnp.minimum(d_chain, d_fwd)
        dcs = d_chain - dci

        # ---- row algebra -------------------------------------------------
        # A "row" is a pair (v, p): v[..., Db] values over the basis (or a
        # single absolute value in pass-2 value mode, Db == 1), p the
        # optional (..., Db, NCOMP) payload with sum(p) == v on finite
        # entries.  All primitives keep that invariant.
        def _exp(x):
            x = jnp.asarray(x, jnp.float64)
            return x[..., None] if x.ndim else x

        def rmax(a, b):
            """max(a, b); strict winners adopt b's payload (ties keep the
            incumbent a, matching the scan engine's `selc`)."""
            va, pa = a
            vb, pb = b
            take = vb > va
            v = jnp.where(take, vb, va)
            p = None if pa is None else jnp.where(take[..., None], pb, pa)
            return (v, p)

        def selr(mask, a, b):
            v = jnp.where(mask, a[0], b[0])
            p = (None if a[1] is None
                 else jnp.where(mask[..., None], a[1], b[1]))
            return (v, p)

        def sel_rmax(mask, a, b):
            """rmax(a, b) where `mask`, else a."""
            return selr(mask, rmax(a, b), a)

        def radd(a, amount, *bumps):
            """Shift a row by `amount`, charging the bump categories.
            The bump amounts must sum to `amount` (invariant)."""
            v, p = a
            v = v + _exp(amount)
            if p is not None:
                for ci, amt in bumps:
                    p = p.at[..., ci].add(_exp(amt))
            return (v, p)

        def rmax_shift(a, b, shift, cat):
            """``max(a, b + shift)`` for the scan engine's flattened
            state-dependent maxima (read_done / occupancy): where the
            shifted b wins over a *finite* a-entry, the excess is charged
            to `cat` on top of a's payload (the per-entry analogue of the
            scan's relu charge); where a's entry is -inf, b's payload is
            adopted with `shift` itself charged to `cat`.  Either way
            sum(p) == v stays exact."""
            va, pa = a
            vb = b[0] + _exp(shift)
            take = vb > va
            v = jnp.where(take, vb, va)
            if pa is None:
                return (v, None)
            fin = va > -jnp.inf
            extra = jnp.where(take & fin, vb - va, 0.0)
            p_flat = pa.at[..., cat].add(extra)
            p_adopt = b[1].at[..., cat].add(_exp(shift))
            p = jnp.where((take & ~fin)[..., None], p_adopt, p_flat)
            return (v, p)

        # Register-table rows: (M, R, W, Db) (+ payload).
        def gather_r(tab, idx):
            tv, tp = tab
            v = jnp.take_along_axis(
                tv, idx[:, None, None, None], axis=1)[:, 0]
            p = None if tp is None else jnp.take_along_axis(
                tp, idx[:, None, None, None, None], axis=1)[:, 0]
            return (v, p)

        def set_r(tab, oh, row):
            tv, tp = tab
            m = oh[:, :, None, None]
            v = jnp.where(m, row[0][:, None], tv)
            p = None if tp is None else jnp.where(m[..., None],
                                                  row[1][:, None], tp)
            return (v, p)

        def rmax_r(tab, oh, row):
            tv, tp = tab
            cand = row[0][:, None]
            take = oh[:, :, None, None] & (cand > tv)
            v = jnp.where(take, cand, tv)
            p = None if tp is None else jnp.where(take[..., None],
                                                  row[1][:, None], tp)
            return (v, p)

        # ---- the per-instruction row step --------------------------------
        # One body serves both passes: pass 1 runs it on basis rows
        # (Db == D, payloads when attributing) to build transfer matrices;
        # pass 2 on absolute values (Db == 1, no payload) to collect the
        # per-instruction observables.  It mirrors the `lax.scan` step of
        # `batch_sim._build_jax_sweep` branch for branch.
        def make_step(zero, collect):
            def step(s, x):
                (k, vl_i, sew_i, nb_i, str_i, fs_i, dv_i, rl_i, d_i,
                 sr_i, bl_i, sok_i, dhw_i) = x
                valid = (k != PAD)[:, None, None]
                is_load = (k == _LOAD)[:, None, None]
                is_store = (k == _STORE)[:, None, None]
                is_red = (k == _REDUCE)[:, None, None]
                is_slide = (k == _SLIDE)[:, None, None]
                vl2 = vl_i[:, None]

                # ---- dependence constraints (RAW / WAR / WAW) ----------
                raws = s["issue"]
                rc = zero
                for j in range(S):
                    srcc = jnp.clip(sr_i[:, j], 0, R - 1)
                    ok = sok_i[:, j][:, None, None]
                    wf = gather_r(s["w_first"], srcc)
                    wc = gather_r(s["w_compl"], srcc)
                    raws = sel_rmax(ok, raws,
                                    radd(wf, d_chain, (IDEAL, dci),
                                         (OPR_CHAIN_DELAY, dcs)))
                    rc = sel_rmax(ok, rc,
                                  radd(wc, d_chain, (IDEAL, dci),
                                       (OPR_CHAIN_DELAY, dcs)))
                dstc = jnp.clip(d_i, 0, R - 1)
                has_dst = (d_i >= 0)[:, None, None]
                wg = selr(has_dst,
                          rmax(zero, gather_r(s["r_rel"], dstc)), zero)
                waw = has_dst & dhw_i[:, None, None]
                wg = sel_rmax(waw, wg, gather_r(s["w_first"], dstc))

                # ---- memory-op shared constants ------------------------
                nburst = jnp.maximum(1.0, jnp.ceil(nb_i / burst))[:, None]
                indexed = (str_i == _INDEXED)[:, None]
                dur_bus = jnp.where(
                    indexed, vl2 * (sew_i[:, None] / bpc) + vl2 * idx_ovh,
                    nb_i[:, None] / bpc + nburst * tx_ovh)
                dur_ideal_m = jnp.where(indexed,
                                        vl2 * (sew_i[:, None] / bpc),
                                        nb_i[:, None] / bpc)
                dur_stall_m = dur_bus - dur_ideal_m

                # ---- LOAD path -----------------------------------------
                turn_l = jnp.where((bl_i == _STORE)[:, None], rw_turn, 0.0)
                req = rmax(rmax(rmax(s["issue"], raws), s["addr"]),
                           radd(s["bus"], turn_l,
                                (MEM_RW_TURNAROUND, turn_l)))
                req = rmax(req, wg)
                lat_unit = jnp.where(fs_i[:, None], mem_lat, pf_hit)
                lat_str = jnp.where(fs_i[:, None], mem_lat,
                                    0.5 * (mem_lat + pf_hit))
                lat_m = jnp.where((str_i == _UNIT)[:, None], lat_unit,
                                  jnp.where((str_i == _STRIDED)[:, None],
                                            lat_str, mem_lat))
                lat = jnp.where(opt_m[None, :], lat_m, mem_lat)
                lat_ideal = jnp.minimum(lat, pf_hit)
                lat_stall = lat - lat_ideal
                data_done = radd(req, lat + dur_bus,
                                 (IDEAL, lat_ideal + dur_ideal_m),
                                 (MEM_DEMAND_LATENCY, lat_stall),
                                 (MEM_TX_OVERHEAD, dur_stall_m))
                fo_l = rmax(radd(req, lat + burst / bpc,
                                 (IDEAL, lat_ideal + burst / bpc),
                                 (MEM_DEMAND_LATENCY, lat_stall)), wg)
                cp_l = rmax(data_done,
                            radd(wg, vl2 / epc, (IDEAL, vl2 / epc)))
                rd_l = req
                busf_l = radd(req, dur_bus, (IDEAL, dur_ideal_m),
                              (MEM_TX_OVERHEAD, dur_stall_m))
                addr_l = selr(opt_mb, req, busf_l)

                # ---- STORE path ----------------------------------------
                bs1 = rmax(rmax(raws, wg), s["addr"])
                bss = rmax(bs1, s["wbus"])
                turn_s = jnp.where((bl_i == _LOAD)[:, None], rw_turn, 0.0)
                bsu = rmax(bs1, radd(s["bus"], turn_s,
                                     (MEM_RW_TURNAROUND, turn_s)))
                bs_s = selr(opt_mb, bss, bsu)
                wbus_s = selr(opt_mb,
                              radd(bss, dur_bus, (IDEAL, dur_ideal_m),
                                   (MEM_TX_OVERHEAD, dur_stall_m)),
                              s["wbus"])
                busf_s = selr(
                    opt_mb,
                    radd(rmax(s["bus"], bss), dur_bus,
                         (IDEAL, dur_ideal_m),
                         (MEM_TX_OVERHEAD, dur_stall_m)),
                    radd(bsu, dur_bus + store_commit,
                         (IDEAL, dur_ideal_m),
                         (MEM_TX_OVERHEAD, dur_stall_m),
                         (MEM_STORE_COMMIT, store_commit)))
                cp_s = rmax(radd(bs_s, dur_bus + mem_lat,
                                 (IDEAL, dur_ideal_m),
                                 (MEM_TX_OVERHEAD, dur_stall_m),
                                 (MEM_STORE_COMMIT, mem_lat)), rc)
                # read_done: max(t1, t2) with a state-independent gap, so
                # the scan's relu charge is a plain shift here.
                q_s = jnp.maximum(dur_bus - queue_adv - vl2 / epc, 0.0)
                rd_s = radd(bs_s, vl2 / epc + q_s, (IDEAL, vl2 / epc),
                            (OPR_QUEUE_LIMIT, q_s))
                addr_s = selr(opt_mb, bs_s,
                              radd(bs_s, dur_bus, (IDEAL, dur_ideal_m),
                                   (MEM_TX_OVERHEAD, dur_stall_m)))

                # ---- COMPUTE / REDUCE / SLIDE path ---------------------
                dur_c = jnp.where(dv_i[:, None], (vl2 / epc) * div_f,
                                  (vl2 / epc) * conflict) \
                    + rl_i[:, None] * ful
                dur_ideal_c = jnp.where(dv_i[:, None],
                                        (vl2 / epc) * div_f,
                                        vl2 / epc) + rl_i[:, None] * ful
                dur_stall_c = dur_c - dur_ideal_c
                unit = selr(is_slide, s["sldu"], s["fpu"])
                bs_c = rmax(rmax(raws, wg), unit)
                cp_c = rmax(radd(bs_c, ful + dur_c,
                                 (IDEAL, ful + dur_ideal_c),
                                 (OPR_BANK_CONFLICT, dur_stall_c)), rc)
                fo_c = selr(is_red, cp_c, radd(bs_c, ful, (IDEAL, ful)))
                rd_c = rmax_shift(radd(bs_c, vl2 / epc,
                                       (IDEAL, vl2 / epc)),
                                  cp_c, -(ful + queue_adv),
                                  OPR_QUEUE_LIMIT)
                occ = rmax_shift(radd(bs_c, dur_c, (IDEAL, dur_ideal_c),
                                      (OPR_BANK_CONFLICT, dur_stall_c)),
                                 cp_c, -ful, OPR_CHAIN_DELAY)

                # ---- merge by kind & update state ----------------------
                bs_row = selr(is_load, req, selr(is_store, bs_s, bs_c))
                cp_row = selr(is_load, cp_l, selr(is_store, cp_s, cp_c))
                fo_row = selr(is_load, fo_l, selr(is_store, cp_s, fo_c))
                rd_row = selr(is_load, rd_l, selr(is_store, rd_s, rd_c))
                is_mem = is_load | is_store
                is_comp = valid & ~is_mem
                ns = dict(s)
                ns["bus"] = selr(valid & is_mem,
                                 selr(is_load, busf_l, busf_s), s["bus"])
                ns["addr"] = selr(valid & is_mem,
                                  selr(is_load, addr_l, addr_s),
                                  s["addr"])
                ns["wbus"] = selr(valid & is_store, wbus_s, s["wbus"])
                ns["sldu"] = selr(is_comp & is_slide, occ, s["sldu"])
                ns["fpu"] = selr(is_comp & ~is_slide, occ, s["fpu"])
                ns["issue"] = selr(valid,
                                   radd(s["issue"], issue_gap,
                                        (DEP_ISSUE_GAP, issue_gap)),
                                   s["issue"])
                ns["total"] = selr(valid, rmax(s["total"], cp_row),
                                   s["total"])
                oh_dst = ((jnp.arange(R)[None, :] == dstc[:, None])
                          & (k != PAD)[:, None] & (d_i >= 0)[:, None])
                ns["w_first"] = set_r(s["w_first"], oh_dst, fo_row)
                ns["w_compl"] = set_r(s["w_compl"], oh_dst, cp_row)
                rel = selr(opt_cb, rd_row,
                           radd(cp_row, war_ovh,
                                (DEP_WAR_RELEASE, war_ovh)))
                rr = s["r_rel"]
                for j in range(S):
                    src = sr_i[:, j]
                    srcc = jnp.clip(src, 0, R - 1)
                    oh = ((jnp.arange(R)[None, :] == srcc[:, None])
                          & (k != PAD)[:, None] & (src >= 0)[:, None])
                    rr = rmax_r(rr, oh, rel)
                ns["r_rel"] = rr
                if collect:
                    return ns, (fo_row[0][..., 0], cp_row[0][..., 0],
                                bs_row[0][..., 0])
                return ns, None

            return step

        C = NCOMP

        # ---- pass 1: basis rows -> per-chunk transfer matrices ----------
        def basis_row(d):
            v = jnp.full((D,), -jnp.inf,
                         jnp.float64).at[d].set(0.0)
            v = jnp.broadcast_to(v, (M, W, D))
            p = (jnp.zeros((M, W, D, C), jnp.float64) if att else None)
            return (v, p)

        def basis_tab(offset):
            v = jnp.where(jnp.arange(D)[None, :]
                          == (offset + jnp.arange(R))[:, None],
                          0.0, -jnp.inf)
            v = jnp.broadcast_to(v[None, :, None, :], (M, R, W, D))
            p = (jnp.zeros((M, R, W, D, C), jnp.float64) if att else None)
            return (v, p)

        s1 = dict(issue=basis_row(_ISSUE), bus=basis_row(_BUS),
                  wbus=basis_row(_WBUS), addr=basis_row(_ADDR),
                  fpu=basis_row(_FPU), sldu=basis_row(_SLDU),
                  total=basis_row(_TOTAL),
                  w_first=basis_tab(_NFIX), w_compl=basis_tab(_NFIX + R),
                  r_rel=basis_tab(_NFIX + 2 * R))
        s1, _ = lax.scan(make_step(basis_row(_CONST), False), s1, fields)

        def tab_rows(t):
            return jnp.moveaxis(t, 1, 2)           # (M,R,W,..) -> (M,W,R,..)

        const = basis_row(_CONST)
        mat_v = jnp.concatenate([
            jnp.stack([const[0], s1["issue"][0], s1["bus"][0],
                       s1["wbus"][0], s1["addr"][0], s1["fpu"][0],
                       s1["sldu"][0], s1["total"][0]], axis=2),
            tab_rows(s1["w_first"][0]), tab_rows(s1["w_compl"][0]),
            tab_rows(s1["r_rel"][0]),
        ], axis=2).reshape(n_chunks, B, W, D, D)
        if att:
            mat_p = jnp.concatenate([
                jnp.stack([const[1], s1["issue"][1], s1["bus"][1],
                           s1["wbus"][1], s1["addr"][1], s1["fpu"][1],
                           s1["sldu"][1], s1["total"][1]], axis=2),
                tab_rows(s1["w_first"][1]), tab_rows(s1["w_compl"][1]),
                tab_rows(s1["r_rel"][1]),
            ], axis=2).reshape(n_chunks, B, W, D, D, C)
        else:
            mat_p = None

        # ---- log-depth composition of the chunk matrices ----------------
        def combine(a, b):
            va, pa = a
            vb, pb = b
            c, kk = tropical_compose(vb, va, use_pallas=use_pallas)
            if pa is None:
                return (c, None)
            pb_g = jnp.take_along_axis(pb, kk[..., None], axis=-2)
            pa_t = jnp.swapaxes(pa, -3, -2)
            pa_g = jnp.take_along_axis(
                pa_t, jnp.swapaxes(kk, -1, -2)[..., None], axis=-2)
            return (c, pb_g + jnp.swapaxes(pa_g, -3, -2))

        prefix_v, prefix_p = lax.associative_scan(
            combine, (mat_v, mat_p), axis=0)

        # cycles (+ attribution) from the full composition applied to the
        # zero initial state: value = max over basis columns of the
        # `total` row; payload rides the argmax column.
        last_v = prefix_v[-1]                       # (B, W, D, D)
        cyc = jnp.max(last_v[..., _TOTAL, :], axis=-1)
        if att:
            j_star = jnp.argmax(last_v[..., _TOTAL, :], axis=-1)
            comp = jnp.take_along_axis(
                prefix_p[-1][..., _TOTAL, :, :],
                j_star[..., None, None], axis=-2)[..., 0, :]
        else:
            comp = cyc

        # chunk-entry states: exclusive prefixes applied to state 0.
        entry_v = jnp.max(prefix_v, axis=-1)        # (nC, B, W, D)
        entry_v = jnp.concatenate(
            [jnp.zeros_like(entry_v[:1]), entry_v[:-1]], axis=0)
        entry_m = entry_v.reshape(M, W, D)

        # ---- pass 2: value mode over all chunks in parallel -------------
        def vrow(ci):
            return (entry_m[..., ci][..., None], None)

        def vtab(lo):
            return (jnp.moveaxis(entry_m[..., lo:lo + R], 2, 1)[..., None],
                    None)

        s2 = dict(issue=vrow(_ISSUE), bus=vrow(_BUS), wbus=vrow(_WBUS),
                  addr=vrow(_ADDR), fpu=vrow(_FPU), sldu=vrow(_SLDU),
                  total=vrow(_TOTAL), w_first=vtab(_NFIX),
                  w_compl=vtab(_NFIX + R), r_rel=vtab(_NFIX + 2 * R))
        zero2 = (jnp.zeros((M, W, 1), jnp.float64), None)
        _, ys = lax.scan(make_step(zero2, True), s2, fields)
        fo, cp, bs = ys                             # each (L, M, W)
        return cyc, comp, fo, cp, bs

    return jax.jit(run, static_argnums=(2, 3))


_FNS: dict[tuple, object] = {}

#: (fn key, shape signature) pairs already traced by jit — used to label
#: the first call on a fresh signature as "exec.assoc.compile" vs the
#: cached-callable "exec.assoc.execute" (see docs/observability.md).
_SEEN: set[tuple] = set()


def _get_fn(mc: MachineConfig, attribution: bool, use_pallas: bool):
    key = (dataclasses.astuple(mc), bool(attribution), bool(use_pallas))
    fn = _FNS.get(key)
    if fn is None:
        fn = _build_assoc(mc, attribution, use_pallas)
        _FNS[key] = fn
    return fn


def run_assoc(mc: MachineConfig, st: StackedTraces, view,
              attribution: bool = False, chunk: int | None = None,
              use_pallas: bool = False):
    """Evaluate the grid with the associative-scan engine.

    Returns the same 7-tuple as `BatchAraSimulator._run_numpy` /
    `_run_jax`: ``(cycles, busy_fpu, busy_bus, comp, lane_first_out,
    first_first_out, finish_start)`` with ``(B, W)`` arrays (comp is
    ``(B, W, NCOMP)`` or None).
    """
    from jax.experimental import enable_x64

    chunk = int(chunk or DEFAULT_CHUNK)
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    B, I = st.kind.shape
    R = max(int(st.max_regs), 1)
    W = view.width
    est = assoc_bytes(I, B, W, R, attribution, chunk)
    limit = float(os.environ.get(MEM_LIMIT_ENV, DEFAULT_MEM_LIMIT))
    obs_metrics.gauge("assoc.mem_estimate_bytes").set(est)
    obs_metrics.gauge("assoc.mem_headroom_bytes").set(limit - est)
    if est > limit:
        raise ValueError(
            f"assoc transfer matrices would need ~{est / 2**30:.1f} GiB "
            f"(> {limit / 2**30:.1f} GiB limit; I={I} B={B} W={W} "
            f"D={basis_dim(R)} chunk={chunk}"
            f"{' with attribution' if attribution else ''}): raise "
            f"`chunk`, set ${MEM_LIMIT_ENV}, or use method='scan'")
    fields, n_chunks, I2 = _prep(st, chunk)
    views = dataclasses.astuple(view)
    with enable_x64():
        fn = _get_fn(mc, attribution, use_pallas)
        sig = (dataclasses.astuple(mc), bool(attribution),
               bool(use_pallas), st.kind.shape, st.srcs.shape[2], W, R,
               chunk)
        name = ("exec.assoc.compile" if sig not in _SEEN
                else "exec.assoc.execute")
        with obs_spans.span(name, batch=B, width=W, n_instrs=I,
                            chunk=chunk):
            cyc, comp, fo, cp, bs = fn(fields, views, R, B)
            cyc = np.asarray(cyc)
            comp = np.asarray(comp) if attribution else None
            fo, cp, bs = (np.asarray(a) for a in (fo, cp, bs))
        _SEEN.add(sig)

    def im(a):            # (L, nC*B, W) -> (I, B, W)
        a = a.reshape(chunk, n_chunks, B, W).transpose(1, 0, 2, 3)
        return a.reshape(I2, B, W)[:I]

    fo, cp, bs = im(fo), im(cp), im(bs)

    # ---- phase observables (host post-pass over pass-2 outputs) --------
    kind = np.swapaxes(st.kind, 0, 1)               # (I, B)
    valid = kind != PAD
    lane_mask = valid & (kind != _LOAD) & (kind != _STORE)
    lane_fo = np.where(lane_mask[..., None], fo, np.inf).min(axis=0)
    first_idx = np.argmax(valid, axis=0)            # first valid instr
    first_fo = np.take_along_axis(
        fo, first_idx[None, :, None], axis=0)[0]
    # finishing instruction = first strict-argmax of completes (matches
    # the sequential `complete > running_total` adoption rule).
    fin_idx = np.argmax(np.where(valid[..., None], cp, -np.inf), axis=0)
    fin_start = np.take_along_axis(bs, fin_idx[None], axis=0)[0]

    # ---- busy counters: closed-form sums over trace constants ----------
    epc = float(mc.elems_per_cycle)
    bpc = float(mc.axi_bytes_per_cycle)
    vl = np.swapaxes(st.vl, 0, 1).astype(np.float64)
    sew = np.swapaxes(st.sew, 0, 1).astype(np.float64)
    nb = np.swapaxes(st.nbytes, 0, 1).astype(np.float64)
    stridea = np.swapaxes(st.stride, 0, 1)
    fmask = valid & ((kind == _COMPUTE) | (kind == _REDUCE))
    busy_fpu = np.broadcast_to(
        np.add.reduce(np.where(fmask, vl / epc, 0.0), axis=0)[:, None],
        (B, W)).copy()
    mem = valid & ((kind == _LOAD) | (kind == _STORE))
    idxm = mem & (stridea == _INDEXED)
    lin = mem & (stridea != _INDEXED)
    nburst = np.maximum(1.0, np.ceil(nb / float(mc.burst_bytes)))
    busy_bus = (
        (np.add.reduce(np.where(lin, nb / bpc, 0.0), axis=0)
         + np.add.reduce(np.where(idxm, vl * (sew / bpc), 0.0),
                         axis=0))[:, None]
        + np.add.reduce(np.where(lin, nburst, 0.0), axis=0)[:, None]
        * np.asarray(view.tx_ovh)[None, :]
        + np.add.reduce(np.where(idxm, vl, 0.0), axis=0)[:, None]
        * np.asarray(view.idx_ovh)[None, :])
    return cyc, busy_fpu, busy_bus, comp, lane_fo, first_fo, fin_start
