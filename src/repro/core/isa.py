"""Vector-ISA trace IR for the Ara sustained-throughput simulator.

The paper analyzes dependent vector-instruction chains (vle -> vfmul ->
vfadd -> vse) executing on a multi-lane RVV machine.  We represent a kernel
as a program-ordered list of strip-mined vector instructions; the simulator
(`repro.core.simulator`) assigns cycle timings under baseline-Ara or Ara-Opt
semantics.

Register semantics follow RVV: a named vector register (group) is written by
exactly one in-flight producer at a time; RAW consumers may chain off the
producer's first results; WAR (a writer overwriting a register still being
read) is the hazard whose release policy the paper's C-optimization changes.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterator, Sequence


class OpKind(enum.Enum):
    LOAD = "load"          # vector load (vle / vlse / vluxei)
    STORE = "store"        # vector store (vse / vsse / vsuxei)
    COMPUTE = "compute"    # lane FPU/ALU op (vfmul, vfadd, vfmacc, ...)
    REDUCE = "reduce"      # vfredsum-style reduction (scalar-out)
    SLIDE = "slide"        # vslideup/down, gathers within VRF (SLDU)


class Stride(enum.Enum):
    UNIT = "unit"          # vle32.v   — prefetchable
    STRIDED = "strided"    # vlse32.v  — partially prefetchable
    INDEXED = "indexed"    # vluxei32  — gather; not prefetchable


@dataclasses.dataclass(frozen=True)
class VInstr:
    """One strip-mined vector instruction.

    Attributes:
      name: mnemonic, for debugging ("vle32", "vfmacc", ...).
      kind: resource class.
      vl: number of elements processed by this strip.
      sew: element width in bytes.
      dst: destination register name or None (stores, scalar-out reduces).
      srcs: vector register names read by this instruction.
      stride: memory access pattern (memory ops only).
      flops: floating-point ops performed (vl * flops_per_element).
      stream: identity of the memory stream this op belongs to (prefetcher
        state is tracked per stream; e.g. all strips of "x" share a stream).
      first_strip: True for the first strip of a memory stream (prefetch
        cannot have warmed the buffer yet).
    """
    name: str
    kind: OpKind
    vl: int
    sew: int = 4
    dst: str | None = None
    srcs: tuple[str, ...] = ()
    stride: Stride = Stride.UNIT
    flops: int = 0
    stream: str = ""
    first_strip: bool = False

    @property
    def bytes(self) -> int:
        if self.kind in (OpKind.LOAD, OpKind.STORE):
            return self.vl * self.sew
        return 0


@dataclasses.dataclass(frozen=True)
class KernelTrace:
    """A complete kernel: instruction stream plus roofline accounting."""
    name: str
    instrs: tuple[VInstr, ...]
    total_flops: int          # useful FLOPs (roofline numerator)
    total_bytes: int          # bytes that must cross the memory interface
    problem: str = ""         # human-readable problem size

    @property
    def operational_intensity(self) -> float:
        return self.total_flops / max(self.total_bytes, 1)


def strips(n: int, vlmax: int) -> Iterator[int]:
    """Strip-mine n elements into vector lengths of at most vlmax."""
    done = 0
    while done < n:
        vl = min(vlmax, n - done)
        yield vl
        done += vl


def vlmax_for(sew: int, vlen_bits: int, lmul: int) -> int:
    return (vlen_bits * lmul) // (8 * sew)


@dataclasses.dataclass(frozen=True)
class MachineConfig:
    """Fixed hardware configuration (paper §VI.A: 4 lanes, VLEN=1024,
    DLEN=256, 128-bit AXI at 1 GHz => 16 GB/s, 16 GFLOPS fp32 peak)."""
    lanes: int = 4
    vlen_bits: int = 1024
    dlen_bits: int = 256
    axi_bytes_per_cycle: int = 16      # 128-bit AXI @ 1 GHz
    freq_ghz: float = 1.0
    fu_latency: int = 5                # FPU pipeline depth (cycles)
    burst_bytes: int = 64              # AXI burst granule for tx accounting

    @property
    def elems_per_cycle(self) -> int:
        """fp32 elements the lane datapath retires per cycle (DLEN-wide)."""
        return self.dlen_bits // 32

    @property
    def peak_flops(self) -> float:
        """fp32 FMA peak: DLEN/32 FMA/cycle * 2 flops (paper: 16 GFLOPS)."""
        return self.elems_per_cycle * 2 * self.freq_ghz * 1e9

    @property
    def peak_bw(self) -> float:
        return self.axi_bytes_per_cycle * self.freq_ghz * 1e9


@dataclasses.dataclass(frozen=True)
class OptConfig:
    """Which Ara-Opt optimization classes are enabled (paper Table I)."""
    memory: bool = False      # M: decoupled front end + next-VL prefetch
    control: bool = False     # C: early read-dep release + dynamic issue
    operand: bool = False     # O: multi-source forwarding + dual-source queues

    @classmethod
    def baseline(cls) -> "OptConfig":
        return cls(False, False, False)

    @classmethod
    def full(cls) -> "OptConfig":
        return cls(True, True, True)

    @property
    def label(self) -> str:
        if not (self.memory or self.control or self.operand):
            return "base"
        parts = [n for n, on in (("M", self.memory), ("C", self.control),
                                 ("O", self.operand)) if on]
        return "+".join(parts)


ABLATION_GRID: tuple[OptConfig, ...] = (
    OptConfig(True, False, False),   # M
    OptConfig(False, True, False),   # C
    OptConfig(False, False, True),   # O
    OptConfig(True, True, False),    # M+C
    OptConfig(True, False, True),    # M+O
    OptConfig(False, True, True),    # C+O
    OptConfig(True, True, True),     # All
)


def geomean(xs: Sequence[float]) -> float:
    xs = list(xs)
    if not xs:
        return float("nan")
    return math.exp(sum(math.log(max(x, 1e-30)) for x in xs) / len(xs))
