"""Zero-dependency context-manager span tracer.

The attribution engine explains every *simulated* cycle; this module
gives the *simulator's own* wall-clock the same treatment: nested, timed
spans over the phases of a `simulate()` call (trace stacking, plan
resolution, backend dispatch, per-chunk execution, jax compile vs.
execute) so a perf claim about the host pipeline is decomposable instead
of one opaque number.

Design constraints, in priority order:

1. **Disabled-by-default with near-zero overhead.**  `span(...)` on a
   disabled tracer returns one shared no-op context manager — the cost
   is a single attribute check plus the caller's kwargs dict.  The hot
   loops (per-instruction scans) are *never* instrumented; spans wrap
   phase boundaries only, so even enabled tracing is O(phases), not
   O(instructions).
2. **Thread-safe collection.**  Span nesting is tracked per thread
   (`threading.local` stacks); finished spans land in one lock-guarded
   list so concurrent `simulate()` calls (the serving direction,
   ROADMAP item 4) interleave safely.
3. **Monotonic-clock durations.**  `time.perf_counter()` throughout;
   `export.py` normalizes to trace-relative microseconds.

Enable explicitly (`enable()` / `REPRO_OBS=1`) or implicitly by asking
for a runlog (`REPRO_RUNLOG=path` or `simulate(..., runlog=...)` — see
`repro.obs.export`).  Span taxonomy: docs/observability.md.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import threading
import time

__all__ = ["Span", "Tracer", "TRACER", "span", "enable", "disable",
           "enabled", "current"]


@dataclasses.dataclass
class Span:
    """One finished (or in-flight) span.

    ``start``/``end`` are `time.perf_counter()` seconds — monotonic and
    comparable only within a process; ``sid``/``parent`` link the tree;
    ``tid`` is a small per-thread ordinal (stable track ids for the
    Chrome exporter, not OS thread ids).
    """
    name: str
    sid: int
    parent: int | None
    tid: int
    start: float
    end: float | None = None
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        """Seconds; 0.0 while the span is still open."""
        return (self.end - self.start) if self.end is not None else 0.0


class _NullSpan:
    """Shared no-op context manager returned by a disabled tracer."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL = _NullSpan()


class _LiveSpan:
    """Context manager recording one span into its tracer."""
    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self._span = Span(name=name, sid=next(tracer._ids), parent=None,
                          tid=0, start=0.0, attrs=attrs)

    def set(self, **attrs):
        """Attach/overwrite key-value attributes on the open span."""
        self._span.attrs.update(attrs)
        return self

    def __enter__(self):
        tr = self._tracer
        stack = tr._stack()
        sp = self._span
        sp.tid = tr._thread_ordinal()
        sp.parent = stack[-1].sid if stack else None
        stack.append(sp)
        sp.start = time.perf_counter()     # last: exclude setup from dur
        return self

    def __exit__(self, *exc):
        sp = self._span
        sp.end = time.perf_counter()       # first: exclude teardown
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] is sp:
            stack.pop()
        else:                              # pragma: no cover - misuse
            # Mis-nested exit (spans closed out of order): drop down to
            # this span if present, else leave the stack untouched.
            if sp in stack:
                del stack[stack.index(sp):]
        with tr._lock:
            tr._done.append(sp)
        return False


class Tracer:
    """Thread-safe span collector with per-thread nesting stacks."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._done: list[Span] = []
        self._ids = itertools.count(1)
        self._local = threading.local()
        self._tids: dict[int, int] = {}

    # -- span creation ----------------------------------------------------
    def span(self, name: str, **attrs):
        """Open a span context manager (no-op while disabled)."""
        if not self.enabled:
            return _NULL
        return _LiveSpan(self, name, attrs)

    def current(self):
        """The innermost open span on this thread (no-op if none/off)."""
        if not self.enabled:
            return _NULL
        stack = self._stack()
        if not stack:
            return _NULL
        # Wrap the open Span so callers get the same .set() surface.
        live = _LiveSpan.__new__(_LiveSpan)
        live._tracer = self
        live._span = stack[-1]
        return live

    # -- lifecycle --------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def drain(self) -> list[Span]:
        """Return and clear all *finished* spans (open spans stay put and
        surface at a later drain, after they close)."""
        with self._lock:
            out, self._done = self._done, []
        return out

    def snapshot(self) -> list[Span]:
        """Finished spans without clearing them."""
        with self._lock:
            return list(self._done)

    # -- internals --------------------------------------------------------
    def _stack(self) -> list[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _thread_ordinal(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid


#: Process-wide default tracer; `REPRO_OBS=1` (or any runlog target, see
#: `repro.obs.export.runlog_target`) switches it on at import.
TRACER = Tracer(enabled=bool(os.environ.get("REPRO_OBS")
                             or os.environ.get("REPRO_RUNLOG")))


def span(name: str, **attrs):
    """`TRACER.span` shorthand — the call sites' one-liner."""
    if not TRACER.enabled:                 # fast path, no method dispatch
        return _NULL
    return _LiveSpan(TRACER, name, attrs)


def current():
    return TRACER.current()


def enable() -> None:
    TRACER.enable()


def disable() -> None:
    TRACER.disable()


def enabled() -> bool:
    return TRACER.enabled
