"""Telemetry substrate: spans, metrics, runlog/trace export.

- `repro.obs.spans` — nested wall-clock spans over simulate() phases.
- `repro.obs.metrics` — process-local counters/gauges/histograms.
- `repro.obs.export` — JSON-lines runlog, Chrome-trace merge with
  `analysis/timeline.py`, and `summarize_runlog()`.

See docs/observability.md for the span taxonomy and metric table.
"""
from repro.obs.spans import span, enable, disable, enabled, TRACER  # noqa: F401
from repro.obs.metrics import REGISTRY, KNOWN_METRICS  # noqa: F401
from repro.obs.export import (  # noqa: F401
    flush, read_runlog, runlog_target, summarize_runlog,
    export_merged_trace,
)
