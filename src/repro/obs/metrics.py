"""Process-local metrics registry: counters, gauges, histograms.

Where `obs.spans` answers "where did the wall-clock go", this module
answers "how often / how much": cache hit rates, auto-plan decisions,
memory-guard headroom, calibration population counts.  Everything is
process-local and lock-guarded — no sockets, no background threads, no
dependencies — matching the repo's zero-infra telemetry posture.

Instruments:

- :class:`Counter` — monotonically increasing float (``inc``).
- :class:`Gauge` — last-written float (``set``).
- :class:`Histogram` — fixed bucket edges chosen at creation;
  ``observe`` records count/sum plus a cumulative-bucket vector, so
  percentiles are approximable without retaining samples.

Instruments may carry a single ``label`` value (e.g.
``plan.auto_backend`` labeled ``"numpy"`` vs ``"jax"``); each
(name, label) pair is an independent instrument.

Every metric *name* emitted anywhere in the repo must appear in
:data:`KNOWN_METRICS`, and that dict is CI-synced against the table in
``docs/observability.md`` (tools/check_docs.py) — the same contract the
SimParams knob table uses.  `repro.obs.export.check_metric_names`
enforces the registry side on recorded runlogs.
"""
from __future__ import annotations

import bisect
import threading

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
           "KNOWN_METRICS", "counter", "gauge", "histogram"]


#: name -> one-line description.  The single source of truth for which
#: metric names exist; docs/observability.md mirrors this table and CI
#: fails on divergence in either direction.
KNOWN_METRICS = {
    "simulate.calls": "api.simulate invocations",
    "simulate.cells": "grid cells (opts x params) executed by simulate",
    "simulate.wall_us": "histogram of simulate() wall-clock, microseconds",
    "plan.resolved": "resolve_plan calls",
    "plan.auto_backend": "auto backend decisions, labeled numpy|jax",
    "plan.auto_method": "auto method decisions, labeled scan|assoc",
    "plan.auto_bucket": "auto bucket decisions, labeled none|pow2",
    "plan.auto_shard": "auto shard decisions, labeled none|devices",
    "plan.pipeline_chunks": "p_chunk dispatches through the async pipeline",
    "plan.pipeline_occupancy": "dispatch share of pipelined jax wall-clock",
    "bucket.groups": "shape buckets executed by run_bucketed",
    "bucket.baseline_waste_share": "pad-waste share of the unbucketed stack",
    "bucket.pad_waste_share": "pad-waste share after shape bucketing",
    "sweep_cache.hits": "SweepCache lookups served from disk",
    "sweep_cache.misses": "SweepCache lookups that required simulation",
    "sweep_cache.evictions": "SweepCache entries removed by LRU pruning",
    "sweep_cache.put_bytes": "bytes written into the SweepCache",
    "assoc.mem_estimate_bytes": "assoc engine's estimated peak bytes",
    "assoc.mem_headroom_bytes": "memory-guard limit minus the estimate",
    "calibration.populations": "candidate populations scored",
    "calibration.candidates": "individual SimParams candidates scored",
    "sensitivity.cells": "sensitivity-grid cells evaluated",
    "simulate.groups": "per-corner groups run by api.simulate_groups",
    "search.populations": "design-search populations batch-scored",
    "search.candidates": "individual designs scored by the search",
    "search.frontier_size": "current Pareto-frontier size (gauge)",
    "serve.requests": "serving-engine generate() requests",
    "serve.tokens": "tokens decoded by the serving engine",
}


class Counter:
    """Monotonic counter (floats allowed: byte totals, cell counts)."""
    __slots__ = ("name", "label", "value", "_lock")

    def __init__(self, name: str, label: str | None = None):
        self.name = name
        self.label = label
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        with self._lock:
            self.value += amount

    def snapshot(self) -> dict:
        return {"type": "counter", "name": self.name, "label": self.label,
                "value": self.value}


class Gauge:
    """Last-written value (e.g. current memory headroom)."""
    __slots__ = ("name", "label", "value", "_lock")

    def __init__(self, name: str, label: str | None = None):
        self.name = name
        self.label = label
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def snapshot(self) -> dict:
        return {"type": "gauge", "name": self.name, "label": self.label,
                "value": self.value}


#: Default bucket edges: microsecond-scaled log ladder wide enough for
#: both a cache-hit lookup (~100 us) and a full-grid jax compile (~60 s).
DEFAULT_BUCKETS = (1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8)


class Histogram:
    """Fixed-bucket histogram; records count, sum, and bucket counts.

    ``buckets`` are upper edges (inclusive), ascending; values above the
    last edge land in the implicit +inf bucket.
    """
    __slots__ = ("name", "label", "buckets", "counts", "count", "sum",
                 "_lock")

    def __init__(self, name: str, label: str | None = None,
                 buckets: tuple = DEFAULT_BUCKETS):
        if list(buckets) != sorted(buckets):
            raise ValueError(f"histogram {name}: bucket edges not ascending")
        self.name = name
        self.label = label
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)  # +1 for +inf
        self.count = 0
        self.sum = 0.0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        idx = bisect.bisect_left(self.buckets, value)
        with self._lock:
            self.counts[idx] += 1
            self.count += 1
            self.sum += value

    def snapshot(self) -> dict:
        with self._lock:
            return {"type": "histogram", "name": self.name,
                    "label": self.label, "buckets": list(self.buckets),
                    "counts": list(self.counts), "count": self.count,
                    "sum": self.sum}


class Registry:
    """Process-local instrument registry keyed on (name, label).

    ``counter``/``gauge``/``histogram`` are get-or-create and enforce
    that a (name, label) pair keeps one instrument type for the process
    lifetime.  Unknown names are allowed at runtime (the registry is a
    library, not a linter) — CI catches them via
    `export.check_metric_names` against :data:`KNOWN_METRICS`.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[tuple, object] = {}

    def _get(self, cls, name: str, label: str | None, **kwargs):
        key = (name, label)
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = self._instruments[key] = cls(name, label, **kwargs)
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {key} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str, label: str | None = None) -> Counter:
        return self._get(Counter, name, label)

    def gauge(self, name: str, label: str | None = None) -> Gauge:
        return self._get(Gauge, name, label)

    def histogram(self, name: str, label: str | None = None,
                  buckets: tuple = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, label, buckets=buckets)

    def snapshot(self) -> list[dict]:
        """Point-in-time dump of every instrument, sorted by (name, label)."""
        with self._lock:
            instruments = list(self._instruments.values())
        return sorted((inst.snapshot() for inst in instruments),
                      key=lambda s: (s["name"], s["label"] or ""))

    def reset(self) -> None:
        """Drop all instruments (tests only — callers cache instrument
        handles, so resetting mid-run orphans their updates)."""
        with self._lock:
            self._instruments.clear()


#: The process-wide registry all repro call sites feed.
REGISTRY = Registry()


def counter(name: str, label: str | None = None) -> Counter:
    return REGISTRY.counter(name, label)


def gauge(name: str, label: str | None = None) -> Gauge:
    return REGISTRY.gauge(name, label)


def histogram(name: str, label: str | None = None,
              buckets: tuple = DEFAULT_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, label, buckets=buckets)
