"""Runlog emission, Chrome-trace export, and runlog summaries.

Three consumers of the span tracer + metrics registry:

1. **JSON-lines runlog** — `flush()` appends every finished span plus a
   cumulative metrics snapshot to a file.  Target resolution:
   ``simulate(..., runlog=path)`` wins, else the ``REPRO_RUNLOG`` env
   var.  Lines are self-describing (``{"kind": "span"|"metrics", ...}``)
   so the file survives schema growth and concatenation across runs.
2. **Chrome ``trace_event`` export** — host spans become "X" complete
   events on per-thread tracks under their own pid, deliberately the
   same schema `analysis/timeline.py` emits for simulated-Ara Gantt
   rows; `export_merged_trace` places both in one file so a Perfetto
   view shows the simulator's wall-clock above the machine it simulated.
   Units differ by design: host spans are real microseconds, simulated
   rows are cycles-as-microseconds — the per-process rows keep them
   visually separate.
3. **`summarize_runlog()`** — terminal-friendly report: top spans by
   total and self time, jax compile-vs-execute share, cache hit rate.

`check_metric_names` closes the docs loop: any metric name recorded in
a runlog that is missing from `metrics.KNOWN_METRICS` is a CI failure
(and KNOWN_METRICS itself is synced against docs/observability.md).
"""
from __future__ import annotations

import json
import os
import pathlib

from repro.obs import metrics as _metrics
from repro.obs import spans as _spans

__all__ = ["RUNLOG_ENV", "runlog_target", "flush", "read_runlog",
           "chrome_events_from_spans", "export_merged_trace",
           "summarize_runlog", "check_metric_names"]

RUNLOG_ENV = "REPRO_RUNLOG"

#: Span leaves whose names start with these prefixes count as "compile"
#: (first call on a fresh shape signature: trace + lower + XLA compile)
#: vs. "execute" (cached callable) in the runlog summary.
COMPILE_PREFIXES = ("exec.jax.compile", "exec.assoc.compile")
EXECUTE_PREFIXES = ("exec.jax.execute", "exec.assoc.execute")


def runlog_target(explicit=None) -> pathlib.Path | None:
    """Resolve the runlog destination: explicit arg, else $REPRO_RUNLOG."""
    if explicit:
        return pathlib.Path(explicit)
    env = os.environ.get(RUNLOG_ENV)
    return pathlib.Path(env) if env else None


def _span_record(sp: _spans.Span) -> dict:
    rec = {"kind": "span", "name": sp.name, "sid": sp.sid,
           "parent": sp.parent, "tid": sp.tid, "start": sp.start,
           "end": sp.end, "dur_us": sp.duration * 1e6}
    if sp.attrs:
        rec["attrs"] = sp.attrs
    return rec


def flush(target=None, tracer: _spans.Tracer | None = None,
          registry: _metrics.Registry | None = None) -> pathlib.Path | None:
    """Drain finished spans and append them + a metrics snapshot.

    No-op (returns None) when no target resolves.  The metrics record is
    cumulative — the *last* one in a file is the run's final state, and
    `summarize_runlog` reads it that way.
    """
    path = runlog_target(target)
    if path is None:
        return None
    tracer = tracer or _spans.TRACER
    registry = registry or _metrics.REGISTRY
    lines = [json.dumps(_span_record(sp), sort_keys=True)
             for sp in tracer.drain()]
    lines.append(json.dumps(
        {"kind": "metrics", "metrics": registry.snapshot()},
        sort_keys=True))
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("a") as fh:
        fh.write("\n".join(lines) + "\n")
    return path


def read_runlog(path) -> list[dict]:
    """Parse a JSON-lines runlog back into records (blank lines skipped)."""
    out = []
    for line in pathlib.Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            out.append(json.loads(line))
    return out


# ---------------------------------------------------------------------------
# Chrome trace_event export

#: pid for the host-span process row; simulated cells get 1, 2, ... so
#: every row in the merged file has a distinct process header.
HOST_PID = 0


def chrome_events_from_spans(span_records, pid: int = HOST_PID,
                             label: str = "simulate() host") -> list[dict]:
    """Map runlog span records (or Span objects) to Chrome "X" events.

    Timestamps are rebased so the earliest span starts at ts=0; spans
    keep perf_counter precision in microseconds.
    """
    recs = [_span_record(sp) if isinstance(sp, _spans.Span) else sp
            for sp in span_records]
    recs = [r for r in recs if r.get("kind", "span") == "span"
            and r.get("end") is not None]
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid,
        "args": {"name": label},
    }]
    if not recs:
        return events
    t0 = min(r["start"] for r in recs)
    for tid in sorted({r.get("tid", 0) for r in recs}):
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": f"host thread {tid}"}})
    for r in recs:
        args = {"sid": r["sid"], "parent": r["parent"]}
        args.update(r.get("attrs", {}))
        events.append({
            "name": r["name"],
            "cat": "host",
            "ph": "X",
            "pid": pid,
            "tid": r.get("tid", 0),
            "ts": (r["start"] - t0) * 1e6,
            "dur": r["dur_us"],
            "args": args,
        })
    return events


def export_merged_trace(path, span_records, cells=()) -> pathlib.Path:
    """One Perfetto-loadable file: host spans + simulated-Ara Gantt rows.

    ``cells`` is an iterable of ``(trace, result)`` pairs as accepted by
    `analysis.timeline.trace_events`; each gets its own pid row below
    the host process.
    """
    from repro.analysis.timeline import trace_events  # cycle-free, lazy

    events = chrome_events_from_spans(span_records, pid=HOST_PID)
    for i, (trace, result) in enumerate(cells):
        events.extend(trace_events(trace, result, pid=HOST_PID + 1 + i))
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(
        {"traceEvents": events, "displayTimeUnit": "ms"}, indent=1))
    return path


# ---------------------------------------------------------------------------
# Runlog summary


def _aggregate_spans(records):
    """Per-name totals: calls, total us, self us (total minus children)."""
    spans = [r for r in records if r.get("kind") == "span"]
    by_sid = {r["sid"]: r for r in spans}
    child_us: dict[int, float] = {}
    for r in spans:
        parent = r.get("parent")
        if parent in by_sid:
            child_us[parent] = child_us.get(parent, 0.0) + r["dur_us"]
    agg: dict[str, dict] = {}
    for r in spans:
        a = agg.setdefault(r["name"], {"calls": 0, "total_us": 0.0,
                                       "self_us": 0.0})
        a["calls"] += 1
        a["total_us"] += r["dur_us"]
        a["self_us"] += max(r["dur_us"] - child_us.get(r["sid"], 0.0), 0.0)
    return agg


def _metric_value(metric_records, name, label=None):
    for m in metric_records:
        if m["name"] == name and m.get("label") == label:
            return m["value"]
    return None


def _sum_metric(metric_records, name):
    vals = [m["value"] for m in metric_records if m["name"] == name]
    return sum(vals) if vals else None


def summarize_runlog(path, top: int = 12) -> str:
    """Human-readable report over a runlog file."""
    records = read_runlog(path)
    agg = _aggregate_spans(records)
    metric_blocks = [r for r in records if r.get("kind") == "metrics"]
    final_metrics = metric_blocks[-1]["metrics"] if metric_blocks else []

    lines = [f"runlog: {path}",
             f"spans: {sum(a['calls'] for a in agg.values())} across "
             f"{len(agg)} names"]

    if agg:
        lines.append("")
        lines.append(f"{'span':<28}{'calls':>7}{'total ms':>11}"
                     f"{'self ms':>10}")
        ordered = sorted(agg.items(), key=lambda kv: -kv[1]["total_us"])
        for name, a in ordered[:top]:
            lines.append(f"{name:<28}{a['calls']:>7}"
                         f"{a['total_us'] / 1e3:>11.2f}"
                         f"{a['self_us'] / 1e3:>10.2f}")

    compile_us = sum(a["total_us"] for n, a in agg.items()
                     if n.startswith(COMPILE_PREFIXES))
    execute_us = sum(a["total_us"] for n, a in agg.items()
                     if n.startswith(EXECUTE_PREFIXES))
    if compile_us or execute_us:
        total = compile_us + execute_us
        lines.append("")
        lines.append(
            f"jit compile/execute: {compile_us / 1e3:.2f} ms / "
            f"{execute_us / 1e3:.2f} ms "
            f"(compile share {100.0 * compile_us / total:.1f}%)")

    hits = _sum_metric(final_metrics, "sweep_cache.hits")
    misses = _sum_metric(final_metrics, "sweep_cache.misses")
    if hits is not None or misses is not None:
        hits = hits or 0.0
        misses = misses or 0.0
        lookups = hits + misses
        rate = (100.0 * hits / lookups) if lookups else 0.0
        evict = _sum_metric(final_metrics, "sweep_cache.evictions") or 0.0
        lines.append(
            f"sweep cache: {hits:.0f} hits / {misses:.0f} misses "
            f"({rate:.1f}% hit rate), {evict:.0f} evictions")

    calls = _metric_value(final_metrics, "simulate.calls")
    cells = _metric_value(final_metrics, "simulate.cells")
    if calls is not None:
        lines.append(f"simulate: {calls:.0f} calls, "
                     f"{cells or 0:.0f} cells")
    return "\n".join(lines)


def check_metric_names(path) -> list[str]:
    """Metric names recorded in a runlog but absent from KNOWN_METRICS."""
    unknown = set()
    for rec in read_runlog(path):
        if rec.get("kind") != "metrics":
            continue
        for m in rec["metrics"]:
            if m["name"] not in _metrics.KNOWN_METRICS:
                unknown.add(m["name"])
    return sorted(unknown)


def main(argv=None) -> int:
    """CLI: summarize a runlog; --check-metrics gates on undocumented names."""
    import argparse

    ap = argparse.ArgumentParser(
        description="Summarize a repro runlog (JSON lines).")
    ap.add_argument("runlog", help="path written via REPRO_RUNLOG/runlog=")
    ap.add_argument("--check-metrics", action="store_true",
                    help="exit 1 if any recorded metric name is not in "
                         "repro.obs.metrics.KNOWN_METRICS")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args(argv)

    print(summarize_runlog(args.runlog, top=args.top))
    if args.check_metrics:
        unknown = check_metric_names(args.runlog)
        if unknown:
            print(f"\nUNDOCUMENTED METRICS: {', '.join(unknown)}")
            return 1
        print("\nall recorded metric names documented")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
