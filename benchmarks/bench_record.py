"""Record/check the measured execution-strategy crossovers behind
`repro.core.api.resolve_plan`.

Benchmarks the three strategy axes of `api.simulate` on the smoke-sized
Table I ablation grid (6 kernels x 8 opt corners):

  * scalar loop vs one batched numpy call   (is batching worth it?)
  * numpy scan vs compiled jax scan         (backend crossover)
  * jax scan vs jax max-plus assoc engine   (method crossover)

Results land in ``benchmarks/BENCH_simulate.json`` keyed by a machine
fingerprint (arch + cpu count + jax device kind), so numbers measured on
different hosts never compare against each other.  The recorded steady
numbers are the evidence behind the ``auto`` policy constants
(`api.JAX_WIDTH_CROSSOVER`, `api.ASSOC_INSTR_CROSSOVER`,
`api.BUCKET_WASTE_CROSSOVER`) and the tables in docs/backends.md.
Each entry also carries a ``crossovers`` fold that
`api.measured_crossovers` reads at plan-resolution time: non-null values
override the code constants on that machine, nulls fall back (CPU-only
hosts record nulls — the code defaults were measured there).

``--planner`` additionally measures the execution planner
(`docs/backends.md` "execution planner"): pad-waste share of the mixed
11-kernel smoke stack before/after shape bucketing, bucketed vs
unbucketed jax-scan steady wall time, and the async P-axis pipeline's
dispatch occupancy.  The planner fold rides the same drift gate, plus an
absolute pad-waste regression gate (bucketing must keep waste down).

    python benchmarks/bench_record.py --check    # CI: drift gate
    python benchmarks/bench_record.py --record   # refresh this machine

``--check`` re-measures and fails (exit 1) only when this machine has a
recorded entry and a steady timing regressed beyond ``--tol`` (default
4x — wall-clock on shared CI runners is noisy; the gate catches
order-of-magnitude regressions like an accidentally-disabled jit, not
percent-level drift).  An unknown machine records a fresh entry and
exits 0, so a new runner fleet never fails CI on its first run.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import platform
import sys
import time

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib  # noqa: E402
from benchmarks.common import timed  # noqa: E402
from repro.core import api  # noqa: E402
from repro.core.calibration import load as load_params  # noqa: E402
from repro.core.isa import ABLATION_GRID, OptConfig  # noqa: E402
from repro.core.simulator import AraSimulator  # noqa: E402
from repro.core.traces import stack_traces  # noqa: E402
from repro.obs import export as obs_export  # noqa: E402
from repro.obs import spans as obs_spans  # noqa: E402

BENCH_PATH = _REPO / "benchmarks" / "BENCH_simulate.json"

#: Steady timings the drift gate compares (compile times are excluded:
#: they move with jax versions and dominate nothing at steady state).
GATED = ("scalar_loop_us", "numpy_scan_us", "jax_scan_us", "jax_assoc_us")

#: Per-kernel microbench timings (entry["kernels"]) are gated too, but
#: only for names recorded on both sides — the kernel set can grow
#: without breaking old entries.
KERNEL_GATE_EXCLUDE = ("naive_attention_model",)  # NaN: model-only row


def machine_key() -> str:
    import jax
    return (f"{platform.machine()}-{os.cpu_count()}cpu-"
            f"{jax.default_backend()}")


def _first_call_us(fn) -> float:
    """Wall time of one cold call (captures trace+compile for jax fns)."""
    import jax
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return (time.perf_counter() - t0) * 1e6


def _span_summary(spans) -> dict:
    """Aggregate drained tracer spans into the committed BENCH summary:
    per exec-leaf totals plus the jit compile-vs-execute split."""
    recs = [obs_export._span_record(sp) for sp in spans]
    agg = obs_export._aggregate_spans(recs)
    exec_names = {n: {"calls": a["calls"],
                      "total_us": round(a["total_us"], 1)}
                  for n, a in sorted(agg.items())
                  if n.startswith("exec.")}
    compile_us = sum(a["total_us"] for n, a in agg.items()
                     if n.startswith(obs_export.COMPILE_PREFIXES))
    execute_us = sum(a["total_us"] for n, a in agg.items()
                     if n.startswith(obs_export.EXECUTE_PREFIXES))
    total = compile_us + execute_us
    return {
        "exec": exec_names,
        "jit_compile_us": round(compile_us, 1),
        "jit_execute_us": round(execute_us, 1),
        "jit_compile_share": round(compile_us / total, 3) if total else 0.0,
    }


def measure() -> dict:
    """Measure every strategy on the smoke Table I grid; returns the
    entry dict stored under this machine's key."""
    from benchmarks.table1_ablation import KERNELS
    params = load_params()
    traces = {k: tr for k, tr in
              gridlib.paper_traces("smoke").items() if k in KERNELS}
    opts = [OptConfig.baseline(), *ABLATION_GRID]
    stacked = stack_traces(list(traces.values()))
    n_instrs = int(stacked.kind.shape[1])

    sim = AraSimulator(params=params, attribution=False)

    def scalar_loop():
        return [sim.run(tr, o).cycles
                for tr in traces.values() for o in opts]

    def run(backend, method):
        return lambda: api.simulate(stacked, opts, params,
                                    backend=backend, method=method)

    # Trace the measurement itself so the committed entry carries the
    # compile-vs-execute split behind its steady numbers.
    was_enabled = obs_spans.enabled()
    obs_spans.enable()
    obs_spans.TRACER.drain()               # start from a clean collector
    try:
        timings = {
            "scalar_loop_us": timed(scalar_loop),
            "numpy_scan_us": timed(run("numpy", "scan")),
            "jax_scan_compile_us": _first_call_us(run("jax", "scan")),
            "jax_scan_us": timed(run("jax", "scan")),
            "jax_assoc_compile_us": _first_call_us(run("jax", "assoc")),
            "jax_assoc_us": timed(run("jax", "assoc")),
        }
        spans = obs_spans.TRACER.drain()
    finally:
        if not was_enabled:
            obs_spans.disable()
    t = timings
    return {
        "recorded_at": time.strftime("%Y-%m-%d"),
        "grid": {"profile": "smoke", "kernels": len(traces),
                 "corners": len(opts), "n_instrs": n_instrs},
        "timings": {k: round(v, 1) for k, v in t.items()},
        "ratios": {
            "batched_vs_scalar": round(
                t["scalar_loop_us"] / t["numpy_scan_us"], 3),
            "numpy_vs_jax_scan": round(
                t["numpy_scan_us"] / t["jax_scan_us"], 3),
            "scan_vs_assoc": round(
                t["jax_scan_us"] / t["jax_assoc_us"], 3),
        },
        "spans": _span_summary(spans),
    }


#: Planner steady timings under the same drift gate as GATED.
PLANNER_GATED = ("jax_scan_unbucketed_us", "jax_scan_bucketed_us")

#: Allowed absolute increase of the bucketed pad-waste share vs the
#: recorded entry (shape-driven, so near-deterministic; 0.02 absorbs
#: trace-generator tweaks without letting bucketing quietly rot).
PAD_WASTE_TOL = 0.02

#: entry["crossovers"] template: the measured overrides for the auto
#: policy thresholds.  Nulls mean "use the code constant" — the right
#: answer on CPU-only hosts, where those constants were measured.
#: Accelerator hosts with different crossovers fill these by hand from
#: a --record run's ratios.
NULL_CROSSOVERS = {"jax_width": None, "assoc_instrs": None,
                   "bucket_waste": None}


def measure_planner() -> dict:
    """Measure the execution planner on the full mixed-length 11-kernel
    smoke grid: pad-waste shares before/after bucketing, bucketed vs
    unbucketed jax-scan steady wall, and pipeline dispatch occupancy."""
    from repro.core import bucketing
    from repro.obs import metrics as obs_metrics

    params = load_params()
    traces = gridlib.paper_traces("smoke")        # all 11: mixed lengths
    opts = [OptConfig.baseline(), *ABLATION_GRID]
    stacked = stack_traces(list(traces.values()))
    buckets = bucketing.plan_buckets(stacked)

    def run(bucket):
        return lambda: api.simulate(stacked, opts, params,
                                    backend="jax", method="scan",
                                    bucket=bucket, shard="none")

    timings = {
        "jax_scan_unbucketed_compile_us": _first_call_us(run("none")),
        "jax_scan_unbucketed_us": timed(run("none")),
        "jax_scan_bucketed_compile_us": _first_call_us(run("pow2")),
        "jax_scan_bucketed_us": timed(run("pow2")),
    }
    # Occupancy of the async P-axis pipeline: a chunked wide-params
    # sweep (8 candidates, p_chunk=2 -> 4 dispatches) sets the gauge.
    api.simulate(stacked, opts, [params] * 8, backend="jax",
                 method="scan", bucket="none", shard="none", p_chunk=2)
    occupancy = obs_metrics.gauge("plan.pipeline_occupancy").value
    return {
        "grid": {"profile": "smoke", "kernels": len(traces),
                 "corners": len(opts),
                 "n_instrs": int(stacked.kind.shape[1])},
        "buckets": len(buckets),
        "bucket_caps": [b.cap for b in buckets],
        "pad_waste_unbucketed": round(
            bucketing.pad_waste_share(stacked), 4),
        "pad_waste_bucketed": round(
            bucketing.pad_waste_share(stacked, buckets), 4),
        "timings": {k: round(v, 1) for k, v in timings.items()},
        "bucketed_speedup": round(
            timings["jax_scan_unbucketed_us"]
            / timings["jax_scan_bucketed_us"], 3),
        "pipeline_occupancy": round(occupancy, 3),
    }


def measure_kernels() -> dict:
    """Smoke-profile per-kernel microbench timings (ROADMAP item 5:
    the Pallas-kernel trajectory folded into the same machine-keyed
    record).  Returns `{kernel_name: cpu_interpret_us}`, NaN rows
    (model-only entries) skipped."""
    from benchmarks import kernel_bench
    rows = kernel_bench.run(profile="smoke", include_grid=False)
    return {r["kernel"]: round(r["cpu_interpret_us"], 1) for r in rows
            if r["cpu_interpret_us"] == r["cpu_interpret_us"]}  # drop NaN


def load_records() -> dict:
    if BENCH_PATH.exists():
        return json.loads(BENCH_PATH.read_text())
    return {}


def save_records(records: dict) -> None:
    BENCH_PATH.write_text(json.dumps(records, indent=2, sort_keys=True)
                          + "\n")


def check(entry: dict, recorded: dict, tol: float) -> list[str]:
    """Steady-timing regressions of `entry` vs `recorded` beyond `tol`x."""
    problems = []
    for name in GATED:
        old = recorded.get("timings", {}).get(name)
        new = entry["timings"][name]
        if old and new > tol * old:
            problems.append(f"{name}: {new:.0f}us vs recorded "
                            f"{old:.0f}us (> {tol:g}x)")
    # Per-kernel timings gate only where both sides measured the kernel.
    for name, new in entry.get("kernels", {}).items():
        if name in KERNEL_GATE_EXCLUDE:
            continue
        old = recorded.get("kernels", {}).get(name)
        if old and new > tol * old:
            problems.append(f"kernels.{name}: {new:.0f}us vs recorded "
                            f"{old:.0f}us (> {tol:g}x)")
    # Planner fold: steady timings under the same tol, pad waste under
    # an absolute regression gate (it is shape-driven, not wall-clock).
    newp, oldp = entry.get("planner", {}), recorded.get("planner", {})
    for name in PLANNER_GATED:
        old = oldp.get("timings", {}).get(name)
        new = newp.get("timings", {}).get(name)
        if old and new and new > tol * old:
            problems.append(f"planner.{name}: {new:.0f}us vs recorded "
                            f"{old:.0f}us (> {tol:g}x)")
    old = oldp.get("pad_waste_bucketed")
    new = newp.get("pad_waste_bucketed")
    if old is not None and new is not None and new > old + PAD_WASTE_TOL:
        problems.append(
            f"planner.pad_waste_bucketed: {new:.4f} vs recorded "
            f"{old:.4f} (> +{PAD_WASTE_TOL:g} abs)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--record", action="store_true",
                    help="measure and (over)write this machine's entry")
    ap.add_argument("--check", action="store_true",
                    help="measure and fail on drift vs this machine's "
                         "recorded entry (records fresh if absent)")
    ap.add_argument("--tol", type=float, default=4.0,
                    help="allowed steady-timing slowdown factor")
    ap.add_argument("--kernels", action="store_true",
                    help="also measure the per-kernel microbench "
                         "(kernel_bench smoke profile) into the entry")
    ap.add_argument("--planner", action="store_true",
                    help="also measure the execution planner (pad-waste "
                         "shares, bucketed vs unbucketed wall, pipeline "
                         "occupancy) into the entry")
    args = ap.parse_args(argv)
    if not (args.record or args.check):
        ap.error("pass --record and/or --check")

    key = machine_key()
    records = load_records()
    entry = measure()
    if args.kernels:
        entry["kernels"] = measure_kernels()
    elif key in records and "kernels" in records[key]:
        # A kernels-less run must not silently drop the recorded
        # trajectory (or its drift gate) — carry it forward unmeasured.
        entry["kernels"] = records[key]["kernels"]
    if args.planner:
        entry["planner"] = measure_planner()
    elif key in records and "planner" in records[key]:
        entry["planner"] = records[key]["planner"]
    # Crossover overrides are hand-curated (possibly on accelerator
    # hosts); re-recording must never clobber them with nulls.
    entry["crossovers"] = (records.get(key, {}).get("crossovers")
                           or dict(NULL_CROSSOVERS))
    print(f"# {key}: "
          + ", ".join(f"{k}={v}" for k, v in entry["timings"].items()))
    print(f"# ratios: {entry['ratios']}")
    print(f"# spans: jit compile {entry['spans']['jit_compile_us']}us / "
          f"execute {entry['spans']['jit_execute_us']}us "
          f"(share {entry['spans']['jit_compile_share']})")
    if args.kernels:
        print("# kernels: "
              + ", ".join(f"{k}={v}" for k, v in entry["kernels"].items()))
    if args.planner:
        p = entry["planner"]
        print(f"# planner: pad_waste {p['pad_waste_unbucketed']} -> "
              f"{p['pad_waste_bucketed']} ({p['buckets']} buckets), "
              f"bucketed_speedup {p['bucketed_speedup']}x, "
              f"pipeline_occupancy {p['pipeline_occupancy']}")

    rc = 0
    if args.check and key in records:
        problems = check(entry, records[key], args.tol)
        for p in problems:
            print(f"[bench-drift] {p}", file=sys.stderr)
        rc = 1 if problems else 0
        if rc == 0:
            print(f"# check ok vs {key} (tol {args.tol:g}x)")
    elif args.check:
        print(f"# no record for {key}: recording fresh entry")
        args.record = True

    if args.record and rc == 0:
        records[key] = entry
        save_records(records)
        print(f"# recorded -> {BENCH_PATH.relative_to(_REPO)}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
