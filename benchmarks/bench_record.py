"""Record/check the measured execution-strategy crossovers behind
`repro.core.api.resolve_plan`.

Benchmarks the three strategy axes of `api.simulate` on the smoke-sized
Table I ablation grid (6 kernels x 8 opt corners):

  * scalar loop vs one batched numpy call   (is batching worth it?)
  * numpy scan vs compiled jax scan         (backend crossover)
  * jax scan vs jax max-plus assoc engine   (method crossover)

Results land in ``benchmarks/BENCH_simulate.json`` keyed by a machine
fingerprint (arch + cpu count + jax device kind), so numbers measured on
different hosts never compare against each other.  The recorded steady
numbers are the evidence behind the ``auto`` policy constants
(`api.JAX_WIDTH_CROSSOVER`, `api.ASSOC_INSTR_CROSSOVER`) and the tables
in docs/backends.md.

    python benchmarks/bench_record.py --check    # CI: drift gate
    python benchmarks/bench_record.py --record   # refresh this machine

``--check`` re-measures and fails (exit 1) only when this machine has a
recorded entry and a steady timing regressed beyond ``--tol`` (default
4x — wall-clock on shared CI runners is noisy; the gate catches
order-of-magnitude regressions like an accidentally-disabled jit, not
percent-level drift).  An unknown machine records a fresh entry and
exits 0, so a new runner fleet never fails CI on its first run.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import platform
import sys
import time

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib  # noqa: E402
from benchmarks.common import timed  # noqa: E402
from repro.core import api  # noqa: E402
from repro.core.calibration import load as load_params  # noqa: E402
from repro.core.isa import ABLATION_GRID, OptConfig  # noqa: E402
from repro.core.simulator import AraSimulator  # noqa: E402
from repro.core.traces import stack_traces  # noqa: E402
from repro.obs import export as obs_export  # noqa: E402
from repro.obs import spans as obs_spans  # noqa: E402

BENCH_PATH = _REPO / "benchmarks" / "BENCH_simulate.json"

#: Steady timings the drift gate compares (compile times are excluded:
#: they move with jax versions and dominate nothing at steady state).
GATED = ("scalar_loop_us", "numpy_scan_us", "jax_scan_us", "jax_assoc_us")

#: Per-kernel microbench timings (entry["kernels"]) are gated too, but
#: only for names recorded on both sides — the kernel set can grow
#: without breaking old entries.
KERNEL_GATE_EXCLUDE = ("naive_attention_model",)  # NaN: model-only row


def machine_key() -> str:
    import jax
    return (f"{platform.machine()}-{os.cpu_count()}cpu-"
            f"{jax.default_backend()}")


def _first_call_us(fn) -> float:
    """Wall time of one cold call (captures trace+compile for jax fns)."""
    import jax
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return (time.perf_counter() - t0) * 1e6


def _span_summary(spans) -> dict:
    """Aggregate drained tracer spans into the committed BENCH summary:
    per exec-leaf totals plus the jit compile-vs-execute split."""
    recs = [obs_export._span_record(sp) for sp in spans]
    agg = obs_export._aggregate_spans(recs)
    exec_names = {n: {"calls": a["calls"],
                      "total_us": round(a["total_us"], 1)}
                  for n, a in sorted(agg.items())
                  if n.startswith("exec.")}
    compile_us = sum(a["total_us"] for n, a in agg.items()
                     if n.startswith(obs_export.COMPILE_PREFIXES))
    execute_us = sum(a["total_us"] for n, a in agg.items()
                     if n.startswith(obs_export.EXECUTE_PREFIXES))
    total = compile_us + execute_us
    return {
        "exec": exec_names,
        "jit_compile_us": round(compile_us, 1),
        "jit_execute_us": round(execute_us, 1),
        "jit_compile_share": round(compile_us / total, 3) if total else 0.0,
    }


def measure() -> dict:
    """Measure every strategy on the smoke Table I grid; returns the
    entry dict stored under this machine's key."""
    from benchmarks.table1_ablation import KERNELS
    params = load_params()
    traces = {k: tr for k, tr in
              gridlib.paper_traces("smoke").items() if k in KERNELS}
    opts = [OptConfig.baseline(), *ABLATION_GRID]
    stacked = stack_traces(list(traces.values()))
    n_instrs = int(stacked.kind.shape[1])

    sim = AraSimulator(params=params, attribution=False)

    def scalar_loop():
        return [sim.run(tr, o).cycles
                for tr in traces.values() for o in opts]

    def run(backend, method):
        return lambda: api.simulate(stacked, opts, params,
                                    backend=backend, method=method)

    # Trace the measurement itself so the committed entry carries the
    # compile-vs-execute split behind its steady numbers.
    was_enabled = obs_spans.enabled()
    obs_spans.enable()
    obs_spans.TRACER.drain()               # start from a clean collector
    try:
        timings = {
            "scalar_loop_us": timed(scalar_loop),
            "numpy_scan_us": timed(run("numpy", "scan")),
            "jax_scan_compile_us": _first_call_us(run("jax", "scan")),
            "jax_scan_us": timed(run("jax", "scan")),
            "jax_assoc_compile_us": _first_call_us(run("jax", "assoc")),
            "jax_assoc_us": timed(run("jax", "assoc")),
        }
        spans = obs_spans.TRACER.drain()
    finally:
        if not was_enabled:
            obs_spans.disable()
    t = timings
    return {
        "recorded_at": time.strftime("%Y-%m-%d"),
        "grid": {"profile": "smoke", "kernels": len(traces),
                 "corners": len(opts), "n_instrs": n_instrs},
        "timings": {k: round(v, 1) for k, v in t.items()},
        "ratios": {
            "batched_vs_scalar": round(
                t["scalar_loop_us"] / t["numpy_scan_us"], 3),
            "numpy_vs_jax_scan": round(
                t["numpy_scan_us"] / t["jax_scan_us"], 3),
            "scan_vs_assoc": round(
                t["jax_scan_us"] / t["jax_assoc_us"], 3),
        },
        "spans": _span_summary(spans),
    }


def measure_kernels() -> dict:
    """Smoke-profile per-kernel microbench timings (ROADMAP item 5:
    the Pallas-kernel trajectory folded into the same machine-keyed
    record).  Returns `{kernel_name: cpu_interpret_us}`, NaN rows
    (model-only entries) skipped."""
    from benchmarks import kernel_bench
    rows = kernel_bench.run(profile="smoke", include_grid=False)
    return {r["kernel"]: round(r["cpu_interpret_us"], 1) for r in rows
            if r["cpu_interpret_us"] == r["cpu_interpret_us"]}  # drop NaN


def load_records() -> dict:
    if BENCH_PATH.exists():
        return json.loads(BENCH_PATH.read_text())
    return {}


def save_records(records: dict) -> None:
    BENCH_PATH.write_text(json.dumps(records, indent=2, sort_keys=True)
                          + "\n")


def check(entry: dict, recorded: dict, tol: float) -> list[str]:
    """Steady-timing regressions of `entry` vs `recorded` beyond `tol`x."""
    problems = []
    for name in GATED:
        old = recorded.get("timings", {}).get(name)
        new = entry["timings"][name]
        if old and new > tol * old:
            problems.append(f"{name}: {new:.0f}us vs recorded "
                            f"{old:.0f}us (> {tol:g}x)")
    # Per-kernel timings gate only where both sides measured the kernel.
    for name, new in entry.get("kernels", {}).items():
        if name in KERNEL_GATE_EXCLUDE:
            continue
        old = recorded.get("kernels", {}).get(name)
        if old and new > tol * old:
            problems.append(f"kernels.{name}: {new:.0f}us vs recorded "
                            f"{old:.0f}us (> {tol:g}x)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--record", action="store_true",
                    help="measure and (over)write this machine's entry")
    ap.add_argument("--check", action="store_true",
                    help="measure and fail on drift vs this machine's "
                         "recorded entry (records fresh if absent)")
    ap.add_argument("--tol", type=float, default=4.0,
                    help="allowed steady-timing slowdown factor")
    ap.add_argument("--kernels", action="store_true",
                    help="also measure the per-kernel microbench "
                         "(kernel_bench smoke profile) into the entry")
    args = ap.parse_args(argv)
    if not (args.record or args.check):
        ap.error("pass --record and/or --check")

    key = machine_key()
    records = load_records()
    entry = measure()
    if args.kernels:
        entry["kernels"] = measure_kernels()
    elif key in records and "kernels" in records[key]:
        # A kernels-less run must not silently drop the recorded
        # trajectory (or its drift gate) — carry it forward unmeasured.
        entry["kernels"] = records[key]["kernels"]
    print(f"# {key}: "
          + ", ".join(f"{k}={v}" for k, v in entry["timings"].items()))
    print(f"# ratios: {entry['ratios']}")
    print(f"# spans: jit compile {entry['spans']['jit_compile_us']}us / "
          f"execute {entry['spans']['jit_execute_us']}us "
          f"(share {entry['spans']['jit_compile_share']})")
    if args.kernels:
        print("# kernels: "
              + ", ".join(f"{k}={v}" for k, v in entry["kernels"].items()))

    rc = 0
    if args.check and key in records:
        problems = check(entry, records[key], args.tol)
        for p in problems:
            print(f"[bench-drift] {p}", file=sys.stderr)
        rc = 1 if problems else 0
        if rc == 0:
            print(f"# check ok vs {key} (tol {args.tol:g}x)")
    elif args.check:
        print(f"# no record for {key}: recording fresh entry")
        args.record = True

    if args.record and rc == 0:
        records[key] = entry
        save_records(records)
        print(f"# recorded -> {BENCH_PATH.relative_to(_REPO)}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
