"""Fig. 6 (analysis artifact): per-kernel stall-breakdown attribution.

For all 11 paper kernels x 8 ablation corners, decompose simulated cycles
into ideal time + the nine stall categories over the paper's three
critical paths (`repro.core.stalls`), via one batched attribution pass
per cache-miss signature (`gridlib` / `sweep_cache`).  Emits stacked
stall-breakdown chart data (CSV) plus one Chrome ``trace_event`` Gantt
JSON for a representative cell (scal, baseline) — the waveform-style view
the paper derives by hand from RTL traces.
"""
from __future__ import annotations

import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib
from benchmarks.common import OUT_DIR, emit
from repro.analysis.report import breakdown_rows, format_report
from repro.analysis.timeline import export_chrome_trace
from repro.core.isa import ABLATION_GRID
from repro.core.simulator import AraSimulator

CONFIGS = (gridlib.BASE, *ABLATION_GRID)

#: Representative cell for the exported Gantt timeline.
TRACE_KERNEL = "scal"


def run() -> list[dict]:
    traces = gridlib.paper_traces()
    cells = gridlib.grid().cells(traces, CONFIGS, attribution=True)
    rows: list[dict] = []
    for cfg in CONFIGS:
        per_kernel = {name: cells[(name, cfg.label)] for name in traces}
        rows.extend(breakdown_rows(per_kernel, config=cfg.label))
    return rows


def export_example_trace(kernel: str = TRACE_KERNEL) -> pathlib.Path:
    """Simulate one baseline cell scalar-side (per-instruction timings)
    and export its Gantt as Chrome trace JSON."""
    tr = gridlib.paper_traces()[kernel]
    res = AraSimulator(params=gridlib.grid().params).run(tr, gridlib.BASE)
    name = gridlib.table_name(f"trace_{kernel}_base")
    return export_chrome_trace(OUT_DIR / f"{name}.json", tr, res)


def main() -> None:
    rows = run()
    emit(rows, gridlib.table_name("fig6_attribution"))
    base_rows = [r for r in rows if r["config"] == gridlib.BASE.label]
    print(format_report(base_rows, title="baseline critical-path shares"))
    path = export_example_trace()
    print(f"# chrome trace -> {path}")


if __name__ == "__main__":
    main()
