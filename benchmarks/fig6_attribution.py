"""Fig. 6 (analysis artifact): per-kernel stall-breakdown attribution.

For all 11 paper kernels x 8 ablation corners, decompose simulated cycles
into ideal time + the nine stall categories over the paper's three
critical paths (`repro.core.stalls`), via one batched attribution pass
per cache-miss signature (`gridlib` / `sweep_cache`).  Each CSV row also
carries the prologue/steady/tail phase split and the deviation triple
``(dp, II_eff, dt)`` from `analysis.attribution.phase_decompose_grid`.
Emits stacked stall-breakdown chart data (CSV, and a rendered PNG with
``--plot``) plus one Chrome ``trace_event`` Gantt JSON for a
representative cell (scal, baseline) — the waveform-style view the paper
derives by hand from RTL traces.  docs/attribution.md walks through how
to read the output.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib
from benchmarks.common import OUT_DIR, emit
from repro.analysis.report import (breakdown_rows, format_report,
                                   have_matplotlib, render_stacked_bars)
from repro.analysis.timeline import export_chrome_trace
from repro.core.isa import ABLATION_GRID
from repro.core.simulator import AraSimulator

CONFIGS = (gridlib.BASE, *ABLATION_GRID)

#: Representative cell for the exported Gantt timeline.
TRACE_KERNEL = "scal"


def run() -> list[dict]:
    traces = gridlib.paper_traces()
    cells = gridlib.grid().cells(traces, CONFIGS, attribution=True)
    rows: list[dict] = []
    for cfg in CONFIGS:
        per_kernel = {name: cells[(name, cfg.label)] for name in traces}
        rows.extend(breakdown_rows(per_kernel, config=cfg.label))
    return rows


def export_example_trace(kernel: str = TRACE_KERNEL) -> pathlib.Path:
    """Simulate one baseline cell scalar-side (per-instruction timings)
    and export its Gantt as Chrome trace JSON."""
    tr = gridlib.paper_traces()[kernel]
    res = AraSimulator(params=gridlib.grid().params).run(tr, gridlib.BASE)
    name = gridlib.table_name(f"trace_{kernel}_base")
    return export_chrome_trace(OUT_DIR / f"{name}.json", tr, res)


def plot(rows: list[dict]) -> pathlib.Path:
    """Render the breakdown rows as stacked bars (one panel per config);
    this is the figure docs/attribution.md embeds."""
    name = gridlib.table_name("fig6_attribution")
    return render_stacked_bars(
        rows, OUT_DIR / f"{name}.png",
        title="cycles decomposed: ideal + 9 stall categories "
              "(3 critical paths)")


def main(argv: list[str] | None = None) -> None:
    from benchmarks.common import apply_execution_args, execution_args
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--plot", action="store_true",
                    help="also render the stacked-bar PNG (needs "
                         "matplotlib, the [plot] extra)")
    execution_args(ap)
    args = ap.parse_args(argv)
    apply_execution_args(args)
    rows = run()
    emit(rows, gridlib.table_name("fig6_attribution"))
    base_rows = [r for r in rows if r["config"] == gridlib.BASE.label]
    print(format_report(base_rows, title="baseline critical-path shares"))
    path = export_example_trace()
    print(f"# chrome trace -> {path}")
    if args.plot:
        if have_matplotlib():
            print(f"# stacked bars -> {plot(rows)}")
        else:
            print("# --plot skipped: matplotlib not installed "
                  "(pip install -e .[plot])")


if __name__ == "__main__":
    main()
