"""Fig. 5: problem-size sensitivity for scal and gemm."""
from __future__ import annotations

from benchmarks.common import emit, simulator
from repro.core.isa import OptConfig
from repro.core.traces import gemm, scal


def run() -> list[dict]:
    sim = simulator()
    rows = []
    for n in (512, 1024, 2048):
        tr = scal(n)
        base = sim.run(tr, OptConfig.baseline())
        opt = sim.run(tr, OptConfig.full())
        rows.append({"kernel": "scal", "size": n,
                     "base_gflops": base.gflops, "opt_gflops": opt.gflops,
                     "speedup": base.cycles / opt.cycles,
                     "lane_util_base": base.lane_utilization,
                     "lane_util_opt": opt.lane_utilization})
    for m in (32, 64, 128, 256):
        tr = gemm(m, m, m)
        base = sim.run(tr, OptConfig.baseline())
        opt = sim.run(tr, OptConfig.full())
        rows.append({"kernel": "gemm", "size": m,
                     "base_gflops": base.gflops, "opt_gflops": opt.gflops,
                     "speedup": base.cycles / opt.cycles,
                     "lane_util_base": base.lane_utilization,
                     "lane_util_opt": opt.lane_utilization})
    return rows


def check_paper_trends(rows: list[dict]) -> dict:
    """Fig. 5 claims: scal keeps stable gains across N; gemm's absolute
    perf grows with size while relative speedup converges."""
    scal_sp = [r["speedup"] for r in rows if r["kernel"] == "scal"]
    gemm_rows = [r for r in rows if r["kernel"] == "gemm"]
    gemm_perf = [r["opt_gflops"] for r in gemm_rows]
    gemm_sp = [r["speedup"] for r in gemm_rows]
    return {
        "scal_gain_stable": max(scal_sp) / min(scal_sp) < 1.6,
        "gemm_perf_monotone": all(a <= b * 1.05 for a, b in
                                  zip(gemm_perf, gemm_perf[1:])),
        "gemm_speedup_converges": gemm_sp[-1] <= max(gemm_sp[:2]) + 0.05,
    }


def main() -> None:
    rows = run()
    emit(rows, "fig5_sensitivity")
    print("# trends:", check_paper_trends(rows))


if __name__ == "__main__":
    main()
