"""Fig. 5: problem-size sensitivity for scal and gemm."""
from __future__ import annotations

import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib
from benchmarks.common import apply_execution_args, emit, execution_args
from repro.core.traces import gemm, scal

#: Sweep points per profile (smoke trims the gemm sizes for CI runners).
SWEEP_SIZES = {
    "default": {"scal": (512, 1024, 2048), "gemm": (32, 64, 128, 256)},
    "smoke": {"scal": (256, 512, 1024), "gemm": (16, 32, 64)},
}


def run() -> list[dict]:
    sizes = SWEEP_SIZES.get(gridlib.active_profile(),
                            SWEEP_SIZES["default"])
    traces = {f"scal_{n}": scal(n) for n in sizes["scal"]}
    traces.update({f"gemm_{m}": gemm(m, m, m) for m in sizes["gemm"]})
    cells = gridlib.grid().base_and_full(traces)
    rows = []
    for key, tr in traces.items():
        kernel, size = key.rsplit("_", 1)
        base = cells[(key, gridlib.BASE.label)]
        opt = cells[(key, gridlib.FULL.label)]
        rows.append({"kernel": kernel, "size": int(size),
                     "base_gflops": base.gflops, "opt_gflops": opt.gflops,
                     "speedup": base.cycles / opt.cycles,
                     "lane_util_base": base.lane_utilization,
                     "lane_util_opt": opt.lane_utilization})
    return rows


def check_paper_trends(rows: list[dict]) -> dict:
    """Fig. 5 claims: scal keeps stable gains across N; gemm's absolute
    perf grows with size while relative speedup converges."""
    scal_sp = [r["speedup"] for r in rows if r["kernel"] == "scal"]
    gemm_rows = [r for r in rows if r["kernel"] == "gemm"]
    gemm_perf = [r["opt_gflops"] for r in gemm_rows]
    gemm_sp = [r["speedup"] for r in gemm_rows]
    return {
        "scal_gain_stable": max(scal_sp) / min(scal_sp) < 1.6,
        "gemm_perf_monotone": all(a <= b * 1.05 for a, b in
                                  zip(gemm_perf, gemm_perf[1:])),
        "gemm_speedup_converges": gemm_sp[-1] <= max(gemm_sp[:2]) + 0.05,
    }


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    execution_args(ap)
    apply_execution_args(ap.parse_args(argv or []))
    rows = run()
    emit(rows, gridlib.table_name("fig5_sensitivity"))
    print("# trends:", check_paper_trends(rows))


if __name__ == "__main__":
    main(sys.argv[1:])
