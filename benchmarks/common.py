"""Shared benchmark helpers: CSV emission + calibrated simulator."""
from __future__ import annotations

import pathlib
import sys
import time

REPO = pathlib.Path(__file__).resolve().parents[1]
if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))

OUT_DIR = REPO / "experiments" / "benchmarks"


def emit(rows: list[dict], name: str) -> None:
    """Print CSV to stdout and persist under experiments/benchmarks/."""
    if not rows:
        return
    cols = list(rows[0].keys())
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(_fmt(r[c]) for c in cols))
    text = "\n".join(lines)
    print(f"# --- {name} ---")
    print(text)
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{name}.csv").write_text(text + "\n")


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def execution_args(ap) -> None:
    """Attach the shared ``--backend``/``--method`` execution-strategy
    flags (every fig script accepts them; see docs/figures.md)."""
    ap.add_argument("--backend", choices=("numpy", "jax", "auto"),
                    default=None,
                    help="array engine for the batched grid passes "
                         "(default: the shared grid's current setting)")
    ap.add_argument("--method", choices=("scan", "assoc", "auto"),
                    default=None,
                    help="jax instruction-axis algorithm: sequential "
                         "lax.scan or the log-depth max-plus assoc "
                         "engine (default: the shared grid's setting)")
    ap.add_argument("--bucket", choices=("none", "pow2", "auto"),
                    default=None,
                    help="execution-planner shape bucketing for the "
                         "batched grid passes; changes wall-clock only, "
                         "never results (default: the shared grid's "
                         "setting)")


def apply_execution_args(args) -> None:
    """Route parsed ``--backend``/``--method``/``--bucket`` into the
    shared grid."""
    bucket = getattr(args, "bucket", None)
    if args.backend is not None or args.method is not None \
            or bucket is not None:
        from benchmarks import gridlib
        gridlib.set_execution(backend=args.backend, method=args.method,
                              bucket=bucket)


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (CPU-interpret numbers;
    structural, not TPU perf — see DESIGN.md §8)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
