"""§Dry-run / §Roofline aggregation: read experiments/dryrun/*.json and
emit the per-cell table EXPERIMENTS.md embeds."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import REPO, emit

DRYRUN = REPO / "experiments" / "dryrun"


COLS = ["arch", "shape", "mesh", "status", "reason", "compile_s",
        "live_gb_per_device", "fits_16gb", "compute_ms", "memory_ms",
        "collective_ms", "bound", "useful_flops_ratio",
        "roofline_fraction", "cost_kind"]


def load_cells() -> list[dict]:
    rows = []
    for p in sorted(DRYRUN.glob("*.json")):
        if p.name.endswith(".analysis.json") or p.name == "sweep.log":
            continue
        rec = json.loads(p.read_text())
        analysis_path = p.with_suffix("").with_suffix("")  # strip .json
        apath = DRYRUN / (p.stem + ".analysis.json")
        analysis = json.loads(apath.read_text()) if apath.exists() else None
        row = {
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "status": rec["status"],
        }
        if rec["status"] == "skipped":
            row.update(reason=rec["reason"])
            rows.append({c: row.get(c, "") for c in COLS})
            continue
        mem = rec.get("memory", {})
        row.update({
            "compile_s": rec.get("compile_s"),
            "live_gb_per_device": round(
                mem.get("live_bytes_per_device", 0) / 1e9, 2),
            "fits_16gb": mem.get("fits_16gb_hbm"),
        })
        from repro.core.roofline import RooflineTerms
        mf = rec.get("model_flops_per_device", 0.0)
        if analysis and analysis.get("status") == "ok":
            t = analysis["total_remat"]
            terms = RooflineTerms(flops=t["flops"],
                                  hbm_bytes=t["hbm_bytes"],
                                  collective_bytes=t["coll_total"])
            kind = "scan-corrected"
        else:
            r = rec["roofline"]
            terms = RooflineTerms(flops=r["flops_per_device"],
                                  hbm_bytes=r["hbm_bytes_per_device"],
                                  collective_bytes=r[
                                      "collective_bytes_per_device"])
            kind = "raw(scan-1x)"
        row.update({
            "compute_ms": round(terms.compute_s * 1e3, 4),
            "memory_ms": round(terms.memory_s * 1e3, 4),
            "collective_ms": round(terms.collective_s * 1e3, 4),
            "bound": terms.bound,
            "useful_flops_ratio": round(mf / terms.flops, 3)
            if terms.flops else None,
            "roofline_fraction": round(terms.roofline_fraction(mf), 5),
            "cost_kind": kind,
        })
        rows.append({c: row.get(c, "") for c in COLS})
    return rows


def main() -> None:
    rows = load_cells()
    if not rows:
        print("# no dry-run records yet: run python -m repro.launch.sweep")
        return
    emit(rows, "dryrun_table")


if __name__ == "__main__":
    main()
