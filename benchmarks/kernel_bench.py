"""TPU kernel microbench: wall-time per call (CPU interpret — structural)
plus the analytic TPU roofline estimate per kernel variant, fused vs
unfused (the paper's O-optimization quantified on v5e constants).

Also benchmarks the batched ablation-sweep engine (core/batch_sim.py)
against the scalar `AraSimulator` loop on the full Table I grid."""
from __future__ import annotations

import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp

from benchmarks import gridlib
from benchmarks.common import emit, timed
from repro.core import api
from repro.core.calibration import load as load_params
from repro.core.isa import ABLATION_GRID, OptConfig
from repro.core.roofline import TPU_V5E
from repro.core.simulator import AraSimulator
from repro.core.traces import stack_traces
from repro.kernels import ops
from repro.kernels.flash_attention import attention_flops_bytes
from repro.kernels.gemm import gemm_flops_bytes
from repro.kernels.streamer import hbm_roundtrip_bytes


def _roofline_us(flops: float, bytes_: float) -> float:
    return max(flops / TPU_V5E.peak_flops, bytes_ / TPU_V5E.hbm_bw) * 1e6


def batch_grid_rows() -> list[dict]:
    """Scalar loop vs one batched call on the full Table I ablation grid
    (6 kernels x 8 opt corners, calibrated params)."""
    from benchmarks.table1_ablation import KERNELS
    params = load_params()
    traces = {k: tr for k, tr in gridlib.paper_traces().items()
              if k in KERNELS}
    opts = [OptConfig.baseline(), *ABLATION_GRID]
    n_cells = len(traces) * len(opts)
    shape = f"{len(traces)}x{len(opts)}"

    # Cycles-only timing: disable attribution so the scalar baseline pays
    # the same accounting the batched call does (none).
    sim = AraSimulator(params=params, attribution=False)

    def scalar_loop():
        return [sim.run(tr, o).cycles
                for tr in traces.values() for o in opts]

    stacked = stack_traces(list(traces.values()))

    def batched(bucket="none"):
        return lambda: api.simulate(stacked, opts, params,
                                    backend="numpy", method="scan",
                                    bucket=bucket, shard="none")

    scalar_us = timed(scalar_loop)
    batch_us = timed(batched())
    # Shape-bucketed variant: numpy already skips pad rows per trace, so
    # this times the planner's grouping overhead, not a pad-waste win —
    # the jax-side win is recorded by bench_record.py --planner.
    bucketed_us = timed(batched("pow2"))
    print(f"# table1 grid ({n_cells} cells): scalar {scalar_us:.0f}us, "
          f"batched {batch_us:.0f}us, bucketed {bucketed_us:.0f}us, "
          f"speedup {scalar_us / max(batch_us, 1e-9):.2f}x")
    return [
        {"kernel": "table1_grid_scalar_loop", "shape": shape,
         "cpu_interpret_us": scalar_us,
         "tpu_roofline_us": float("nan"), "hbm_bytes": 0},
        {"kernel": "table1_grid_batched", "shape": shape,
         "cpu_interpret_us": batch_us,
         "tpu_roofline_us": float("nan"), "hbm_bytes": 0},
        {"kernel": "table1_grid_bucketed", "shape": shape,
         "cpu_interpret_us": bucketed_us,
         "tpu_roofline_us": float("nan"), "hbm_bytes": 0},
    ]


#: Per-profile kernel sizes: ``default`` is the canonical microbench,
#: ``smoke`` shrinks every kernel so `bench_record.py --kernels` can fold
#: per-kernel numbers into BENCH_simulate.json within CI time budgets.
KERNEL_SIZES = {
    "default": {"n": 1 << 16, "gemm": 512, "attn_s": 512, "ssd_l": 512},
    "smoke": {"n": 1 << 12, "gemm": 128, "attn_s": 128, "ssd_l": 128},
}


def run(profile: str = "default", include_grid: bool = True) -> list[dict]:
    sz = KERNEL_SIZES[profile]
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    rows = []

    n = sz["n"]
    x, y, w = (jax.random.normal(k, (n,)) for k in ks[:3])
    for name, fn, fused in (("chain_fused", ops.fused_chain, True),
                            ("chain_unfused", ops.unfused_chain, False)):
        b = hbm_roundtrip_bytes((n,), jnp.float32, fused=fused)
        rows.append({
            "kernel": name, "shape": f"n={n}",
            "cpu_interpret_us": timed(fn, x, y, w),
            "tpu_roofline_us": _roofline_us(2 * n, b),
            "hbm_bytes": b,
        })

    m = kk = nn = sz["gemm"]
    a = jax.random.normal(ks[0], (m, kk), jnp.float32)
    bmat = jax.random.normal(ks[1], (kk, nn), jnp.float32)
    bias = jax.random.normal(ks[2], (nn,), jnp.float32)
    for name, fn, fused in (
            ("gemm_fused_epilogue",
             lambda: ops.gemm(a, bmat, bias, "gelu"), True),
            ("gemm_unfused_epilogue",
             lambda: ops.gemm_unfused_epilogue(a, bmat, bias, "gelu"),
             False)):
        fl, by = gemm_flops_bytes(m, nn, kk, jnp.float32,
                                  fused_epilogue=fused)
        rows.append({
            "kernel": name, "shape": f"{m}x{kk}x{nn}",
            "cpu_interpret_us": timed(fn),
            "tpu_roofline_us": _roofline_us(fl, by),
            "hbm_bytes": by,
        })

    b_, s, h, d = 1, sz["attn_s"], 4, 64
    q = jax.random.normal(ks[0], (b_, s, h, d), jnp.float32)
    kv = jax.random.normal(ks[1], (b_, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b_, s, h, d), jnp.float32)
    for name, flash in (("flash_attention", True),
                        ("naive_attention_model", False)):
        fl, by = attention_flops_bytes(b_, s, s, h, d, jnp.float32,
                                       flash=flash)
        rows.append({
            "kernel": name, "shape": f"b{b_} s{s} h{h} d{d}",
            "cpu_interpret_us": (timed(lambda: ops.flash_attention(
                q, kv, v, causal=True, bq=128, bkv=128))
                if flash else float("nan")),
            "tpu_roofline_us": _roofline_us(fl, by),
            "hbm_bytes": by,
        })

    L = sz["ssd_l"]
    xs = jax.random.normal(ks[0], (2, L, 8, 64), jnp.float32)
    dts = jax.nn.softplus(jax.random.normal(ks[1], (2, L, 8)))
    a_ = -jnp.exp(jax.random.normal(ks[2], (8,)))
    bs = jax.random.normal(ks[3], (2, L, 1, 64), jnp.float32)
    cs = jax.random.normal(ks[0], (2, L, 1, 64), jnp.float32)
    ssd_flops = 2 * 2 * L * 8 * (64 * 64 * 2 + 128 * 64)
    ssd_bytes = (xs.size + bs.size + cs.size + xs.size) * 4
    rows.append({
        "kernel": "ssd_chunked", "shape": f"b2 l{L} h8 p64 n64",
        "cpu_interpret_us": timed(
            lambda: ops.ssd_batched(xs, dts, a_, bs, cs, chunk=128)),
        "tpu_roofline_us": _roofline_us(ssd_flops, ssd_bytes),
        "hbm_bytes": ssd_bytes,
    })
    if include_grid:
        rows.extend(batch_grid_rows())
    return rows


def main() -> None:
    emit(run(), "kernel_bench")


if __name__ == "__main__":
    main()
