"""Table I: 2^3 orthogonal ablation of the M/C/O optimization classes."""
from __future__ import annotations

import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib
from benchmarks.common import apply_execution_args, emit, execution_args
from repro.core import paper
from repro.core.isa import ABLATION_GRID, geomean

KERNELS = ("scal", "axpy", "ger", "gemm", "gemv", "dotp")


def run() -> list[dict]:
    traces = {k: tr for k, tr in gridlib.paper_traces().items()
              if k in KERNELS}
    cells = gridlib.grid().cells(traces, [gridlib.BASE, *ABLATION_GRID])
    rows = []
    cols = {}
    for name in KERNELS:
        base = cells[(name, gridlib.BASE.label)].cycles
        row = {"kernel": name}
        for label, cfg in zip(paper.TABLE1_CONFIGS, ABLATION_GRID):
            s = base / cells[(name, cfg.label)].cycles
            row[f"{label}_sim"] = s
            cols.setdefault(label, []).append(s)
        for label, val in zip(paper.TABLE1_CONFIGS, paper.TABLE1[name]):
            row[f"{label}_paper"] = val
        rows.append(row)
    gm = {"kernel": "GEOMEAN"}
    for label in paper.TABLE1_CONFIGS:
        gm[f"{label}_sim"] = geomean(cols[label])
    for label, val in zip(paper.TABLE1_CONFIGS, paper.TABLE1_GEOMEAN):
        gm[f"{label}_paper"] = val
    rows.append(gm)
    return rows


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    execution_args(ap)
    apply_execution_args(ap.parse_args(argv or []))
    emit(run(), gridlib.table_name("table1_ablation"))


if __name__ == "__main__":
    main(sys.argv[1:])
