"""Table I: 2^3 orthogonal ablation of the M/C/O optimization classes."""
from __future__ import annotations

from benchmarks.common import emit, simulator
from repro.core import paper
from repro.core.isa import ABLATION_GRID, OptConfig, geomean
from repro.core.traces import DEFAULT_TRACES

KERNELS = ("scal", "axpy", "ger", "gemm", "gemv", "dotp")


def run() -> list[dict]:
    sim = simulator()
    rows = []
    cols = {}
    for name in KERNELS:
        tr = DEFAULT_TRACES[name]()
        base = sim.run(tr, OptConfig.baseline()).cycles
        row = {"kernel": name}
        for label, cfg in zip(paper.TABLE1_CONFIGS, ABLATION_GRID):
            s = base / sim.run(tr, cfg).cycles
            row[f"{label}_sim"] = s
            cols.setdefault(label, []).append(s)
        for label, val in zip(paper.TABLE1_CONFIGS, paper.TABLE1[name]):
            row[f"{label}_paper"] = val
        rows.append(row)
    gm = {"kernel": "GEOMEAN"}
    for label in paper.TABLE1_CONFIGS:
        gm[f"{label}_sim"] = geomean(cols[label])
    for label, val in zip(paper.TABLE1_CONFIGS, paper.TABLE1_GEOMEAN):
        gm[f"{label}_paper"] = val
    rows.append(gm)
    return rows


def main() -> None:
    emit(run(), "table1_ablation")


if __name__ == "__main__":
    main()
