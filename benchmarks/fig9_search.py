"""Fig. 9 (beyond the paper): attribution-guided design-space search.

The paper's Table I picks three optimization classes at one strength
each and measures eight corners; this figure inverts the question —
*given the simulator and the Table II cost anchors, which designs
should have been built?*  `repro.launch.design_search` searches the
flags-x-strengths space (beam / evolutionary / random-restart, every
candidate population scored in batched `simulate_groups` calls,
mutations biased by each design's binding critical path and by Sobol
interaction structure) and this script emits its outputs:

* ``fig9_search.csv`` — every evaluated design, frontier members
  flagged, with cost/score/per-class gap-closed columns;
* ``fig9_convergence.csv`` — the per-generation search log;
* ``fig9_search.png`` / ``fig9_convergence.png`` (``--plot``) — the
  cost/score frontier and the best-score trajectory;
* ``--regen`` rewrites the committed `experiments/search/pareto.json`
  at the canonical budget; ``--check`` regenerates it at that budget
  and verifies the committed file is dominance-equivalent, still
  mutually non-dominated, and its best design's calibrated-grid
  geomean has not drifted below `ara_calibrated.json` — the CI gate.

Profiles: ``smoke`` runs exactly the canonical committed budget (so
the CI smoke job's run doubles as the regeneration for ``--check``);
``default``/``large`` raise generations, population, and the corpus
evaluation budget.  docs/figures.md has the how-to-read-it entry.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib
from benchmarks.common import OUT_DIR, emit
from repro.launch import design_search

#: Per-profile search budgets.  ``smoke`` IS the canonical committed
#: budget — byte-identical config to `design_search.CANONICAL_BUDGET`
#: — so a smoke run regenerates `pareto.json` content for the gate.
PROFILE_BUDGETS = {
    "smoke": dict(design_search.CANONICAL_BUDGET),
    "default": dict(design_search.CANONICAL_BUDGET, per_class=4,
                    generations=6, population=20),
    "large": dict(design_search.CANONICAL_BUDGET, per_class=None,
                  generations=8, population=24),
}


def frontier_rows(payload: dict) -> list[dict]:
    """Flatten a `design_search.frontier_payload` into CSV rows: one
    per frontier point, cheapest first, the per-class gap-closed map
    unpacked into ``gap_<class>`` columns."""
    classes = sorted({c for r in payload["frontier"]
                      for c in r["gap_closed_by_class"]})
    records = sorted(payload["frontier"], key=lambda r: r["cost"])
    on_front = {r["key"] for r in records}
    # The calibrated-grid champion rides along even when the corpus
    # objective dominates it off the frontier (the drift-gate design).
    extra = payload.get("best_calibrated")
    if extra is not None and extra["key"] not in on_front:
        records.append(extra)
    rows = []
    for rank, r in enumerate(records):
        row = {
            "rank": rank, "key": r["key"], "label": r["label"],
            "score": r["score"], "cost": r["cost"],
            "area_mm2": r["area_mm2"], "power_mw": r["power_mw"],
            "geomean_speedup": r["geomean_speedup"],
            "gap_closed": r["gap_closed"],
            "calibrated_geomean": r.get("calibrated_geomean", ""),
            "dominant_path": r["dominant_path"],
            "on_frontier": r["key"] in on_front,
            "is_best": r["key"] == payload["best"]["key"],
            "is_best_calibrated": (
                extra is not None and r["key"] == extra["key"]),
        }
        for c in classes:
            row[f"gap_{c}"] = r["gap_closed_by_class"].get(c, "")
        row["strengths"] = ";".join(
            f"{k}={v:.4g}"
            for k, v in sorted(r["design"]["strengths"].items()))
        rows.append(row)
    return rows


def convergence_rows(payload: dict) -> list[dict]:
    return [dict(h) for h in payload["history"]]


def run(profile: str, seed: int | None = None) -> dict:
    """One search at the profile budget; returns the JSON payload
    (frontier annotated with calibrated-grid geomeans)."""
    budget = dict(PROFILE_BUDGETS[profile])
    if seed is not None:
        budget["seed"] = seed
    result = design_search.run_search(**budget)
    return design_search.frontier_payload(result)


def main(argv: list[str] | None = None) -> None:
    from benchmarks.common import apply_execution_args, execution_args
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=None,
                    help="override the budget's search seed")
    ap.add_argument("--plot", action="store_true",
                    help="also render fig9_search.png / "
                         "fig9_convergence.png (needs matplotlib)")
    ap.add_argument("--regen", action="store_true",
                    help="rewrite experiments/search/pareto.json from "
                         "this run (requires the canonical budget, "
                         "i.e. the smoke profile and default seed)")
    ap.add_argument("--check", action="store_true",
                    help="verify the committed pareto.json against "
                         "this run (CI gate; canonical budget only)")
    execution_args(ap)
    args = ap.parse_args(argv)
    apply_execution_args(args)

    profile = gridlib.active_profile()
    canonical = (PROFILE_BUDGETS[profile]
                 == design_search.CANONICAL_BUDGET
                 and args.seed is None)
    if (args.check or args.regen) and not canonical:
        raise SystemExit("--check/--regen need the canonical budget: "
                         "run under the smoke profile with no --seed")
    payload = run(profile, seed=args.seed)

    emit(frontier_rows(payload), gridlib.table_name("fig9_search"))
    emit(convergence_rows(payload),
         gridlib.table_name("fig9_convergence"))
    best = payload["best"]
    bcal = payload.get("best_calibrated", best)
    print(f"# best design: {best['label']} score={best['score']:.4f} "
          f"cost={best['cost']:.4f} mm2 "
          f"calibrated={best.get('calibrated_geomean', float('nan')):.4f} "
          f"| best on calibrated grid: "
          f"{bcal.get('calibrated_geomean', float('nan')):.4f} "
          f"({payload['n_evaluated']} designs evaluated, "
          f"{len(payload['frontier'])} on the frontier)")

    if args.plot:
        from repro.analysis.report import (render_convergence,
                                           render_frontier)
        png = OUT_DIR / f"{gridlib.table_name('fig9_search')}.png"
        render_frontier(frontier_rows(payload), png)
        conv = OUT_DIR / f"{gridlib.table_name('fig9_convergence')}.png"
        render_convergence(convergence_rows(payload), conv)
        print(f"# wrote {png} and {conv}")

    if args.regen:
        design_search.PARETO_PATH.parent.mkdir(parents=True,
                                               exist_ok=True)
        design_search.PARETO_PATH.write_text(
            json.dumps(payload, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {design_search.PARETO_PATH}")
    if args.check:
        errors = design_search.check_committed(regen=payload)
        for e in errors:
            print(f"ERROR: {e}")
        if errors:
            raise SystemExit(1)
        print("# committed pareto.json OK (dominance-equivalent, "
              "non-dominated, no calibrated-geomean drift)")


if __name__ == "__main__":
    main()
