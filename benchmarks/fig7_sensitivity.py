"""Fig. 7 (analysis artifact): parameter-sensitivity sweep.

Which microarchitectural knobs does the reproduced speedup hinge on?
For all 11 paper kernels, sweep every `SimParams` field around the
calibrated point (`repro.launch.sensitivity`): per-field 1-D traversals
(OAT) reduced to per-knob elasticities and tornado rankings, one
pairwise 2-D grid reduced to a gap-closed-ratio surface, and a
Latin-hypercube joint sample reduced to robustness bands.  Everything
runs as wide-params batched sweeps through `BatchAraSimulator`
(chunked P axis, content-addressed cell cache); ``--backend auto``
picks jax once the grid is wide enough (docs/backends.md records the
measured crossover).  docs/sensitivity.md explains every knob and how
to read the output.

    python benchmarks/fig7_sensitivity.py --profile smoke        # CI
    python benchmarks/fig7_sensitivity.py --profile large --plot
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib
from benchmarks.common import OUT_DIR, emit
from repro.analysis.report import (have_matplotlib, render_param_heatmap,
                                   render_tornado)
from repro.launch import sensitivity as S

#: Per-profile design sizes: OAT points per knob, pairwise grid side,
#: LHS joint-sample count.  smoke stays tiny for CI; `large` pairs the
#: past-paper problem sizes with a lean design so the full suite stays
#: in minutes (see docs/backends.md for measured runtimes).
DESIGN_SIZES = {
    "smoke": {"points": 2, "pair_points": 3, "lhs": 8},
    "default": {"points": 5, "pair_points": 5, "lhs": 32},
    "large": {"points": 2, "pair_points": 3, "lhs": 8},
}

#: Default pairwise surface: the dominant memory-side knob against the
#: dominant issue-side knob (the paper's §IV.A vs §IV.B tension).
DEFAULT_PAIR = ("mem_latency", "issue_gap_base")


def run(points: int, pair: tuple[str, str], pair_points: int, lhs_n: int,
        backend: str = "auto", method: str = "auto"
        ) -> dict[str, list[dict]]:
    """Run the three designs and reduce to row lists (keys: ``knobs``,
    ``pair``, ``lhs``)."""
    g = gridlib.grid()
    traces = gridlib.paper_traces()
    center = g.params
    kw = dict(mc=g.mc, backend=backend, method=method, cache=g.cache,
              use_cache=g.use_cache, sim=g.sim)

    oat = S.oat_design(center, points=points)
    t = S.sweep_design(traces, oat, **kw)
    out = {"knobs": S.knob_rows(oat, t)}

    pd = S.pair_design(center, pair, points=pair_points)
    out["pair"] = S.pair_rows(pd, S.sweep_design(traces, pd, **kw))

    ld = S.lhs_design(center, n=lhs_n)
    out["lhs"] = S.lhs_rows(ld, S.sweep_design(traces, ld, **kw))
    return out


def top_knobs(rows: list[dict], n: int = 3) -> dict[str, list[str]]:
    """Per-kernel top-`n` knobs by tornado rank."""
    by_kernel: dict[str, list[dict]] = {}
    for r in rows:
        by_kernel.setdefault(str(r["kernel"]), []).append(r)
    return {k: [r["knob"] for r in
                sorted(v, key=lambda r: r["tornado_rank"])[:n]]
            for k, v in by_kernel.items()}


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--profile", choices=tuple(gridlib.PROFILE_SIZES),
                    default=None,
                    help="problem-size profile (default: the active "
                         "gridlib profile)")
    ap.add_argument("--backend", choices=("auto", "numpy", "jax"),
                    default="auto",
                    help="auto picks jax past the measured width "
                         "crossover (docs/backends.md)")
    ap.add_argument("--method", choices=("auto", "scan", "assoc"),
                    default="auto",
                    help="jax instruction-axis algorithm; auto picks the "
                         "max-plus assoc engine only on accelerator "
                         "hosts (docs/backends.md)")
    ap.add_argument("--points", type=int, default=None,
                    help="OAT traversal points per knob")
    ap.add_argument("--pair", default=",".join(DEFAULT_PAIR),
                    help="two knobs for the pairwise surface, "
                         "comma-separated")
    ap.add_argument("--pair-points", type=int, default=None)
    ap.add_argument("--lhs", type=int, default=None,
                    help="Latin-hypercube joint-sample count")
    ap.add_argument("--plot", action="store_true",
                    help="also render tornado + heatmap PNGs (needs "
                         "matplotlib, the [plot] extra)")
    args = ap.parse_args(argv)

    prev_profile = gridlib.active_profile()
    if args.profile:
        gridlib.set_profile(args.profile)
    try:
        sizes = DESIGN_SIZES.get(gridlib.active_profile(),
                                 DESIGN_SIZES["default"])
        points = args.points if args.points is not None else \
            sizes["points"]
        pair_points = args.pair_points if args.pair_points is not None \
            else sizes["pair_points"]
        lhs_n = args.lhs if args.lhs is not None else sizes["lhs"]
        pair = tuple(args.pair.split(","))
        if len(pair) != 2:
            ap.error(f"--pair needs exactly two knobs, got {args.pair!r}")

        t0 = time.perf_counter()
        out = run(points, pair, pair_points, lhs_n, backend=args.backend,
                  method=args.method)
        dt = time.perf_counter() - t0

        emit(out["knobs"], gridlib.table_name("fig7_sensitivity"))
        emit(out["pair"],
             gridlib.table_name(f"fig7_pair_{pair[0]}_{pair[1]}"))
        emit(out["lhs"], gridlib.table_name("fig7_lhs"))
        print(f"# fig7 sweep: {dt:.1f}s "
              f"(profile={gridlib.active_profile()}, "
              f"backend={args.backend}, points={points})")
        print("# top-3 knobs per kernel (tornado rank):")
        for kernel, knobs in top_knobs(out["knobs"]).items():
            print(f"#   {kernel:<6} {', '.join(knobs)}")

        if args.plot:
            if have_matplotlib():
                p = render_tornado(
                    out["knobs"],
                    OUT_DIR / f"{gridlib.table_name('fig7_tornado')}.png",
                    title="per-kernel knob tornado")
                print(f"# tornado -> {p}")
                p = render_param_heatmap(
                    out["pair"], pair,
                    OUT_DIR / (gridlib.table_name(
                        f"fig7_pair_{pair[0]}_{pair[1]}") + ".png"))
                print(f"# pair heatmap -> {p}")
            else:
                print("# --plot skipped: matplotlib not installed "
                      "(pip install -e .[plot])")
    finally:
        gridlib.set_profile(prev_profile)


if __name__ == "__main__":
    main()
