"""Shared sweep grid for all paper benchmarks.

fig3/fig4/fig5/table1/table2 all read `(kernel, OptConfig)` cells of the
same ablation grid.  Instead of each script re-walking the traces through
the scalar simulator, they ask this module: cells are batch-evaluated by
`repro.core.batch_sim.BatchAraSimulator` (one vectorized call for every
missing cell) and memoized in the content-addressed
`repro.launch.sweep_cache.SweepCache`, so the second benchmark that needs
a cell gets it for free.

Profiles pick the problem sizes: ``default`` is the paper's Fig. 3 set;
``smoke`` shrinks every kernel so the whole benchmark suite finishes in
seconds on a CPU-only CI runner (`benchmarks/run.py --smoke`); ``large``
scales every kernel past the paper sizes for sensitivity sweeps beyond
Fig. 5 (expected runtimes in docs/backends.md — prefer the jax backend
there).
"""
from __future__ import annotations

import pathlib
import sys
from typing import Mapping, Sequence

REPO = pathlib.Path(__file__).resolve().parents[1]
if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))

from repro.analysis.attribution import phase_decompose_grid  # noqa: E402
from repro.core import api  # noqa: E402
from repro.core import traces as T  # noqa: E402
from repro.core.batch_sim import BatchAraSimulator  # noqa: E402
from repro.core.calibration import load as load_params  # noqa: E402
from repro.core.isa import (KernelTrace, MachineConfig,  # noqa: E402
                            OptConfig)
from repro.core.simulator import SimParams, SimResult  # noqa: E402
from repro.core.traces import stack_traces  # noqa: E402
from repro.launch.sweep_cache import (SweepCache, cell_key,  # noqa: E402
                                      trace_fingerprint)
from repro.obs import export as obs_export  # noqa: E402
from repro.obs import spans as obs_spans  # noqa: E402

#: Problem sizes per profile (kernel -> positional args).
PROFILE_SIZES: dict[str, dict[str, tuple]] = {
    "default": {
        "scal": (1024,), "axpy": (1024,), "dotp": (1024,),
        "gemv": (32, 128), "symv": (32,), "ger": (128, 128),
        "gemm": (128, 128, 128), "trsm": (32,), "syrk": (32, 32),
        "spmv": (32,), "dwt": (1024,),
    },
    "smoke": {
        "scal": (256,), "axpy": (256,), "dotp": (256,),
        "gemv": (16, 64), "symv": (16,), "ger": (32, 32),
        "gemm": (32, 32, 32), "trsm": (16,), "syrk": (16, 16),
        "spmv": (16,), "dwt": (256,),
    },
    # Sensitivity sweeps beyond Fig. 5: ~2-4x the paper sizes per axis.
    # Instruction streams grow accordingly (gemm dominates at ~112k
    # instructions); see docs/backends.md for measured runtimes.
    "large": {
        "scal": (4096,), "axpy": (4096,), "dotp": (4096,),
        "gemv": (64, 256), "symv": (64,), "ger": (256, 256),
        "gemm": (192, 192, 192), "trsm": (64,), "syrk": (64, 64),
        "spmv": (64,), "dwt": (4096,),
    },
}

_profile = "default"
_backend = "numpy"
_method = "scan"
_bucket = "auto"


def set_profile(name: str) -> None:
    """Select the active problem-size profile (``default`` or ``smoke``)."""
    global _profile
    if name not in PROFILE_SIZES:
        raise ValueError(f"unknown profile {name!r}")
    _profile = name


def active_profile() -> str:
    return _profile


def set_execution(backend: str | None = None,
                  method: str | None = None,
                  bucket: str | None = None) -> None:
    """Select the execution strategy for the shared grid (`grid()`).

    ``backend`` in ``numpy``/``jax``/``auto``; ``method`` in
    ``scan``/``assoc``/``auto`` — the ``--backend``/``--method`` flags of
    the fig scripts land here.  ``bucket`` picks the planner's shape
    bucketing (``none``/``pow2``/``auto``); it changes execution shape
    only, never results or cache keys.  Choices are validated by
    `repro.core.api.resolve_plan` at evaluation time (so ``auto`` can
    resolve per miss-batch); an already-built shared grid is updated in
    place, keeping its cache and compiled programs."""
    global _backend, _method, _bucket
    if backend is not None:
        _backend = backend
    if method is not None:
        _method = method
    if bucket is not None:
        _bucket = bucket
    if _shared is not None:
        if backend is not None:
            _shared.backend = backend
        if method is not None:
            _shared.method = method
        if bucket is not None:
            _shared.bucket = bucket


def active_method() -> str:
    return _method


def table_name(base: str) -> str:
    """Output-CSV name for the active profile.  Non-default profiles get a
    suffix so smoke-sized results never clobber (or masquerade as) the
    canonical paper-repro tables."""
    return base if _profile == "default" else f"{base}_{_profile}"


def paper_traces(profile: str | None = None) -> dict[str, KernelTrace]:
    """The 11 paper kernels at the active profile's sizes."""
    sizes = PROFILE_SIZES[profile or _profile]
    return {name: T.KERNELS[name](*sizes[name]) for name in sizes}


#: Scenarios per workload class the corpus axis serves, per profile.
#: ``None`` means the whole committed corpus; smoke keeps CI quick while
#: still spanning every class.
CORPUS_PER_CLASS: dict[str, int | None] = {
    "default": None, "smoke": 4, "large": None,
}


def corpus_traces(classes: Sequence[str] | None = None,
                  per_class: int | None = None,
                  profile: str | None = None) -> dict[str, KernelTrace]:
    """The committed scenario corpus (`repro.data.corpus`) as a grid
    axis: scenario-name -> trace, budgeted by the active profile.

    This is the workload frontier beyond the 11 paper kernels — ~160
    generated scenarios across the `repro.core.tracegen` classes, with
    genuinely mixed instruction-stream lengths (the shape-bucketed
    planner's first production workload).  `fig8_corpus.py` sweeps it.
    """
    from repro.data import corpus as C
    if per_class is None:
        per_class = CORPUS_PER_CLASS[profile or _profile]
    return C.corpus_traces(classes=classes, per_class=per_class)


#: Sentinel labels used as cell keys alongside OptConfig.label.
BASE = OptConfig.baseline()
FULL = OptConfig.full()


class Grid:
    """Batch-evaluated, cache-backed view of the ablation grid."""

    def __init__(self, params: SimParams | None = None,
                 mc: MachineConfig = MachineConfig(),
                 cache: SweepCache | None = None, use_cache: bool = True,
                 backend: str = "numpy", method: str = "scan",
                 bucket: str = "auto"):
        self.params = params if params is not None else load_params()
        self.mc = mc
        self.cache = cache if cache is not None else SweepCache()
        self.use_cache = use_cache
        self.backend = backend
        self.method = method
        self.bucket = bucket
        self.sim = BatchAraSimulator(mc)

    def cells(self, traces: Mapping[str, KernelTrace],
              opts: Sequence[OptConfig],
              attribution: bool = False
              ) -> dict[tuple[str, str], SimResult]:
        """Evaluate `(trace x opt)` cells, batch-running only cache misses.

        Returns `{(trace_key, opt.label): SimResult}` (timings omitted).
        With `attribution`, results carry the kernel ideal/stall
        decomposition plus the phase-split columns of
        `analysis.attribution.phase_decompose_grid` (`SimResult.phases`:
        prologue/steady/tail, dp/ii_eff/dt, t_ideal), on whichever
        backend the grid was built with; cached cells stored without
        either transparently re-simulate.
        """
        opts = list(opts)
        out: dict[tuple[str, str], SimResult] = {}
        keys: dict[tuple[str, str], str] = {}
        # Traces grouped by which opts they are missing, so a partial
        # cache hit only re-simulates the absent columns (one batched
        # call per distinct missing-opt signature, usually just one).
        by_sig: dict[tuple[int, ...], list[str]] = {}
        with obs_spans.span("cache.lookup", n_traces=len(traces),
                            n_opts=len(opts)) as lk:
            for tname, tr in traces.items():
                fp = trace_fingerprint(tr)     # hash the stream once
                sig = []
                for oi, opt in enumerate(opts):
                    ck = cell_key(tr, opt, self.params, self.mc,
                                  trace_fp=fp)
                    keys[(tname, opt.label)] = ck
                    res = (self.cache.get_result(
                               ck, tr.name, attribution=attribution,
                               require_phases=attribution)
                           if self.use_cache else None)
                    if res is None:
                        sig.append(oi)
                    else:
                        out[(tname, opt.label)] = res
                if sig:
                    by_sig.setdefault(tuple(sig), []).append(tname)
            lk.set(hit_cells=len(out))

        for sig, tnames in by_sig.items():
            run_opts = [opts[oi] for oi in sig]
            run_traces = [traces[t] for t in tnames]
            stacked = stack_traces(run_traces)
            plan = api.resolve_plan(backend=self.backend,
                                    method=self.method,
                                    width=len(run_opts),
                                    n_instrs=int(stacked.kind.shape[1]))
            # The cache stores only numpy/scan-computed cells: cell keys
            # don't encode the execution plan, and the cache's contract
            # is scalar bit-exactness — jax results (float64 allclose,
            # not bit-exact) are served to this call but never persisted.
            persist = (self.use_cache and plan.backend == "numpy"
                       and plan.method == "scan")
            batch = api.simulate(stacked, run_opts, self.params,
                                 mc=self.mc, backend=plan.backend,
                                 method=plan.method,
                                 bucket=self.bucket,
                                 attribution=attribution, sim=self.sim)
            pg = (phase_decompose_grid(run_traces, batch, mc=self.mc,
                                       params=[self.params])
                  if attribution else None)
            for bi, tname in enumerate(tnames):
                for oi, opt in enumerate(run_opts):
                    res = SimResult(
                        kernel=traces[tname].name,
                        cycles=float(batch.cycles[bi, oi, 0]),
                        flops=int(batch.flops[bi]),
                        bytes=int(batch.bytes[bi]), timings=[],
                        busy_fpu=float(batch.busy_fpu[bi, oi, 0]),
                        busy_bus=float(batch.busy_bus[bi, oi, 0]),
                        ideal=(float(batch.ideal[bi, oi, 0])
                               if batch.ideal is not None else 0.0),
                        stalls=(batch.stalls[bi, oi, 0].copy()
                                if batch.stalls is not None else None),
                        phases=(pg.columns(bi, oi, 0)
                                if pg is not None else None))
                    out[(tname, opt.label)] = res
                    if persist:
                        self.cache.put_result(keys[(tname, opt.label)], res)
        # All-hit grids never reach api.simulate (which flushes its own
        # runlog records), so flush here too when an env target is set —
        # a cache-served benchmark still leaves its lookup spans behind.
        obs_export.flush()
        return out

    def param_cells(self, traces: Mapping[str, KernelTrace],
                    opts: Sequence[OptConfig],
                    params_list: Sequence[SimParams],
                    attribution: bool = True,
                    p_chunk: int | None = None
                    ) -> dict[tuple[str, str, int], SimResult]:
        """Wide-params cells: `{(trace_key, opt.label, param_index):
        SimResult}` over an explicit params axis.

        The sensitivity counterpart of `cells`: evaluation, caching
        (content-addressed on the params block) and phase-column
        threading are delegated to `repro.launch.sensitivity.run_grid`,
        which chunks the P axis so `large`-profile grids fit memory and
        resolves the backend by grid width when this grid was built
        with ``backend="auto"``.
        """
        from repro.launch.sensitivity import DEFAULT_P_CHUNK, run_grid
        return run_grid(traces, params_list, opts, mc=self.mc,
                        backend=self.backend, method=self.method,
                        attribution=attribution,
                        cache=self.cache, use_cache=self.use_cache,
                        p_chunk=p_chunk if p_chunk is not None
                        else DEFAULT_P_CHUNK, bucket=self.bucket,
                        sim=self.sim)

    def base_and_full(self, traces: Mapping[str, KernelTrace]
                      ) -> dict[tuple[str, str], SimResult]:
        return self.cells(traces, [BASE, FULL])


_shared: Grid | None = None


def grid() -> Grid:
    """Process-wide shared grid (benchmarks run as one process via run.py,
    so fig3/fig4/table1/... cooperate through one cache/simulator)."""
    global _shared
    if _shared is None:
        _shared = Grid(backend=_backend, method=_method, bucket=_bucket)
    return _shared
