"""Fig. 4: roofline-normalized performance and gap-closed ratio."""
from __future__ import annotations

import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib
from benchmarks.common import apply_execution_args, emit, execution_args
from repro.core import paper
from repro.core.isa import geomean
from repro.core.roofline import gap_closed, normalized, p_ideal


def run() -> list[dict]:
    traces = gridlib.paper_traces()
    cells = gridlib.grid().base_and_full(traces)
    rows = []
    norm_b, norm_o, gaps = [], [], []
    for name, tr in traces.items():
        base = cells[(name, gridlib.BASE.label)]
        opt = cells[(name, gridlib.FULL.label)]
        oi = tr.operational_intensity
        nb, no = normalized(base.gflops, oi), normalized(opt.gflops, oi)
        gc = gap_closed(base.gflops, opt.gflops, oi)
        norm_b.append(nb)
        norm_o.append(no)
        gaps.append(gc)
        pb, po = paper.FIG4_NORMALIZED.get(name, (float("nan"),) * 2)
        rows.append({
            "kernel": name, "oi_flops_per_byte": oi,
            "p_ideal_gflops": p_ideal(oi),
            "norm_base_sim": nb, "norm_opt_sim": no, "gap_closed_sim": gc,
            "norm_base_paper": pb, "norm_opt_paper": po,
            "gap_closed_paper": paper.FIG4_GAP_CLOSED.get(name,
                                                          float("nan")),
        })
    rows.append({
        "kernel": "GEOMEAN", "oi_flops_per_byte": float("nan"),
        "p_ideal_gflops": float("nan"),
        "norm_base_sim": geomean(norm_b), "norm_opt_sim": geomean(norm_o),
        "gap_closed_sim": geomean([max(g, 1e-6) for g in gaps]),
        "norm_base_paper": paper.FIG4_GEOMEAN_NORM[0],
        "norm_opt_paper": paper.FIG4_GEOMEAN_NORM[1],
        "gap_closed_paper": paper.FIG4_GEOMEAN_GAP_CLOSED,
    })
    return rows


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    execution_args(ap)
    apply_execution_args(ap.parse_args(argv or []))
    emit(run(), gridlib.table_name("fig4_roofline"))


if __name__ == "__main__":
    main(sys.argv[1:])
