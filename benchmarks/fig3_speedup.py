"""Fig. 3: achieved performance of baseline Ara vs Ara-Opt per kernel."""
from __future__ import annotations

from benchmarks.common import emit, simulator
from repro.core import paper
from repro.core.isa import OptConfig, geomean
from repro.core.traces import DEFAULT_TRACES


def run() -> list[dict]:
    sim = simulator()
    rows = []
    speedups = []
    for name, fn in DEFAULT_TRACES.items():
        tr = fn()
        base = sim.run(tr, OptConfig.baseline())
        opt = sim.run(tr, OptConfig.full())
        s = base.cycles / opt.cycles
        speedups.append(s)
        rows.append({
            "kernel": name, "problem": tr.problem,
            "base_gflops": base.gflops, "opt_gflops": opt.gflops,
            "speedup_sim": s,
            "speedup_paper": paper.FIG3_SPEEDUP.get(name, float("nan")),
            "lane_util_base": base.lane_utilization,
            "lane_util_opt": opt.lane_utilization,
        })
    rows.append({
        "kernel": "GEOMEAN", "problem": "",
        "base_gflops": float("nan"), "opt_gflops": float("nan"),
        "speedup_sim": geomean(speedups),
        "speedup_paper": paper.FIG3_GEOMEAN,
        "lane_util_base": float("nan"), "lane_util_opt": float("nan"),
    })
    return rows


def main() -> None:
    emit(run(), "fig3_speedup")


if __name__ == "__main__":
    main()
