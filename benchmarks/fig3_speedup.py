"""Fig. 3: achieved performance of baseline Ara vs Ara-Opt per kernel."""
from __future__ import annotations

import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib
from benchmarks.common import apply_execution_args, emit, execution_args
from repro.core import paper
from repro.core.isa import geomean


def run() -> list[dict]:
    traces = gridlib.paper_traces()
    cells = gridlib.grid().base_and_full(traces)
    rows = []
    speedups = []
    for name, tr in traces.items():
        base = cells[(name, gridlib.BASE.label)]
        opt = cells[(name, gridlib.FULL.label)]
        s = base.cycles / opt.cycles
        speedups.append(s)
        rows.append({
            "kernel": name, "problem": tr.problem,
            "base_gflops": base.gflops, "opt_gflops": opt.gflops,
            "speedup_sim": s,
            "speedup_paper": paper.FIG3_SPEEDUP.get(name, float("nan")),
            "lane_util_base": base.lane_utilization,
            "lane_util_opt": opt.lane_utilization,
        })
    rows.append({
        "kernel": "GEOMEAN", "problem": "",
        "base_gflops": float("nan"), "opt_gflops": float("nan"),
        "speedup_sim": geomean(speedups),
        "speedup_paper": paper.FIG3_GEOMEAN,
        "lane_util_base": float("nan"), "lane_util_opt": float("nan"),
    })
    return rows


def main(argv=None) -> list[dict]:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    execution_args(ap)
    apply_execution_args(ap.parse_args(argv or []))
    rows = run()
    emit(rows, gridlib.table_name("fig3_speedup"))
    return rows


if __name__ == "__main__":
    main(sys.argv[1:])
