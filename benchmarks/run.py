"""Run every benchmark (one per paper table/figure + kernel/dry-run
tables).  Prints CSV per table and persists to experiments/benchmarks/."""
from __future__ import annotations

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))


def main() -> None:
    from benchmarks import (dryrun_table, fig3_speedup, fig4_roofline,
                            fig5_sensitivity, kernel_bench, table1_ablation,
                            table2_efficiency)
    fig3_speedup.main()
    fig4_roofline.main()
    table1_ablation.main()
    fig5_sensitivity.main()
    table2_efficiency.main()
    kernel_bench.main()
    dryrun_table.main()


if __name__ == "__main__":
    main()
