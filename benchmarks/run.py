"""Run every benchmark (one per paper table/figure + kernel/dry-run
tables).  Prints CSV per table and persists to experiments/benchmarks/.

``--smoke`` runs the paper tables/figures at reduced problem sizes and
skips the dry-run sweep and the JAX kernel microbench, so the suite
finishes in well under two minutes on a CPU-only CI runner.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small problem sizes, no dry-run sweep, no JAX "
                         "kernel microbench (CI profile)")
    args = ap.parse_args()

    from benchmarks import (dryrun_table, fig3_speedup, fig4_roofline,
                            fig5_sensitivity, fig6_attribution,
                            fig7_sensitivity, fig8_corpus, fig9_search,
                            gridlib, kernel_bench, table1_ablation,
                            table2_efficiency)
    if args.smoke:
        gridlib.set_profile("smoke")

    # fig6 first: its attribution=True pass stores stall-carrying cells
    # that every later (plain) reader hits, instead of plain cells the
    # attribution pass would have to re-simulate.  The stacked-bar PNG
    # rides along whenever matplotlib is importable (CI uploads it).
    from repro.analysis.report import have_matplotlib
    fig6_attribution.main(["--plot"] if have_matplotlib() else [])
    fig3_speedup.main()
    fig4_roofline.main()
    table1_ablation.main()
    fig5_sensitivity.main()
    table2_efficiency.main()
    # fig8 sweeps the generated-scenario corpus (the workload frontier
    # beyond the 11 paper kernels): per-class attribution + gap-closed.
    # Smoke trims it to CORPUS_PER_CLASS["smoke"] scenarios per class.
    fig8_corpus.main([])
    # fig7 parameter sensitivity: a tiny grid at smoke sizes for CI, the
    # wide params axis at `large` sizes in the full profile (the sweep
    # that actually exercises `large`; fig7 restores the active profile
    # on exit so it never leaks into later benchmarks).
    plot = ["--plot"] if have_matplotlib() else []
    if args.smoke:
        fig7_sensitivity.main(["--profile", "smoke", *plot])
        from benchmarks.common import emit
        emit(kernel_bench.batch_grid_rows(),
             gridlib.table_name("kernel_bench"))
        # fig9 design-space search: the smoke profile runs exactly the
        # canonical committed budget, so the same pass that emits the
        # frontier/convergence CSVs also verifies the committed
        # experiments/search/pareto.json (dominance equivalence + the
        # calibrated-geomean drift gate).
        fig9_search.main(["--check", *plot])
    else:
        fig7_sensitivity.main(["--profile", "large", *plot])
        fig9_search.main(plot)
        kernel_bench.main()
        dryrun_table.main()


if __name__ == "__main__":
    main()
