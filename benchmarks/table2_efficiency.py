"""Table II: PPA / efficiency positioning.

Synthesis is impossible in this container; we reproduce the table's
*structure* with an analytic resource model: the added hardware (descriptor
buffers, prefetch data buffer, dual-source operand queues, forwarding
muxes) is costed in SRAM bits + register-equivalents against the published
Ara area, and throughput comes from the calibrated simulator on the same
single-precision 128x128 gemm the paper measures.  Published values are
carried alongside for comparison.
"""
from __future__ import annotations

import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_REPO), str(_REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import gridlib
from benchmarks.common import emit
from repro.core import paper

# Resource model (TSMC28-ish densities): SRAM ~ 0.25 mm^2/Mbit,
# std-cell regs ~ 1.5x SRAM bit area.
SRAM_MM2_PER_MBIT = 0.25
ARA_BASE_MM2 = paper.TABLE2["area_mm2"][0]

ADDED_STRUCTURES = {
    # name: (bits, kind)
    "descriptor_buffer": (8 * 128, "reg"),          # 8 descriptors x 128b
    "transaction_queue": (16 * 96, "reg"),
    "prefetch_data_buffer": (2 * 1024 * 8 * 4, "sram"),   # 2x next-VL of fp32
    "dual_source_operand_queues": (4 * 2 * 10 * 64, "reg"),  # /lane x2 src
    "forwarding_network": (4 * 6 * 64, "reg"),      # per-lane 6-source mux
    "read_done_aggregator": (512, "reg"),
}


def added_area_mm2() -> float:
    total = 0.0
    for bits, kind in ADDED_STRUCTURES.values():
        mm2_per_bit = SRAM_MM2_PER_MBIT / 1e6 * (1.5 if kind == "reg"
                                                 else 1.0)
        total += bits * mm2_per_bit
    # control overhead factor for FSMs/arbiters around the new queues
    return total * 2.5


def run() -> list[dict]:
    traces = {"gemm": gridlib.paper_traces()["gemm"]}
    cells = gridlib.grid().base_and_full(traces)
    base = cells[("gemm", gridlib.BASE.label)]
    opt = cells[("gemm", gridlib.FULL.label)]
    add = added_area_mm2()
    area_opt = ARA_BASE_MM2 + add
    # Power model: dynamic power scales with achieved activity (lane
    # utilization) plus the new always-on structures.
    p_base = paper.TABLE2["power_mw"][0]
    p_opt = p_base * (opt.lane_utilization / max(base.lane_utilization,
                                                 1e-9)) * 0.95 + 12.0
    rows = [{
        "metric": "perf_gflops",
        "ara_sim": base.gflops, "ara_opt_sim": opt.gflops,
        "ratio_sim": opt.gflops / base.gflops,
        "ara_paper": paper.TABLE2["perf_gflops"][0],
        "ara_opt_paper": paper.TABLE2["perf_gflops"][1],
    }, {
        "metric": "area_mm2",
        "ara_sim": ARA_BASE_MM2, "ara_opt_sim": area_opt,
        "ratio_sim": area_opt / ARA_BASE_MM2,
        "ara_paper": paper.TABLE2["area_mm2"][0],
        "ara_opt_paper": paper.TABLE2["area_mm2"][1],
    }, {
        "metric": "power_mw",
        "ara_sim": p_base, "ara_opt_sim": p_opt,
        "ratio_sim": p_opt / p_base,
        "ara_paper": paper.TABLE2["power_mw"][0],
        "ara_opt_paper": paper.TABLE2["power_mw"][1],
    }, {
        "metric": "area_eff_gflops_mm2",
        "ara_sim": base.gflops / ARA_BASE_MM2,
        "ara_opt_sim": opt.gflops / area_opt,
        "ratio_sim": (opt.gflops / area_opt) / (base.gflops / ARA_BASE_MM2),
        "ara_paper": paper.TABLE2["area_eff"][0],
        "ara_opt_paper": paper.TABLE2["area_eff"][1],
    }, {
        "metric": "energy_eff_gflops_w",
        "ara_sim": base.gflops / (p_base / 1e3),
        "ara_opt_sim": opt.gflops / (p_opt / 1e3),
        "ratio_sim": (opt.gflops / p_opt) / (base.gflops / p_base),
        "ara_paper": paper.TABLE2["energy_eff"][0],
        "ara_opt_paper": paper.TABLE2["energy_eff"][1],
    }]
    return rows


def main() -> None:
    emit(run(), gridlib.table_name("table2_efficiency"))


if __name__ == "__main__":
    main()
